"""Rule 3: no blocking calls lexically inside a held-lock block.

Under any ``with <lock>`` flag:
- ``<x>.recv(...)`` — inbox wait
- ``<x>.transport.request(...)`` — blocking RPC (request_async is fine)
- ``<x>.get(timeout=...)`` with a positive or non-constant timeout —
  queue waits; plain ``d.get(k)`` dict lookups have no timeout kw
- ``time.sleep(...)``

These turn a lock into a convoy: every other thread needing it stalls for
a full network timeout. The transport deliberately calls ``waiter.put``
and ``ep.deliver`` outside its locks for the same reason.
"""
from __future__ import annotations

import ast
from typing import Dict, List

from .report import Violation
from .locks import iter_functions, walk_with_stacks


def _is_blocking(call: ast.Call) -> str:
    fn = call.func
    if not isinstance(fn, ast.Attribute):
        return ""
    if fn.attr == "recv":
        return "recv() (inbox wait)"
    if fn.attr == "request" and "transport" in ast.unparse(fn.value):
        return "blocking transport.request()"
    if fn.attr == "sleep" and isinstance(fn.value, ast.Name) \
            and fn.value.id == "time":
        return "time.sleep()"
    if fn.attr == "get":
        for kw in call.keywords:
            if kw.arg == "timeout":
                v = kw.value
                if isinstance(v, ast.Constant) \
                        and isinstance(v.value, (int, float)) \
                        and v.value <= 0:
                    return ""
                return "queue.get(timeout=...)"
    return ""


def check(trees: Dict[str, ast.Module]) -> List[Violation]:
    violations: List[Violation] = []
    for fname, tree in trees.items():
        if fname == "locktrack.py":
            continue
        for fn, cls in iter_functions(tree):
            for node, held in walk_with_stacks(fn, cls):
                if not held or not isinstance(node, ast.Call):
                    continue
                what = _is_blocking(node)
                if what:
                    violations.append(Violation(
                        "blocking", fname, node.lineno,
                        f"{held[-1]}:{ast.unparse(node.func)}",
                        f"{what} while holding {held[-1]}"))
    return violations
