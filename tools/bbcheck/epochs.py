"""Rule 7: epoch state-machine verification.

The flush / drain / stage coordinators track multi-step epochs in plain
dict tables (``Server._flush`` / ``_drain_epochs`` / ``_stage_epochs``,
the manager's ``_user_flushes`` and the ``_drain`` / ``_stage``
singletons). A zombie entry — created but never deleted on the failure
path — wedges the coordinator forever (the exact hazard the hand-written
comment above ``BBServer._closed_epochs`` worries about). This rule
extracts each table's lifecycle from its mutation sites and verifies:

- **creation reachability**: every site that creates an entry
  (``self.T[k] = ...`` / ``self.T.setdefault(k, ...)`` / dict-literal
  assignment to a singleton slot) is reachable, through the intra-class
  call graph, from a ``*begin*`` / ``*request*`` function — epochs only
  start at an explicit begin;
- **no zombies**: every table has at least one deletion site
  (``pop`` / ``del`` / ``None``-assignment) reachable from an
  ``abort`` / ``timeout`` / ``expire`` / ``sweep`` / ``fail`` path;
- **idempotent aborts**: deletion sites on an ``abort`` path must be
  membership-guarded — ``pop(k, default)``, an assignment to ``None``
  (inherently idempotent, incl. the swap-and-check idiom), or a ``del``
  under an ``if`` that tests the table — so a late duplicate abort is a
  no-op, not a KeyError;
- **disjoint id spaces**: ``*_EPOCH_BASE`` constants must be pairwise
  distinct and ``>= 1 << 30`` (user flush epochs own the low space), no
  two ``self._next_*`` allocation counters may share a base, and the
  user-facing ``begin*``-function that creates a user-epoch entry must
  range-check the caller's epoch against the lowest base.

Tables are discovered, not configured: any ``self.<attr>`` whose name
mentions epoch/flush/drain/stage and is keyed-created (or swings between
a dict literal and ``None`` for singleton slots) is tracked. A table
whose creating function also bounds it (deletes in the same function) is
a results cache, not a lifecycle table, and is exempt.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set, Tuple

from .report import Violation

NAME_RE = re.compile(r"epoch|flush|drain|stage", re.I)
BEGIN_RE = re.compile(r"begin|request", re.I)
ABORT_RE = re.compile(r"abort|timeout|expire|sweep|fail", re.I)
SKIP_MODULES = {"locktrack.py"}


def _const_int(node: ast.AST) -> Optional[int]:
    """Constant-fold an int expression (handles ``1 << 30`` etc.)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return node.value
    if isinstance(node, ast.BinOp):
        left, right = _const_int(node.left), _const_int(node.right)
        if left is None or right is None:
            return None
        try:
            if isinstance(node.op, ast.LShift):
                return left << right
            if isinstance(node.op, ast.Add):
                return left + right
            if isinstance(node.op, ast.Sub):
                return left - right
            if isinstance(node.op, ast.Mult):
                return left * right
            if isinstance(node.op, ast.Pow):
                return left ** right
        except Exception:                    # pragma: no cover
            return None
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        inner = _const_int(node.operand)
        return None if inner is None else -inner
    return None


def _self_attr(node: ast.AST) -> Optional[str]:
    """Attr name if ``node`` is ``self.<attr>``, else None."""
    if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name) \
            and node.value.id == "self":
        return node.attr
    return None


class Site:
    __slots__ = ("fn", "line", "guarded")

    def __init__(self, fn: str, line: int, guarded: bool = True):
        self.fn = fn          # enclosing method name
        self.line = line
        self.guarded = guarded


class Table:
    def __init__(self, cls: str, attr: str, fname: str):
        self.cls = cls
        self.attr = attr
        self.fname = fname
        self.creates: List[Site] = []
        self.deletes: List[Site] = []
        self.singleton = False


def _class_methods(cls: ast.ClassDef) -> Dict[str, ast.FunctionDef]:
    return {fn.name: fn for fn in cls.body
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef))}


def _call_graph(methods: Dict[str, ast.FunctionDef]) -> Dict[str, Set[str]]:
    graph: Dict[str, Set[str]] = {}
    for name, fn in methods.items():
        callees: Set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                callee = _self_attr(node.func)
                if callee is not None and callee in methods:
                    callees.add(callee)
        graph[name] = callees
    return graph


def _reachable_from(graph: Dict[str, Set[str]], pattern: re.Pattern,
                    ) -> Set[str]:
    """Methods reachable (incl. transitively) from any pattern-matching
    method — the matching methods themselves included."""
    roots = {m for m in graph if pattern.search(m)}
    seen = set(roots)
    stack = list(roots)
    while stack:
        for callee in graph.get(stack.pop(), ()):
            if callee not in seen:
                seen.add(callee)
                stack.append(callee)
    return seen


def _has_membership_guard(node: ast.AST, attr: str,
                          fn: ast.FunctionDef) -> bool:
    """True if ``node`` (a del/pop site) sits under or after an ``if``
    whose test mentions ``self.<attr>`` inside ``fn``."""
    for test in (n.test for n in ast.walk(fn) if isinstance(n, ast.If)):
        if test.lineno <= node.lineno \
                and f"self.{attr}" in ast.unparse(test):
            return True
    return False


def _collect_tables(cls: ast.ClassDef, fname: str) -> List[Table]:
    methods = _class_methods(cls)
    tables: Dict[str, Table] = {}

    def table(attr: str) -> Table:
        return tables.setdefault(attr, Table(cls.name, attr, fname))

    for mname, fn in methods.items():
        for node in ast.walk(fn):
            # -- keyed creation: self.T[k] = v / self.T.setdefault(k, ...)
            if isinstance(node, ast.Assign):
                # pair tuple-unpack targets with their values so the
                # swap-and-check idiom ``d, self._drain = self._drain,
                # None`` registers as a None-assignment delete
                pairs: List[Tuple[ast.AST, ast.AST]] = []
                for tgt in node.targets:
                    if isinstance(tgt, (ast.Tuple, ast.List)) \
                            and isinstance(node.value,
                                           (ast.Tuple, ast.List)) \
                            and len(tgt.elts) == len(node.value.elts):
                        pairs.extend(zip(tgt.elts, node.value.elts))
                    else:
                        pairs.append((tgt, node.value))
                for tgt, val in pairs:
                    if isinstance(tgt, ast.Subscript):
                        attr = _self_attr(tgt.value)
                        if attr and NAME_RE.search(attr) \
                                and mname != "__init__":
                            table(attr).creates.append(
                                Site(mname, node.lineno))
                    else:
                        attr = _self_attr(tgt)
                        if attr and NAME_RE.search(attr) \
                                and mname != "__init__":
                            # singleton slot: a non-empty dict-literal
                            # state blob <-> None swings ({} resets are
                            # not epoch creations)
                            if isinstance(val, ast.Dict) and val.keys:
                                t = table(attr)
                                t.singleton = True
                                t.creates.append(Site(mname, node.lineno))
                            elif isinstance(val, ast.Constant) \
                                    and val.value is None:
                                t = tables.get(attr)
                                if t is None:
                                    t = table(attr)
                                # None-assignment is idempotent by nature
                                t.deletes.append(
                                    Site(mname, node.lineno, guarded=True))
            elif isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute):
                base = _self_attr(node.func.value)
                if base and NAME_RE.search(base) and mname != "__init__":
                    if node.func.attr == "setdefault":
                        table(base).creates.append(Site(mname, node.lineno))
                    elif node.func.attr == "pop":
                        guarded = len(node.args) >= 2 or bool(node.keywords) \
                            or _has_membership_guard(
                                node, base, methods[mname])
                        table(base).deletes.append(
                            Site(mname, node.lineno, guarded))
            elif isinstance(node, ast.Delete):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Subscript):
                        attr = _self_attr(tgt.value)
                        if attr and NAME_RE.search(attr):
                            guarded = _has_membership_guard(
                                node, attr, methods[mname])
                            table(attr).deletes.append(
                                Site(mname, node.lineno, guarded))

    # only lifecycle tables: must actually be created somewhere; a table
    # whose every delete lives in its own creating function is a
    # self-bounded results cache, not an epoch lifecycle
    out = []
    for t in tables.values():
        if not t.creates:
            continue
        if not t.singleton and t.deletes:
            create_fns = {s.fn for s in t.creates}
            if {d.fn for d in t.deletes} <= create_fns:
                continue
        out.append(t)
    return out


def _check_id_spaces(fname: str, tree: ast.Module,
                     violations: List[Violation]):
    bases: Dict[str, Tuple[int, int]] = {}   # name -> (value, line)
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and node.targets[0].id.endswith("_EPOCH_BASE"):
            val = _const_int(node.value)
            if val is not None:
                bases[node.targets[0].id] = (val, node.lineno)

    names = sorted(bases)
    for i, a in enumerate(names):
        va, la = bases[a]
        if va < (1 << 30):
            violations.append(Violation(
                "epochs", fname, la, f"id-low:{a}",
                f"{a} = {va} overlaps the user flush epoch space "
                f"(bases must be >= 1<<30)"))
        for b in names[i + 1:]:
            vb, _lb = bases[b]
            if va == vb:
                violations.append(Violation(
                    "epochs", fname, la, f"id-overlap:{a}:{b}",
                    f"{a} and {b} share value {va}: drain/stage/flush "
                    f"epoch-id spaces must be disjoint"))

    # allocation counters must not share a base expression
    for cls in ast.walk(tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        init = _class_methods(cls).get("__init__")
        if init is None:
            continue
        counters: Dict[str, Tuple[str, int]] = {}
        for node in ast.walk(init):
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                attr = _self_attr(node.targets[0])
                if attr and attr.startswith("_next_") \
                        and NAME_RE.search(attr):
                    counters[attr] = (ast.unparse(node.value), node.lineno)
        seen: Dict[str, str] = {}
        for attr, (expr, line) in sorted(counters.items()):
            if expr in seen:
                violations.append(Violation(
                    "epochs", fname, line,
                    f"id-shared-base:{cls.name}.{attr}",
                    f"{cls.name}.{attr} and {cls.name}.{seen[expr]} "
                    f"allocate from the same base ({expr}): their epoch-id "
                    f"spaces collide"))
            else:
                seen[expr] = attr

    return bases


def _check_user_space_guard(fname: str, tree: ast.Module, bases: Dict,
                            tables: List[Table],
                            violations: List[Violation]):
    """The ``begin*`` function that admits caller-chosen epoch ids into a
    table must range-check them against the lowest reserved base."""
    if not bases:
        return
    low_base = min(bases, key=lambda n: bases[n][0])
    for cls in ast.walk(tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        methods = _class_methods(cls)
        cls_tables = {t.attr: t for t in tables if t.cls == cls.name}
        for mname, fn in methods.items():
            if not BEGIN_RE.search(mname) or mname.startswith("_on_"):
                continue
            creates_here = any(
                any(s.fn == mname for s in t.creates)
                for t in cls_tables.values() if not t.singleton)
            if not creates_here:
                continue
            src = ast.unparse(fn)
            if low_base not in src:
                violations.append(Violation(
                    "epochs", fname, fn.lineno,
                    f"user-space-unchecked:{cls.name}.{mname}",
                    f"{cls.name}.{mname} admits caller-chosen epoch ids "
                    f"but never checks them against {low_base}: a user "
                    f"epoch >= 1<<30 would collide with reserved spaces"))


def check(trees: Dict[str, ast.Module]) -> List[Violation]:
    violations: List[Violation] = []
    for fname, tree in sorted(trees.items()):
        if fname in SKIP_MODULES:
            continue
        bases = _check_id_spaces(fname, tree, violations)
        all_tables: List[Table] = []
        for cls in ast.walk(tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            tables = _collect_tables(cls, fname)
            all_tables.extend(tables)
            methods = _class_methods(cls)
            graph = _call_graph(methods)
            from_begin = _reachable_from(graph, BEGIN_RE)
            from_abort = _reachable_from(graph, ABORT_RE)
            for t in sorted(tables, key=lambda t: t.attr):
                for s in t.creates:
                    if s.fn not in from_begin:
                        violations.append(Violation(
                            "epochs", fname, s.line,
                            f"create-unreachable:{t.cls}.{t.attr}:{s.fn}",
                            f"{t.cls}.{t.attr} entry created in {s.fn} "
                            f"which is not reachable from any "
                            f"*begin*/*request* handler"))
                abort_deletes = [d for d in t.deletes if d.fn in from_abort]
                if not abort_deletes:
                    violations.append(Violation(
                        "epochs", fname, t.creates[0].line,
                        f"zombie:{t.cls}.{t.attr}",
                        f"{t.cls}.{t.attr} has no abort/timeout path that "
                        f"deletes entries: a failed epoch wedges the "
                        f"table forever"))
                for d in abort_deletes:
                    if not d.guarded:
                        violations.append(Violation(
                            "epochs", fname, d.line,
                            f"abort-unguarded:{t.cls}.{t.attr}:{d.fn}",
                            f"abort-path delete of {t.cls}.{t.attr} in "
                            f"{d.fn} is not membership-guarded: a "
                            f"duplicate abort raises instead of no-op"))
        _check_user_space_guard(fname, tree, bases, all_tables, violations)
    return violations
