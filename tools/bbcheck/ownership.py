"""Rule 8: static thread-ownership race detection.

Every ``self.<field>`` mutation in core is classified by the *execution
contexts* that can reach it:

- ``run-loop`` — the entity's ``run`` method and, for getattr-dispatched
  classes, its ``_on_*`` handlers;
- ``thread:<m>`` / ``worker:<m>`` — methods used as
  ``threading.Thread(target=...)`` targets or ``parallel_map`` fan-out
  bodies anywhere in core (lambda bodies are scanned for
  ``self``-rooted calls, so ``Thread(target=lambda: self._expire(e))``
  marks ``_expire``);
- ``api`` — public methods (external callers), plus private methods not
  reachable from any other entry (they must be driven cross-class).

Contexts propagate through the intra-class call graph (lambda bodies are
not attributed to their enclosing method — they run in their own
context). The guarding lock at each mutation site is inferred from
enclosing ``with`` statements via the same walker as the lock-order rule,
*plus* caller-held locks: a private helper that every visible intra-class
call site invokes with a lock held (the ``*_locked`` naming convention)
inherits the intersection of its callers' locks. Entry-point methods —
``run``, thread/worker targets, dispatched handlers, the public API —
can be called with nothing held and never inherit.

A field mutated from **two or more contexts without one lock common to
every mutation site** is flagged. Fields holding synchronization
primitives (locks, Events, Queues, ...) are exempt; ``__init__``
assignments are construction, not mutation. ``del``/``pop``/``+=``/
``.append`` and friends all count as mutations, including through one
subscript level (``self.stats["k"] += 1``).

The sanctioned escape is a structured marker on the field's declaration
(or any mutation site):  ``# bbcheck: shared=<lock>``  where ``<lock>``
names a lock attribute of the class — or the literal ``gil`` for fields
that deliberately rely on single-bytecode atomicity. The marker is
verified: naming a lock the class does not own fails, and a marker on a
field the pass would *not* flag is stale and fails too — annotations can
only shrink, like the allowlist.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set, Tuple

from .locks import walk_with_stacks
from .report import Violation

SKIP_MODULES = {"locktrack.py"}

ANNOT_RE = re.compile(r"#\s*bbcheck:\s*shared=([A-Za-z_][\w]*)")

# mutating method names on containers (one call = one mutation)
MUTATORS = {"append", "appendleft", "add", "update", "clear", "pop",
            "popleft", "popitem", "discard", "remove", "setdefault",
            "extend", "insert", "sort", "reverse"}

# constructor calls whose results are internally synchronized (or
# construction-only): fields holding these are exempt
PRIMITIVE_FACTORIES = {"lock", "rlock", "Lock", "RLock", "Event",
                       "Condition", "Semaphore", "BoundedSemaphore",
                       "Barrier", "Queue", "SimpleQueue", "LifoQueue",
                       "PriorityQueue", "local", "count"}

LOCK_FACTORIES = {"lock", "rlock", "Lock", "RLock"}


def _root_name(node: ast.AST) -> Optional[str]:
    while isinstance(node, (ast.Attribute, ast.Subscript, ast.Call)):
        node = node.func if isinstance(node, ast.Call) else node.value
    return node.id if isinstance(node, ast.Name) else None


def _self_attr(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name) \
            and node.value.id == "self":
        return node.attr
    return None


def _field_of_target(node: ast.AST) -> Optional[str]:
    """Field name if ``node`` is ``self.X`` or ``self.X[...]...`` —
    i.e. the mutated object hangs directly off self. ``self.a.b`` mutates
    another object's state and is out of scope here."""
    while isinstance(node, ast.Subscript):
        node = node.value
    return _self_attr(node)


def _mutation_targets(node: ast.AST) -> List[Tuple[str, int]]:
    """(field, line) pairs mutated by a statement node."""
    out: List[Tuple[str, int]] = []

    def targets_of(tgt: ast.AST):
        if isinstance(tgt, (ast.Tuple, ast.List)):
            for el in tgt.elts:
                targets_of(el)
            return
        field = _field_of_target(tgt)
        if field is not None:
            out.append((field, tgt.lineno))

    if isinstance(node, ast.Assign):
        for tgt in node.targets:
            targets_of(tgt)
    elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
        if not (isinstance(node, ast.AnnAssign) and node.value is None):
            targets_of(node.target)
    elif isinstance(node, ast.Delete):
        for tgt in node.targets:
            targets_of(tgt)
    elif isinstance(node, ast.Call) \
            and isinstance(node.func, ast.Attribute) \
            and node.func.attr in MUTATORS:
        field = _field_of_target(node.func.value)
        if field is not None:
            out.append((field, node.lineno))
    return out


def _thread_and_worker_targets(trees: Dict[str, ast.Module]
                               ) -> Tuple[Set[str], Set[str]]:
    """Method names used as Thread targets / parallel_map bodies anywhere
    (self-rooted only: ``self.run``, ``self.fs.stage``,
    ``lambda: self._expire(e)``)."""

    def self_rooted_calls(expr: ast.AST) -> Set[str]:
        names: Set[str] = set()
        if isinstance(expr, ast.Attribute) and _root_name(expr) == "self":
            names.add(expr.attr)
        elif isinstance(expr, ast.Lambda):
            for node in ast.walk(expr.body):
                if isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Attribute) \
                        and _root_name(node.func) == "self":
                    names.add(node.func.attr)
        return names

    threads: Set[str] = set()
    workers: Set[str] = set()
    for tree in trees.values():
        for call in ast.walk(tree):
            if not isinstance(call, ast.Call):
                continue
            func_txt = ast.unparse(call.func)
            if func_txt.endswith("Thread"):
                for kw in call.keywords:
                    if kw.arg == "target":
                        threads |= self_rooted_calls(kw.value)
            elif func_txt.endswith("parallel_map") and call.args:
                workers |= self_rooted_calls(call.args[0])
    return threads, workers


def _is_dispatcher(cls: ast.ClassDef) -> bool:
    for node in ast.walk(cls):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id == "getattr":
            for a in node.args:
                if isinstance(a, ast.JoinedStr) and any(
                        isinstance(v, ast.Constant) and "_on_" in str(v.value)
                        for v in a.values):
                    return True
    return False


def _callees(fn: ast.AST, methods: Dict[str, ast.AST]) -> Set[str]:
    """Direct ``self.m()`` callees — lambda/nested-def bodies excluded
    (they run in their own context, discovered as thread targets)."""
    out: Set[str] = set()

    def visit(node: ast.AST):
        if isinstance(node, ast.Lambda):
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node is not fn:
            return
        if isinstance(node, ast.Call):
            callee = _self_attr(node.func)
            if callee in methods:
                out.add(callee)
        for child in ast.iter_child_nodes(node):
            visit(child)

    visit(fn)
    return out


class _ClassInfo:
    def __init__(self, cls: ast.ClassDef, fname: str,
                 threads: Set[str], workers: Set[str]):
        self.cls = cls
        self.fname = fname
        self.methods: Dict[str, ast.AST] = {
            fn.name: fn for fn in cls.body
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef))}
        self.dispatcher = _is_dispatcher(cls)
        self.entry_points: Set[str] = set()
        self.ctx = self._contexts(threads, workers)
        self.entry_locks = self._caller_locks()
        self.exempt, self.lock_fields, self.decl_lines = self._init_fields()

    def _contexts(self, threads: Set[str],
                  workers: Set[str]) -> Dict[str, Set[str]]:
        entry: Dict[str, Set[str]] = {}
        for m in self.methods:
            ctxs: Set[str] = set()
            if m == "run":
                ctxs.add("run-loop")
            else:
                if m in threads:
                    ctxs.add(f"thread:{m}")
                if m in workers:
                    ctxs.add(f"worker:{m}")
            if not ctxs:
                if self.dispatcher and m.startswith("_on_"):
                    ctxs.add("run-loop")
                elif not m.startswith("_") \
                        or (m.startswith("__") and m.endswith("__")):
                    ctxs.add("api")
            entry[m] = ctxs
        self.entry_points = {m for m, c in entry.items() if c}

        calls = {m: _callees(fn, self.methods)
                 for m, fn in self.methods.items()}
        ctx = {m: set(s) for m, s in entry.items()}

        def propagate():
            changed = True
            while changed:
                changed = False
                for m, callees in calls.items():
                    for c in callees:
                        if not ctx[m] <= ctx[c]:
                            ctx[c] |= ctx[m]
                            changed = True
        propagate()
        # private methods no entry reaches are driven cross-class: api
        for m in ctx:
            if not ctx[m]:
                ctx[m].add("api")
        propagate()
        return ctx

    def _caller_locks(self) -> Dict[str, Set[str]]:
        """Locks guaranteed held at *entry* to each method: the
        intersection over every visible ``self.m()`` call site of
        (locks lexically held at the site, plus the caller's own entry
        locks). Formalizes the ``*_locked`` convention where the caller
        acquires the lock. Entry-point methods are forced empty — an
        external caller holds nothing. Pessimistic start, so the
        fixpoint only ever grows and never over-claims."""
        # callee -> [(caller, locks lexically held at the call site)]
        sites: Dict[str, List[Tuple[str, frozenset]]] = {}
        for m, fn in self.methods.items():
            for node, held in walk_with_stacks(fn, self.cls.name):
                if isinstance(node, ast.Call):
                    callee = _self_attr(node.func)
                    if callee in self.methods:
                        sites.setdefault(callee, []).append(
                            (m, frozenset(held)))

        locks: Dict[str, Set[str]] = {m: set() for m in self.methods}
        changed = True
        while changed:
            changed = False
            for m in self.methods:
                if m in self.entry_points or not sites.get(m):
                    continue
                new: Optional[Set[str]] = None
                for caller, held in sites[m]:
                    eff = set(held) | locks[caller]
                    new = eff if new is None else new & eff
                if new != locks[m]:
                    locks[m] = new or set()
                    changed = True
        return locks

    def _init_fields(self):
        """(exempt primitive-holding fields, lock fields, decl lines)."""
        exempt: Set[str] = set()
        lock_fields: Set[str] = set()
        decl_lines: Dict[str, List[int]] = {}
        init = self.methods.get("__init__")
        nodes = list(ast.walk(init)) if init is not None else []
        for stmt in self.cls.body:                  # class-level attrs too
            nodes.append(stmt)
        for node in nodes:
            if not isinstance(node, ast.Assign):
                continue
            for tgt in node.targets:
                field = _self_attr(tgt)
                if field is None and isinstance(tgt, ast.Name):
                    field = tgt.id                  # class-level attribute
                if field is None:
                    continue
                decl_lines.setdefault(field, []).append(node.lineno)
                v = node.value
                if isinstance(v, ast.Call):
                    callee = v.func.attr if isinstance(v.func, ast.Attribute) \
                        else (v.func.id if isinstance(v.func, ast.Name)
                              else None)
                    if callee in PRIMITIVE_FACTORIES:
                        exempt.add(field)
                    if callee in LOCK_FACTORIES:
                        lock_fields.add(field)
        return exempt, lock_fields, decl_lines


def check(trees: Dict[str, ast.Module]) -> List[Violation]:
    trees = {f: t for f, t in trees.items() if f not in SKIP_MODULES}
    threads, workers = _thread_and_worker_targets(trees)
    violations: List[Violation] = []

    for fname, tree in sorted(trees.items()):
        source = getattr(tree, "_bb_source", None)
        src_lines = source.splitlines() if source is not None else None

        for cls in ast.walk(tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            info = _ClassInfo(cls, fname, threads, workers)

            # field -> [(ctxs, locks, line, method)]
            muts: Dict[str, List[Tuple[Set[str], frozenset, int, str]]] = {}
            for m, fn in info.methods.items():
                if m == "__init__":
                    continue
                ctxs = info.ctx[m]
                inherited = info.entry_locks.get(m, set())
                for node, held in walk_with_stacks(fn, cls.name):
                    for field, line in _mutation_targets(node):
                        if field in info.exempt:
                            continue
                        muts.setdefault(field, []).append(
                            (ctxs, frozenset(set(held) | inherited),
                             line, m))

            def annotation(field: str) -> Tuple[Optional[str], Optional[int]]:
                if src_lines is None:
                    return None, None
                lines = list(info.decl_lines.get(field, []))
                lines += [ln for _c, _l, ln, _m in muts.get(field, [])]
                for ln in lines:
                    if 1 <= ln <= len(src_lines):
                        hit = ANNOT_RE.search(src_lines[ln - 1])
                        if hit:
                            return hit.group(1), ln
                return None, None

            flagged: Set[str] = set()
            for field, sites in sorted(muts.items()):
                all_ctxs: Set[str] = set().union(*[s[0] for s in sites])
                if len(all_ctxs) < 2:
                    continue
                common = set.intersection(*[set(s[1]) for s in sites])
                if common:
                    continue
                flagged.add(field)
                marker, _mline = annotation(field)
                if marker is None:
                    first = min(sites, key=lambda s: s[2])
                    ctx_txt = ", ".join(sorted(all_ctxs))
                    where = sorted({f"{m}:{ln}" for _c, _l, ln, m in sites})
                    violations.append(Violation(
                        "ownership", fname, first[2],
                        f"unguarded:{cls.name}.{field}",
                        f"{cls.name}.{field} is mutated from contexts "
                        f"[{ctx_txt}] with no common lock "
                        f"(sites: {', '.join(where)}) — guard every "
                        f"mutation with one lock or annotate the field "
                        f"`# bbcheck: shared=<lock>`"))
                elif marker != "gil" and marker not in info.lock_fields:
                    violations.append(Violation(
                        "ownership", fname, _mline or 0,
                        f"bad-annotation:{cls.name}.{field}",
                        f'{cls.name}.{field} is annotated shared={marker} '
                        f"but {cls.name} owns no lock attribute named "
                        f'"{marker}" (or use shared=gil)'))

            # stale markers: annotation on a field the pass would not flag
            if src_lines is not None:
                candidates = set(info.decl_lines) | set(muts)
                for field in sorted(candidates - flagged):
                    marker, mline = annotation(field)
                    if marker is not None:
                        violations.append(Violation(
                            "ownership", fname, mline or 0,
                            f"stale-annotation:{cls.name}.{field}",
                            f"{cls.name}.{field} carries a "
                            f"`bbcheck: shared={marker}` marker but is not "
                            f"multi-context-mutated (fixed? remove the "
                            f"marker)"))
    return violations
