"""Rule 5: no hardcoded timeout/interval literals in core call sites.

Flags positive numeric constants appearing as:
- a ``timeout=`` keyword argument to any call (including inside an IfExp
  arm, e.g. ``recv(timeout=0.0 if busy else 0.02)``);
- the first positional argument of ``time.sleep(...)``;
- the first positional argument of ``<x>.wait(...)`` (event/condition).

Zero is allowed (non-blocking poll, not a tunable). Function-signature
defaults and dataclass field defaults are intentionally not flagged —
that is exactly where a tunable belongs (``BBConfig``, ``StageConfig``,
ctor kwargs); the rule pushes call sites to route through them.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List

from .report import Violation


def _positive_consts(node: ast.AST) -> Iterable[ast.Constant]:
    """Positive numeric constants inside a (possibly conditional) expr."""
    if isinstance(node, ast.Constant):
        if isinstance(node.value, (int, float)) \
                and not isinstance(node.value, bool) and node.value > 0:
            yield node
    elif isinstance(node, ast.IfExp):
        yield from _positive_consts(node.body)
        yield from _positive_consts(node.orelse)


def check(trees: Dict[str, ast.Module]) -> List[Violation]:
    violations: List[Violation] = []
    for fname, tree in trees.items():
        if fname == "locktrack.py":
            continue
        for call in ast.walk(tree):
            if not isinstance(call, ast.Call):
                continue
            hits: List[ast.Constant] = []
            what = ""
            for kw in call.keywords:
                if kw.arg == "timeout":
                    hits.extend(_positive_consts(kw.value))
                    what = "timeout="
            fn = call.func
            if isinstance(fn, ast.Attribute) and call.args:
                if fn.attr == "sleep" and isinstance(fn.value, ast.Name) \
                        and fn.value.id == "time":
                    hits.extend(_positive_consts(call.args[0]))
                    what = "time.sleep"
                elif fn.attr == "wait":
                    hits.extend(_positive_consts(call.args[0]))
                    what = ".wait"
            target = ast.unparse(call.func)
            for c in hits:
                violations.append(Violation(
                    "literals", fname, call.lineno,
                    f"{what}:{target}:{c.value}",
                    f"hardcoded interval {c.value} in {target}(...) — "
                    f"route through BBConfig / a ctor parameter"))
    return violations
