"""Rule 1: protocol completeness.

Cross-checks every transport ``send``/``request``/``request_async``/``reply``
call site against the ``_on_<kind>`` handler tables of the dispatcher
classes (manager/server) and the compare-style dispatch the client uses:

- a non-reply kind sent toward a role with no handler there (the silent
  black-hole: today a typo'd kind just times out);
- a dead ``_on_<kind>`` handler that nothing in the codebase sends;
- a payload key a handler requires (``msg.payload["k"]``) that no send
  site for that kind constructs.

Replies are exempt from the needs-handler check (they are consumed by the
blocking ``request`` waiter or the async sink, not dispatched), but they
do count as senders for the dead-handler check. Destination expressions
are resolved to roles {manager, server, client} heuristically from the
dst text plus the enclosing for-loop iterable; ``msg.src`` destinations
mean "whoever sent this" and are satisfied by any role handling the kind.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from .report import Violation

SEND_ATTRS = {"send", "request", "request_async", "reply"}
SKIP_MODULES = {"transport.py", "locktrack.py"}

ROLE_OF_MODULE = {"client.py": "client", "filesystem.py": "client",
                  "system.py": "client", "manager.py": "manager",
                  "server.py": "server"}

# kinds broadcast to mixed destination lists the text heuristic can't split
KIND_DEST_OVERRIDES = {"ring": {"server", "client"},
                       "ring_update": {"server", "client"}}

SERVER_DST_HINTS = ("server", "ring", "owner", "peer", "nxt", "pred", "succ",
                    "suspect", "target", "primary", "replica")


class SendSite:
    def __init__(self, file: str, line: int, kind: str, roles: Set[str],
                 is_reply: bool, payload_keys: Optional[Set[str]]):
        self.file = file
        self.line = line
        self.kind = kind
        self.roles = roles            # destination roles, may contain "*"
        self.is_reply = is_reply
        self.payload_keys = payload_keys   # None = unresolvable payload expr


def _attach_parents(tree: ast.AST):
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child._bb_parent = node   # type: ignore[attr-defined]


def _enclosing(node: ast.AST, *types) -> Optional[ast.AST]:
    cur = getattr(node, "_bb_parent", None)
    while cur is not None:
        if isinstance(cur, types):
            return cur
        cur = getattr(cur, "_bb_parent", None)
    return None


def _arg(call: ast.Call, pos: int, kw: str) -> Optional[ast.AST]:
    if len(call.args) > pos:
        return call.args[pos]
    for k in call.keywords:
        if k.arg == kw:
            return k.value
    return None


def _const_str(node: Optional[ast.AST]) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def _dict_keys(node: ast.AST) -> Optional[Set[str]]:
    """Key set of a fully-literal dict expression, else None."""
    if not isinstance(node, ast.Dict):
        return None
    keys: Set[str] = set()
    for k in node.keys:
        if k is None:                       # ** expansion: unresolvable
            return None
        if not (isinstance(k, ast.Constant) and isinstance(k.value, str)):
            return None
        keys.add(k.value)
    return keys


def _resolve_payload_keys(node: Optional[ast.AST]) -> Optional[Set[str]]:
    if node is None:
        return set()                        # payload defaults to None
    direct = _dict_keys(node)
    if direct is not None:
        return direct
    if isinstance(node, ast.Name):          # single local dict-literal alias
        fn = _enclosing(node, ast.FunctionDef, ast.AsyncFunctionDef,
                        ast.Lambda)
        if fn is None or isinstance(fn, ast.Lambda):
            return None
        assigns = [a for a in ast.walk(fn)
                   if isinstance(a, ast.Assign)
                   and any(isinstance(t, ast.Name) and t.id == node.id
                           for t in a.targets)]
        if len(assigns) == 1:
            return _dict_keys(assigns[0].value)
    return None


def _dst_roles(call: ast.Call, attr: str, kind: str) -> Set[str]:
    if kind in KIND_DEST_OVERRIDES:
        return set(KIND_DEST_OVERRIDES[kind])
    if attr == "reply":                     # goes back to msg.src
        return {"*"}
    dst = _arg(call, 1, "dst")
    if dst is None:
        return {"server"}
    text = ast.unparse(dst)
    if isinstance(dst, ast.Name):
        loop = _enclosing(call, ast.For)
        while loop is not None:
            tgt = ast.unparse(loop.target)
            if dst.id in tgt.replace(",", " ").split():
                text += " " + ast.unparse(loop.iter)
                break
            loop = _enclosing(loop, ast.For)
    roles: Set[str] = set()
    low = text.lower()
    if ".src" in low:
        return {"*"}
    if "manager" in low:
        roles.add("manager")
    if "client" in low:
        roles.add("client")
    if any(h in low for h in SERVER_DST_HINTS):
        roles.add("server")
    return roles or {"server"}


def _collect_wrappers(trees: Dict[str, ast.Module]) -> Dict[str, Tuple[int, int, Set[str]]]:
    """Functions that forward a parameter as the transport kind argument.

    Returns {func_name: (kind_pos, payload_pos, dst_roles)} with positions
    as seen by the caller (i.e. with a leading ``self`` already dropped).
    """
    out: Dict[str, Tuple[int, int, Set[str]]] = {}
    for tree in trees.values():
        for fn in ast.walk(tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            params = [a.arg for a in fn.args.args]
            shift = 1 if params and params[0] == "self" else 0
            for call in ast.walk(fn):
                if not (isinstance(call, ast.Call)
                        and isinstance(call.func, ast.Attribute)
                        and call.func.attr in SEND_ATTRS
                        and "transport" in ast.unparse(call.func.value)):
                    continue
                kind = _arg(call, 2, "kind")
                if not (isinstance(kind, ast.Name) and kind.id in params):
                    continue
                payload = _arg(call, 3, "payload")
                if not (isinstance(payload, ast.Name)
                        and payload.id in params):
                    continue
                out[fn.name] = (params.index(kind.id) - shift,
                                params.index(payload.id) - shift,
                                _dst_roles(call, call.func.attr, ""))
    return out


def _collect_sites(trees: Dict[str, ast.Module]) -> List[SendSite]:
    wrappers = _collect_wrappers(trees)
    sites: List[SendSite] = []
    for fname, tree in trees.items():
        for call in ast.walk(tree):
            if not (isinstance(call, ast.Call)
                    and isinstance(call.func, ast.Attribute)):
                continue
            attr = call.func.attr
            if attr in SEND_ATTRS \
                    and "transport" in ast.unparse(call.func.value):
                kind = _const_str(_arg(call, 2, "kind"))
                if kind is None:
                    continue                # wrapper-internal, handled below
                is_reply = attr == "reply" or any(
                    k.arg == "reply_to" for k in call.keywords)
                sites.append(SendSite(
                    fname, call.lineno, kind,
                    _dst_roles(call, attr, kind), is_reply,
                    _resolve_payload_keys(_arg(call, 3, "payload"))))
            elif attr in wrappers:
                kpos, ppos, roles = wrappers[attr]
                kind = _const_str(_arg(call, kpos, "kind"))
                if kind is None:
                    continue
                sites.append(SendSite(
                    fname, call.lineno, kind, set(roles), False,
                    _resolve_payload_keys(_arg(call, ppos, "payload"))))
    return sites


def _is_dispatcher(cls: ast.ClassDef) -> bool:
    for node in ast.walk(cls):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id == "getattr":
            for a in node.args:
                if isinstance(a, ast.JoinedStr) and any(
                        isinstance(v, ast.Constant) and "_on_" in str(v.value)
                        for v in a.values):
                    return True
    return False


def _class_role(cls: ast.ClassDef, fname: str) -> str:
    for marker, role in (("Manager", "manager"), ("Server", "server"),
                         ("Client", "client")):
        if marker in cls.name:
            return role
    return ROLE_OF_MODULE.get(fname, "server")


def _handler_keys(fn: ast.FunctionDef) -> Tuple[Set[str], int]:
    """Required payload keys (subscript reads) of a ``_on_*`` handler.

    Only reads of the handler's own message parameter count — other
    messages in scope (e.g. an original request stashed in pending state)
    were constructed elsewhere and are checked at their own kind.
    """
    params = [a.arg for a in fn.args.args]
    msg_param = params[1] if len(params) > 1 else None
    aliases = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) \
                and isinstance(node.value, ast.Attribute) \
                and node.value.attr == "payload" \
                and isinstance(node.value.value, ast.Name) \
                and node.value.value.id == msg_param \
                and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            aliases.add(node.targets[0].id)
    required: Set[str] = set()
    line = fn.lineno
    for node in ast.walk(fn):
        if isinstance(node, ast.Subscript) \
                and isinstance(node.ctx, ast.Load):
            base = node.value
            is_payload = (isinstance(base, ast.Attribute)
                          and base.attr == "payload"
                          and isinstance(base.value, ast.Name)
                          and base.value.id == msg_param) \
                or (isinstance(base, ast.Name) and base.id in aliases)
            key = _const_str(node.slice)
            if is_payload and key is not None:
                required.add(key)
    return required, line


def _compare_handled(trees: Dict[str, ast.Module]) -> Dict[str, Set[str]]:
    """Kinds consumed via ``x.kind == "lit"`` / ``x.kind in (...)``."""
    out: Dict[str, Set[str]] = {}
    for fname, tree in trees.items():
        role = ROLE_OF_MODULE.get(fname)
        if role is None:
            continue
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Compare)
                    and isinstance(node.left, ast.Attribute)
                    and node.left.attr == "kind"):
                continue
            for cmp in node.comparators:
                if isinstance(cmp, ast.Constant) \
                        and isinstance(cmp.value, str):
                    out.setdefault(role, set()).add(cmp.value)
                elif isinstance(cmp, (ast.Tuple, ast.List, ast.Set)):
                    for el in cmp.elts:
                        s = _const_str(el)
                        if s is not None:
                            out.setdefault(role, set()).add(s)
    return out


def check(trees: Dict[str, ast.Module]) -> List[Violation]:
    trees = {f: t for f, t in trees.items() if f not in SKIP_MODULES}
    for tree in trees.values():
        _attach_parents(tree)

    sites = _collect_sites(trees)
    compare_handled = _compare_handled(trees)

    # role -> {kind: (required payload keys, def line, file)}
    handlers: Dict[str, Dict[str, Tuple[Set[str], int, str]]] = {}
    for fname, tree in trees.items():
        for cls in ast.walk(tree):
            if not (isinstance(cls, ast.ClassDef) and _is_dispatcher(cls)):
                continue
            role = _class_role(cls, fname)
            table = handlers.setdefault(role, {})
            for fn in cls.body:
                if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and fn.name.startswith("_on_"):
                    keys, line = _handler_keys(fn)
                    table[fn.name[4:]] = (keys, line, fname)

    violations: List[Violation] = []

    # (a) sent kinds with no handler at any resolved destination role
    for site in sites:
        if site.is_reply:
            continue
        roles = site.roles
        all_roles = set(handlers) | set(compare_handled)
        targets = sorted(all_roles) if "*" in roles else sorted(roles)
        handled_somewhere = any(
            site.kind in handlers.get(r, ()) or
            site.kind in compare_handled.get(r, ())
            for r in targets)
        if "*" in roles:
            if not handled_somewhere:
                violations.append(Violation(
                    "protocol", site.file, site.line,
                    f"unhandled:{site.kind}",
                    f'kind "{site.kind}" sent to msg.src but no role '
                    f"handles it"))
            continue
        for r in targets:
            if site.kind not in handlers.get(r, ()) \
                    and site.kind not in compare_handled.get(r, ()):
                violations.append(Violation(
                    "protocol", site.file, site.line,
                    f"unhandled:{site.kind}:{r}",
                    f'kind "{site.kind}" sent toward {r} which has no '
                    f"handler for it (silent black-hole)"))

    # (b) handlers nothing sends (replies count as senders here)
    sent_kinds = {s.kind for s in sites}
    for role, table in handlers.items():
        for kind, (_keys, line, fname) in table.items():
            if kind not in sent_kinds:
                violations.append(Violation(
                    "protocol", fname, line, f"dead-handler:{role}:{kind}",
                    f"_on_{kind} on {role} is dead: nothing sends "
                    f'"{kind}"'))

    # (c) payload keys a handler requires that no send site constructs
    by_kind: Dict[str, List[SendSite]] = {}
    for s in sites:
        by_kind.setdefault(s.kind, []).append(s)
    for role, table in handlers.items():
        for kind, (keys, line, fname) in table.items():
            ksites = by_kind.get(kind, [])
            if not ksites or any(s.payload_keys is None for s in ksites):
                continue                    # some payload unresolvable: skip
            constructed: Set[str] = set()
            for s in ksites:
                constructed |= s.payload_keys or set()
            for key in sorted(keys - constructed):
                violations.append(Violation(
                    "protocol", fname, line,
                    f"missing-key:{role}:{kind}:{key}",
                    f'_on_{kind} on {role} reads payload["{key}"] but no '
                    f'send site for "{kind}" constructs it'))

    return violations
