"""bbcheck: AST-based invariant checks for the burst-buffer core.

Eight rules, each a module exposing ``check(trees) -> [Violation]`` where
``trees`` maps a display filename to a parsed ``ast.Module``:

- protocol  -- message kinds sent vs. ``_on_<kind>`` handlers, payload keys
- locks     -- lexical lock-acquisition graph must be acyclic
- blocking  -- no recv/request/queue.get(timeout>0)/sleep under a held lock
- clocks    -- no direct time.time()/time.monotonic() outside the
               injected-clock guard pattern
- literals  -- no hardcoded timeout/interval floats; route through BBConfig
- schema    -- senders and handlers agree on payload shape (key sets +
               coarse value types); also generates docs/PROTOCOL.md
- epochs    -- epoch-table lifecycles: begin-reachable creation, an
               abort/timeout delete path (no zombies), idempotent aborts,
               disjoint drain/stage/user epoch-id spaces
- ownership -- no field mutated from two execution contexts (run loop,
               ACK pump, fan-out workers, API callers) without one
               consistent lock; ``# bbcheck: shared=<lock>`` markers are
               verified and must not go stale

Run ``python -m tools.bbcheck`` (see __main__.py) or ``scripts/ci.sh --lint``.
The committed allowlist (allowlist.json) is shrinking-only: unknown
violations fail, and so do stale allowlist entries.
"""
from . import (blocking, clocks, epochs, literals, locks, ownership,  # noqa: F401
               protocol, schema)
from .report import Violation, load_allowlist, apply_allowlist  # noqa: F401

ALL_RULES = (protocol, locks, blocking, clocks, literals,
             schema, epochs, ownership)
