"""bbcheck: AST-based invariant checks for the burst-buffer core.

Five rules, each a module exposing ``check(trees) -> [Violation]`` where
``trees`` maps a display filename to a parsed ``ast.Module``:

- protocol  -- message kinds sent vs. ``_on_<kind>`` handlers, payload keys
- locks     -- lexical lock-acquisition graph must be acyclic
- blocking  -- no recv/request/queue.get(timeout>0)/sleep under a held lock
- clocks    -- no direct time.time()/time.monotonic() outside the
               injected-clock guard pattern
- literals  -- no hardcoded timeout/interval floats; route through BBConfig

Run ``python -m tools.bbcheck`` (see __main__.py) or ``scripts/ci.sh --lint``.
The committed allowlist (allowlist.json) is shrinking-only: unknown
violations fail, and so do stale allowlist entries.
"""
from . import blocking, clocks, literals, locks, protocol  # noqa: F401
from .report import Violation, load_allowlist, apply_allowlist  # noqa: F401

ALL_RULES = (protocol, locks, blocking, clocks, literals)
