"""CLI: ``python -m tools.bbcheck [root] [--allowlist PATH]``.

Exit status is non-zero if any rule reports a violation not covered by
the allowlist, OR if the allowlist contains stale entries (so the list
can only ever shrink).
"""
from __future__ import annotations

import argparse
import ast
import os
import sys

from . import ALL_RULES
from .report import apply_allowlist, load_allowlist

DEFAULT_ROOT = "src/repro/core"
DEFAULT_ALLOWLIST = os.path.join(os.path.dirname(__file__), "allowlist.json")


def parse_tree(root: str):
    trees = {}
    for name in sorted(os.listdir(root)):
        if not name.endswith(".py"):
            continue
        path = os.path.join(root, name)
        with open(path) as fh:
            trees[name] = ast.parse(fh.read(), filename=path)
    return trees


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="bbcheck")
    ap.add_argument("root", nargs="?", default=DEFAULT_ROOT)
    ap.add_argument("--allowlist", default=DEFAULT_ALLOWLIST)
    args = ap.parse_args(argv)

    trees = parse_tree(args.root)
    violations = []
    for rule in ALL_RULES:
        violations.extend(rule.check(trees))
    violations.sort(key=lambda v: (v.file, v.line, v.rule))

    allow = load_allowlist(args.allowlist)
    new, allowed, stale = apply_allowlist(violations, allow)

    for v in new:
        print(f"FAIL {v}")
    for v in allowed:
        print(f"allow {v}")
    for key in stale:
        print(f"STALE allowlist entry (fixed? remove it): {key}")

    n_mod = len(trees)
    print(f"bbcheck: {n_mod} modules, {len(new)} new violation(s), "
          f"{len(allowed)} allowlisted, {len(stale)} stale entries")
    return 1 if (new or stale) else 0


if __name__ == "__main__":
    sys.exit(main())
