"""CLI: ``python -m tools.bbcheck [root] [options]``.

Exit status is non-zero if any rule reports a violation not covered by
the allowlist, OR if the allowlist contains stale entries (so the list
can only ever shrink), OR if ``--check-protocol`` finds the committed
``docs/PROTOCOL.md`` drifted from the code.

Options:
  --rule NAME            run only this rule (repeatable; default: all)
  --json [PATH]          machine-readable report to PATH ("-" = stdout)
  --emit-protocol PATH   (re)generate the inferred protocol registry
  --check-protocol PATH  fail if PATH differs from the regenerated registry
  --emit-metrics PATH    (re)generate the instrument-catalog markdown
  --check-metrics PATH   fail if PATH differs from telemetry.CATALOG
"""
from __future__ import annotations

import argparse
import ast
import json
import os
import sys

from . import ALL_RULES
from . import metrics as metrics_doc
from . import schema as schema_rule
from .report import apply_allowlist, load_allowlist

DEFAULT_ROOT = "src/repro/core"
DEFAULT_ALLOWLIST = os.path.join(os.path.dirname(__file__), "allowlist.json")
RULE_NAMES = {r.__name__.rsplit(".", 1)[-1]: r for r in ALL_RULES}


def parse_tree(root: str):
    trees = {}
    for name in sorted(os.listdir(root)):
        if not name.endswith(".py"):
            continue
        path = os.path.join(root, name)
        with open(path) as fh:
            src = fh.read()
        tree = ast.parse(src, filename=path)
        # rules that need comments (ownership's shared= markers) read the
        # raw source off the tree; fixture trees may omit it
        tree._bb_source = src               # type: ignore[attr-defined]
        trees[name] = tree
    return trees


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="bbcheck")
    ap.add_argument("root", nargs="?", default=DEFAULT_ROOT)
    ap.add_argument("--allowlist", default=DEFAULT_ALLOWLIST)
    ap.add_argument("--rule", action="append", choices=sorted(RULE_NAMES),
                    help="run only this rule (repeatable)")
    ap.add_argument("--json", nargs="?", const="-", default=None,
                    metavar="PATH", help="machine-readable report")
    ap.add_argument("--emit-protocol", metavar="PATH",
                    help="write the inferred protocol registry markdown")
    ap.add_argument("--check-protocol", metavar="PATH",
                    help="fail if PATH drifted from the inferred registry")
    ap.add_argument("--emit-metrics", metavar="PATH",
                    help="write the instrument-catalog markdown")
    ap.add_argument("--check-metrics", metavar="PATH",
                    help="fail if PATH drifted from telemetry.CATALOG")
    args = ap.parse_args(argv)

    trees = parse_tree(args.root)
    rules = [RULE_NAMES[n] for n in args.rule] if args.rule \
        else list(ALL_RULES)
    violations = []
    for rule in rules:
        violations.extend(rule.check(trees))
    violations.sort(key=lambda v: (v.file, v.line, v.rule))

    allow = load_allowlist(args.allowlist)
    new, allowed, stale = apply_allowlist(violations, allow)

    for v in new:
        print(f"FAIL {v}")
    for v in allowed:
        print(f"allow {v}")
    for key in stale:
        print(f"STALE allowlist entry (fixed? remove it): {key}")

    drifted = False
    registry = None
    if args.emit_protocol or args.check_protocol:
        registry = schema_rule.render(trees)
    if args.emit_protocol:
        with open(args.emit_protocol, "w") as fh:
            fh.write(registry)
        print(f"bbcheck: wrote {args.emit_protocol}")
    if args.check_protocol:
        try:
            with open(args.check_protocol) as fh:
                committed = fh.read()
        except FileNotFoundError:
            committed = None
        if committed != registry:
            drifted = True
            print(f"DRIFT {args.check_protocol} is stale — regenerate with "
                  f"`python -m tools.bbcheck --emit-protocol "
                  f"{args.check_protocol}`")

    if args.emit_metrics or args.check_metrics:
        metrics_md = metrics_doc.render()
        if args.emit_metrics:
            with open(args.emit_metrics, "w") as fh:
                fh.write(metrics_md)
            print(f"bbcheck: wrote {args.emit_metrics}")
        if args.check_metrics:
            try:
                with open(args.check_metrics) as fh:
                    committed_md = fh.read()
            except FileNotFoundError:
                committed_md = None
            if committed_md != metrics_md:
                drifted = True
                print(f"DRIFT {args.check_metrics} is stale — regenerate "
                      f"with `python -m tools.bbcheck --emit-metrics "
                      f"{args.check_metrics}`")

    n_mod = len(trees)
    rule_names = [r.__name__.rsplit(".", 1)[-1] for r in rules]
    print(f"bbcheck: {n_mod} modules, {len(rules)} rules, "
          f"{len(new)} new violation(s), "
          f"{len(allowed)} allowlisted, {len(stale)} stale entries")

    if args.json is not None:
        def vdict(v):
            return {"rule": v.rule, "file": v.file, "line": v.line,
                    "ident": v.ident, "key": v.key, "message": v.message}
        report = {"root": args.root, "modules": n_mod, "rules": rule_names,
                  "new": [vdict(v) for v in new],
                  "allowed": [vdict(v) for v in allowed],
                  "stale_allowlist": stale,
                  "protocol_drift": drifted,
                  "ok": not (new or stale or drifted)}
        payload = json.dumps(report, indent=2, sort_keys=True)
        if args.json == "-":
            print(payload)
        else:
            with open(args.json, "w") as fh:
                fh.write(payload + "\n")
            print(f"bbcheck: report at {args.json}")

    return 1 if (new or stale or drifted) else 0


if __name__ == "__main__":
    sys.exit(main())
