"""Rule 2 (static half): lexical lock-acquisition graph must be acyclic.

Walks every function, tracking a stack of ``with <lock-ish expr>`` blocks.
A lock-ish expression is an attribute or name whose final component
contains "lock" or "mutex" (``self._lock``, ``src_ep._lock``, ``op.lock``).
Lock names are canonicalised to ``Class.attr`` where possible so that
``self._lock`` inside BBClient and inside LogStore become distinct nodes.
Nested with-blocks add directed edges outer -> inner; any cycle (including
a same-name self edge, which is unordered same-class nesting) is flagged.

The runtime half lives in ``src/repro/core/locktrack.py`` and catches
orders this lexical scan cannot see (lock taken in a callee).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from .report import Violation

# variable-name -> owning class, for locks reached through a non-self base
TYPE_HINTS = {"src_ep": "Endpoint", "ep": "Endpoint", "dst_ep": "Endpoint"}


def _lock_name(expr: ast.AST, cls: Optional[str]) -> Optional[str]:
    """Canonical lock node name for a with-item expression, else None."""
    if isinstance(expr, ast.Attribute):
        leaf = expr.attr
        if "lock" not in leaf.lower() and "mutex" not in leaf.lower():
            return None
        base = expr.value
        if isinstance(base, ast.Name):
            if base.id == "self":
                return f"{cls or '?'}.{leaf}"
            owner = TYPE_HINTS.get(base.id, base.id)
            return f"{owner}.{leaf}"
        return f"{ast.unparse(base)}.{leaf}"
    if isinstance(expr, ast.Name):
        if "lock" in expr.id.lower() or "mutex" in expr.id.lower():
            return expr.id
        return None
    return None


def walk_with_stacks(fn: ast.AST, cls: Optional[str]):
    """Yield (node, held) for every statement/expr in ``fn``, where
    ``held`` is the ordered tuple of lock names lexically held there.
    Nested function/lambda bodies are not entered (they run elsewhere)."""

    def visit(node: ast.AST, held: Tuple[str, ...]):
        yield node, held
        if isinstance(node, ast.With):
            inner = held
            for item in node.items:
                name = _lock_name(item.context_expr, cls)
                if name is not None:
                    inner = inner + (name,)
                else:
                    yield item.context_expr, held
            for stmt in node.body:
                yield from visit(stmt, inner)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)) and node is not fn:
            return
        for child in ast.iter_child_nodes(node):
            yield from visit(child, held)

    yield from visit(fn, ())


def iter_functions(tree: ast.Module):
    """Yield (function node, enclosing class name or None)."""
    def scan(node: ast.AST, cls: Optional[str]):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                yield from scan(child, child.name)
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield child, cls
                yield from scan(child, cls)
            else:
                yield from scan(child, cls)
    yield from scan(tree, None)


def check(trees: Dict[str, ast.Module]) -> List[Violation]:
    # edge -> first site observed
    edges: Dict[Tuple[str, str], Tuple[str, int]] = {}
    violations: List[Violation] = []

    for fname, tree in trees.items():
        for fn, cls in iter_functions(tree):
            for node, held in walk_with_stacks(fn, cls):
                if not (isinstance(node, ast.With) and len(held) >= 1):
                    continue
                names = [_lock_name(i.context_expr, cls)
                         for i in node.items]
                for inner in filter(None, names):
                    for outer in held:
                        if outer == inner:
                            violations.append(Violation(
                                "locks", fname, node.lineno,
                                f"self-nest:{inner}",
                                f"{inner} lexically nested inside itself "
                                f"(unordered same-class nesting)"))
                            continue
                        edges.setdefault((outer, inner),
                                         (fname, node.lineno))

    adj: Dict[str, Set[str]] = {}
    for outer, inner in edges:
        adj.setdefault(outer, set()).add(inner)

    def reachable(src: str, dst: str) -> bool:
        seen: Set[str] = set()
        stack = [src]
        while stack:
            n = stack.pop()
            if n == dst:
                return True
            if n in seen:
                continue
            seen.add(n)
            stack.extend(adj.get(n, ()))
        return False

    for (outer, inner), (fname, line) in sorted(edges.items(),
                                                key=lambda kv: kv[1]):
        if reachable(inner, outer):
            violations.append(Violation(
                "locks", fname, line, f"cycle:{outer}->{inner}",
                f"lock-order cycle: {outer} -> {inner} here, but a "
                f"{inner} -> ... -> {outer} path exists elsewhere"))

    return violations
