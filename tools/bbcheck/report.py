"""Violation records + shrinking-only allowlist handling."""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Tuple


@dataclass(frozen=True)
class Violation:
    rule: str          # "protocol" | "locks" | "blocking" | "clocks" | "literals"
    file: str
    line: int
    ident: str         # stable identity, line-number-free (allowlist key)
    message: str

    @property
    def key(self) -> str:
        return f"{self.rule}:{self.file}:{self.ident}"

    def __str__(self) -> str:
        return f"{self.file}:{self.line}: [{self.rule}] {self.message}"


def load_allowlist(path: str) -> List[str]:
    try:
        with open(path) as fh:
            data = json.load(fh)
    except FileNotFoundError:
        return []
    if not isinstance(data, list) or not all(isinstance(x, str) for x in data):
        raise ValueError(f"{path}: allowlist must be a JSON list of strings")
    return data


def apply_allowlist(violations: List[Violation], allow: List[str],
                    ) -> Tuple[List[Violation], List[Violation], List[str]]:
    """Split into (new, allowed, stale-allowlist-entries)."""
    allowset = set(allow)
    new = [v for v in violations if v.key not in allowset]
    allowed = [v for v in violations if v.key in allowset]
    hit = {v.key for v in allowed}
    stale = sorted(allowset - hit)
    return new, allowed, stale
