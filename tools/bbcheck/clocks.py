"""Rule 4: clock injection.

Direct ``time.time()`` / ``time.monotonic()`` calls are banned in core
modules. Two sanctioned shapes remain:

- the injected-clock guard drain.py/qos.py pioneered::

      now = time.monotonic() if now is None else now

  (recognised as a call inside an IfExp whose test is ``<x> is None``);

- bare attribute references, e.g. a constructor default
  ``clock: Callable[[], float] = time.monotonic`` — not calls at all.

Everything else must go through the entity's injected ``self._clock`` so
tests can drive time deterministically. The committed allowlist is
shrinking-only; the goal state (and current state) is empty.
"""
from __future__ import annotations

import ast
from typing import Dict, List

from .report import Violation

BANNED = {"time", "monotonic"}


def _in_none_guard(node: ast.AST) -> bool:
    cur = getattr(node, "_bb_parent", None)
    while cur is not None:
        if isinstance(cur, ast.IfExp) \
                and isinstance(cur.test, ast.Compare) \
                and len(cur.test.ops) == 1 \
                and isinstance(cur.test.ops[0], (ast.Is, ast.IsNot)) \
                and isinstance(cur.test.comparators[0], ast.Constant) \
                and cur.test.comparators[0].value is None:
            return True
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return False
        cur = getattr(cur, "_bb_parent", None)
    return False


def check(trees: Dict[str, ast.Module]) -> List[Violation]:
    violations: List[Violation] = []
    for fname, tree in trees.items():
        if fname == "locktrack.py":
            continue
        for node in ast.walk(tree):
            for child in ast.iter_child_nodes(node):
                child._bb_parent = node   # type: ignore[attr-defined]
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in BANNED
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id == "time"):
                continue
            if _in_none_guard(node):
                continue
            fn = getattr(node, "_bb_parent", None)
            while fn is not None and not isinstance(
                    fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                fn = getattr(fn, "_bb_parent", None)
            where = fn.name if fn is not None else "<module>"
            violations.append(Violation(
                "clocks", fname, node.lineno,
                f"time.{node.func.attr}:{where}",
                f"direct time.{node.func.attr}() — inject a clock "
                f"(self._clock) or use the `x if now is None` guard"))
    return violations
