"""Rule 6: payload schema inference.

Rule 1 (protocol) checks that every sent kind has a handler and that
required payload keys exist *somewhere*; this rule checks that senders and
handlers agree on the payload *shape*. At every ``send`` / ``request`` /
``request_async`` / ``reply`` site the payload dict literal is resolved to
a per-kind schema — key set plus a coarse value type (int / str / bytes /
list / dict / None; anything dynamic is ``?`` and never conflicts). At
every ``_on_<kind>`` handler the ``msg.payload[...]`` subscripts and
``msg.payload.get(...)`` calls are collected. Three things are flagged:

- a handler read of a key no send site for that kind constructs (a typo'd
  field name — today it would raise KeyError or silently return None).
  Keys *injected* into an existing payload after construction — subscript
  assignment (``it["_stale"] = True`` marking parked puts stale) or an
  extension literal (``{**p, "chain": rest}`` re-forwarding down the
  replica chain) — travel the wire without appearing in any from-scratch
  payload literal and are exempted from this check (a genuinely typo'd
  read matches no assignment anywhere, so the check still bites);
- a *required* read (``payload["k"]``) of a key some send site omits —
  ``.get`` with a default is the sanctioned escape for optional fields;
- cross-site type conflicts: two send sites giving the same key of the
  same kind different concrete coarse types (``None`` marks a nullable
  field and does not conflict).

Kinds whose payload is not a dict literal (or a single local dict-literal
alias) at even one site are skipped entirely — no guessing.

``render(trees)`` emits the inferred registry as ``docs/PROTOCOL.md``
(see ``--emit-protocol`` / ``--check-protocol`` in ``__main__``); CI
fails when the committed doc drifts from the code.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from .report import Violation
from .protocol import (SEND_ATTRS, SKIP_MODULES, _arg, _attach_parents,
                       _class_role, _collect_wrappers, _const_str,
                       _dst_roles, _enclosing)

# builtin calls whose coarse result type is knowable without inference
_CALL_TYPES = {"len": "int", "int": "int", "sum": "int", "min": "int",
               "max": "int", "bool": "int", "float": "int", "abs": "int",
               "str": "str", "repr": "str", "bytes": "bytes",
               "sorted": "list", "list": "list", "tuple": "list",
               "set": "list", "dict": "dict"}


def _coarse_type(node: ast.AST) -> str:
    """Coarse value type of a payload dict value expression."""
    if isinstance(node, ast.Constant):
        v = node.value
        if v is None:
            return "None"
        if isinstance(v, (bool, int, float)):
            return "int"
        if isinstance(v, str):
            return "str"
        if isinstance(v, bytes):
            return "bytes"
        return "?"
    if isinstance(node, (ast.List, ast.Tuple, ast.Set, ast.ListComp,
                         ast.SetComp, ast.GeneratorExp)):
        return "list"
    if isinstance(node, (ast.Dict, ast.DictComp)):
        return "dict"
    if isinstance(node, ast.JoinedStr):
        return "str"
    if isinstance(node, ast.UnaryOp):
        return _coarse_type(node.operand)
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        return _CALL_TYPES.get(node.func.id, "?")
    if isinstance(node, ast.IfExp):
        a, b = _coarse_type(node.body), _coarse_type(node.orelse)
        if a == b:
            return a
        if "None" in (a, b):                # nullable field: base type wins
            return a if b == "None" else b
        return "?"
    return "?"


def _dict_schema(node: ast.AST) -> Optional[Dict[str, str]]:
    """{key: coarse type} of a fully-literal-keyed dict expr, else None."""
    if not isinstance(node, ast.Dict):
        return None
    out: Dict[str, str] = {}
    for k, v in zip(node.keys, node.values):
        if k is None:                       # ** expansion: unresolvable
            return None
        if not (isinstance(k, ast.Constant) and isinstance(k.value, str)):
            return None
        out[k.value] = _coarse_type(v)
    return out


def _resolve_payload_schema(node: Optional[ast.AST]) -> Optional[Dict[str, str]]:
    if node is None:
        return {}                           # payload defaults to None
    direct = _dict_schema(node)
    if direct is not None:
        return direct
    if isinstance(node, ast.Name):          # single local dict-literal alias
        fn = _enclosing(node, ast.FunctionDef, ast.AsyncFunctionDef,
                        ast.Lambda)
        if fn is None or isinstance(fn, ast.Lambda):
            return None
        assigns = [a for a in ast.walk(fn)
                   if isinstance(a, ast.Assign)
                   and any(isinstance(t, ast.Name) and t.id == node.id
                           for t in a.targets)]
        if len(assigns) == 1:
            return _dict_schema(assigns[0].value)
    return None


class SchemaSite:
    __slots__ = ("file", "line", "kind", "roles", "is_reply", "schema")

    def __init__(self, file: str, line: int, kind: str, roles: Set[str],
                 is_reply: bool, schema: Optional[Dict[str, str]]):
        self.file = file
        self.line = line
        self.kind = kind
        self.roles = roles
        self.is_reply = is_reply
        self.schema = schema                # None = unresolvable payload


def _collect_schema_sites(trees: Dict[str, ast.Module]) -> List[SchemaSite]:
    wrappers = _collect_wrappers(trees)
    sites: List[SchemaSite] = []
    for fname, tree in trees.items():
        for call in ast.walk(tree):
            if not (isinstance(call, ast.Call)
                    and isinstance(call.func, ast.Attribute)):
                continue
            attr = call.func.attr
            if attr in SEND_ATTRS \
                    and "transport" in ast.unparse(call.func.value):
                kind = _const_str(_arg(call, 2, "kind"))
                if kind is None:
                    continue                # wrapper-internal, handled below
                is_reply = attr == "reply" or any(
                    k.arg == "reply_to" for k in call.keywords)
                sites.append(SchemaSite(
                    fname, call.lineno, kind,
                    _dst_roles(call, attr, kind), is_reply,
                    _resolve_payload_schema(_arg(call, 3, "payload"))))
            elif attr in wrappers:
                kpos, ppos, roles = wrappers[attr]
                kind = _const_str(_arg(call, kpos, "kind"))
                if kind is None:
                    continue
                sites.append(SchemaSite(
                    fname, call.lineno, kind, set(roles), False,
                    _resolve_payload_schema(_arg(call, ppos, "payload"))))
    return sites


def _handler_accesses(fn: ast.FunctionDef) -> Dict[str, dict]:
    """{key: {"required", "get", "default", "line"}} for a ``_on_*``
    handler's reads of its own message's payload (aliases included)."""
    params = [a.arg for a in fn.args.args]
    msg_param = params[1] if len(params) > 1 else None
    aliases: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) \
                and isinstance(node.value, ast.Attribute) \
                and node.value.attr == "payload" \
                and isinstance(node.value.value, ast.Name) \
                and node.value.value.id == msg_param \
                and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            aliases.add(node.targets[0].id)

    def is_payload(base: ast.AST) -> bool:
        return (isinstance(base, ast.Attribute)
                and base.attr == "payload"
                and isinstance(base.value, ast.Name)
                and base.value.id == msg_param) \
            or (isinstance(base, ast.Name) and base.id in aliases)

    out: Dict[str, dict] = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Subscript) \
                and isinstance(node.ctx, ast.Load) \
                and is_payload(node.value):
            key = _const_str(node.slice)
            if key is not None:
                acc = out.setdefault(key, {"required": False, "get": False,
                                           "default": False,
                                           "line": node.lineno})
                acc["required"] = True
        elif isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr == "get" \
                and is_payload(node.func.value):
            key = _const_str(node.args[0]) if node.args else None
            if key is not None:
                acc = out.setdefault(key, {"required": False, "get": False,
                                           "default": False,
                                           "line": node.lineno})
                acc["get"] = True
                if len(node.args) > 1 or node.keywords:
                    acc["default"] = True
    return out


def _injected_keys(trees: Dict[str, ast.Module]) -> Set[str]:
    """String keys added to an already-built dict anywhere in the scanned
    modules: ``x["k"] = v`` subscript assignment, or a dict extension
    literal ``{**base, "k": v}``. Used only to *suppress* typo findings —
    never widens a schema."""
    # telemetry.TRACE_KEY: Transport injects the trace context into dict
    # payloads at send time (telemetry.trace_inject), and transport.py is a
    # SKIP_MODULE — declare it here so a handler going through msg_span /
    # trace_from never trips the typo check on the piggybacked key.
    keys: Set[str] = {"_trace"}
    for tree in trees.values():
        for node in ast.walk(tree):
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Subscript):
                        k = _const_str(tgt.slice)
                        if k is not None:
                            keys.add(k)
            elif isinstance(node, ast.Dict) and None in node.keys:
                for k in node.keys:
                    if isinstance(k, ast.Constant) \
                            and isinstance(k.value, str):
                        keys.add(k.value)
    return keys


def _collect_handlers(trees: Dict[str, ast.Module]):
    """[(role, kind, fname, line, accesses)] for every ``_on_*`` method."""
    handlers = []
    for fname, tree in sorted(trees.items()):
        for cls in ast.walk(tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            role = _class_role(cls, fname)
            for fn in cls.body:
                if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and fn.name.startswith("_on_"):
                    handlers.append((role, fn.name[4:], fname, fn.lineno,
                                     _handler_accesses(fn)))
    return handlers


def _prep(trees: Dict[str, ast.Module]):
    trees = {f: t for f, t in trees.items() if f not in SKIP_MODULES}
    for tree in trees.values():
        _attach_parents(tree)
    sites = _collect_schema_sites(trees)
    by_kind: Dict[str, List[SchemaSite]] = {}
    for s in sites:
        by_kind.setdefault(s.kind, []).append(s)
    for ksites in by_kind.values():
        ksites.sort(key=lambda s: (s.file, s.line))
    return by_kind, _collect_handlers(trees), _injected_keys(trees)


def check(trees: Dict[str, ast.Module]) -> List[Violation]:
    by_kind, handlers, injected = _prep(trees)
    violations: List[Violation] = []

    for role, kind, fname, _hline, accesses in handlers:
        ksites = by_kind.get(kind, [])
        if not ksites or any(s.schema is None for s in ksites):
            continue                        # no data / unresolvable payload
        keysets = [set(s.schema) for s in ksites]
        constructed = set().union(*keysets)
        always = set.intersection(*keysets)
        for key, acc in sorted(accesses.items()):
            if key not in constructed and key not in injected:
                violations.append(Violation(
                    "schema", fname, acc["line"],
                    f"typo:{role}:{kind}:{key}",
                    f'_on_{kind} on {role} reads payload key "{key}" which '
                    f'no send site for "{kind}" constructs (typo?)'))
            elif acc["required"] and key not in always:
                n_omit = sum(1 for s in ksites if key not in s.schema)
                violations.append(Violation(
                    "schema", fname, acc["line"],
                    f"optional:{role}:{kind}:{key}",
                    f'_on_{kind} on {role} requires payload["{key}"] but '
                    f'{n_omit} of {len(ksites)} send site(s) omit it — '
                    f'use .get with a default'))

    for kind, ksites in sorted(by_kind.items()):
        if any(s.schema is None for s in ksites):
            continue
        types_by_key: Dict[str, Set[str]] = {}
        first_site: Dict[Tuple[str, str], Tuple[str, int]] = {}
        for s in ksites:
            for k, t in s.schema.items():
                types_by_key.setdefault(k, set()).add(t)
                first_site.setdefault((k, t), (s.file, s.line))
        for k, ts in sorted(types_by_key.items()):
            concrete = sorted(ts - {"?", "None"})
            if len(concrete) >= 2:
                f, line = first_site[(k, concrete[0])]
                violations.append(Violation(
                    "schema", f, line, f"type:{kind}:{k}",
                    f'payload key "{k}" of kind "{kind}" has conflicting '
                    f'types across send sites: {"/".join(concrete)}'))
    return violations


# ------------------------------------------------------- PROTOCOL.md
def _access_cell(acc: Optional[dict]) -> str:
    if acc is None:
        return "—"
    parts = []
    if acc["required"]:
        parts.append("required")
    if acc["get"]:
        parts.append(".get(default)" if acc["default"] else ".get")
    return " + ".join(parts) if parts else "—"


def render(trees: Dict[str, ast.Module]) -> str:
    """Deterministic markdown registry of the inferred wire protocol."""
    by_kind, handlers, injected = _prep(trees)
    handlers_by_kind: Dict[str, list] = {}
    for role, kind, fname, line, accesses in handlers:
        handlers_by_kind.setdefault(kind, []).append((role, fname, accesses))
    n_sites = sum(len(v) for v in by_kind.values())

    out: List[str] = [
        "# Burst-buffer message protocol",
        "",
        "<!-- GENERATED by `python -m tools.bbcheck --emit-protocol"
        " docs/PROTOCOL.md` -->",
        "<!-- Do not edit by hand: `scripts/ci.sh --lint` fails when this"
        " file drifts from the code. -->",
        "",
        f"Inferred from `src/repro/core`: **{len(by_kind)} message kinds** "
        f"across {n_sites} send/request/reply sites. Coarse value types: "
        "`int` / `str` / `bytes` / `list` / `dict`; `None` marks a "
        "nullable field, `?` a dynamic expression the checker does not "
        "type. *required* means the handler subscripts the key "
        "(`payload[k]`); `.get` reads tolerate absence.",
        "",
    ]
    for kind in sorted(by_kind):
        ksites = by_kind[kind]
        out.append(f"## `{kind}`")
        out.append("")
        by_file: Dict[str, int] = {}
        for s in ksites:
            by_file[s.file] = by_file.get(s.file, 0) + 1
        senders = ", ".join(f"`{f}` ×{n}" if n > 1 else f"`{f}`"
                            for f, n in sorted(by_file.items()))
        roles = sorted(set().union(*[s.roles for s in ksites]))
        roles_txt = ", ".join("reply-to-sender" if r == "*" else r
                              for r in roles)
        reply_note = " (reply)" if all(s.is_reply for s in ksites) else ""
        out.append(f"- sent from: {senders} — toward {roles_txt}{reply_note}")
        hs = sorted(handlers_by_kind.get(kind, []))
        if hs:
            htxt = ", ".join(f"{role} `_on_{kind}` (`{fname}`)"
                             for role, fname, _a in hs)
            out.append(f"- handled by: {htxt}")
        elif all(s.is_reply for s in ksites):
            out.append("- handled by: request waiters / async reply sinks")
        unresolved = sum(1 for s in ksites if s.schema is None)
        resolved = [s for s in ksites if s.schema is not None]
        if unresolved:
            out.append(f"- payload: dynamic expression at {unresolved} "
                       f"site(s) — not inferred")
        if resolved:
            keysets = [set(s.schema) for s in resolved]
            always = set.intersection(*keysets)
            allkeys = sorted(set().union(*keysets))
            if allkeys:
                out.append("")
                header = "| key | type | sent by |"
                sep = "|---|---|---|"
                acc_cols = [f"{role} access" for role, _f, _a in hs]
                header += "".join(f" {c} |" for c in acc_cols)
                sep += "---|" * len(acc_cols)
                out.append(header)
                out.append(sep)
                for k in allkeys:
                    ts = sorted({s.schema[k] for s in resolved
                                 if k in s.schema})
                    sent = "all sites" if k in always else \
                        f"{sum(1 for s in resolved if k in s.schema)}" \
                        f"/{len(resolved)} sites"
                    row = f"| `{k}` | {'/'.join(ts)} | {sent} |"
                    for _role, _f, accesses in hs:
                        row += f" {_access_cell(accesses.get(k))} |"
                    out.append(row)
            elif not unresolved:
                out.append("- payload: none")
            accessed = set()
            for _role, _f, accesses in hs:
                accessed |= set(accesses)
            extra = sorted(k for k in accessed - set().union(*keysets)
                           if k in injected)
            if extra:
                out.append("")
                out.append("- in-flight keys (injected into queued or "
                           "re-forwarded payloads after construction): "
                           + ", ".join(f"`{k}`" for k in extra))
        out.append("")
    return "\n".join(out).rstrip() + "\n"
