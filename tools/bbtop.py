"""bbtop: live cluster health dashboard (ISSUE 10).

Renders the health engine's verdict stream — overall status, SLO rule
verdicts with their offending numbers, stall-watchdog anomalies,
per-server occupancy / lane-queue depth, and the top critical-path
bottleneck — either from a saved JSON document or live from a --demo
system. The machine mode (``--once --json``) prints one frame as JSON to
stdout for scripting, carrying the engine's verdicts verbatim.

Accepted input documents: a ``BurstBufferSystem.health()`` report, a
``pressure()`` report (which embeds one under ``"health"``), or a frame
``{"health": ..., "pressure": ...}`` as emitted by ``--json``.

Usage:
  python -m tools.bbtop HEALTH.json             render one frame and exit
  python -m tools.bbtop HEALTH.json --json      machine-readable frame
  python -m tools.bbtop HEALTH.json --watch 2   re-read + re-render loop
  python -m tools.bbtop --demo --watch 1        live demo system dashboard

Exit code 4 when the frame's overall status is ``critical`` (scriptable
alerting), 0 otherwise.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_MARK = {"ok": " ok ", "warn": "WARN", "critical": "CRIT",
         "disabled": "off ", "unknown": " ?? "}


def _import_repro():
    try:
        from repro.core import telemetry     # noqa: F401
    except ImportError:
        src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
        sys.path.insert(0, os.path.abspath(src))


def _fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        if v and abs(v) < 0.1:
            return f"{v * 1e3:.2f}m"
        return f"{v:.3g}"
    return str(v)


def as_frame(doc: dict) -> dict:
    """Normalize any accepted input document into a frame."""
    if "health" in doc:                     # frame or pressure report
        return {"health": doc["health"],
                "pressure": doc.get("pressure",
                                    doc if "servers" in doc else None)}
    if "slos" in doc:                       # bare health report
        return {"health": doc, "pressure": None}
    raise ValueError("not a health/pressure/frame document "
                     "(expected a 'health' or 'slos' key)")


def render(frame: dict, out=None):
    w = (out or sys.stdout).write       # resolved late: capture-friendly
    h = frame.get("health") or {}
    status = h.get("status", "unknown")
    w(f"bbtop  status={status.upper():<9} evals={h.get('evals', 0)}"
      f"  t={_fmt(h.get('t'))}\n")
    w("slo rules:\n")
    for s in h.get("slos", []):
        label = f" [{s['label']}]" if s.get("label") else ""
        w(f"  [{_MARK.get(s['verdict'], s['verdict'])}] "
          f"{s['rule']:<24} value={_fmt(s.get('value')):<10}"
          f" warn={_fmt(s.get('warn'))} crit={_fmt(s.get('critical'))}"
          f"{label}\n")
    wds = h.get("watchdogs", [])
    w(f"watchdogs: {'none firing' if not wds else ''}\n")
    for a in wds:
        who = a.get("server") or a.get("phase") or "-"
        detail = ", ".join(f"{k}={_fmt(v)}" for k, v in sorted(a.items())
                           if k not in ("kind", "verdict"))
        w(f"  [{_MARK.get(a['verdict'], a['verdict'])}] "
          f"{a['kind']:<16} {who}: {detail}\n")
    pressure = frame.get("pressure") or {}
    servers = pressure.get("servers", {})
    if servers:
        w("servers:\n")
        for name, p in sorted(servers.items()):
            occ = p.get("fraction", 0.0)
            bar = "#" * int(occ * 20.0 + 0.5)
            w(f"  {name:<12} occ={occ:6.1%} [{bar:<20}]"
              f" draining={'y' if p.get('draining') else 'n'}\n")
    top = (h.get("bottlenecks") or {}).get("top")
    ops = (h.get("bottlenecks") or {}).get("ops", {})
    w(f"bottleneck: {top['summary'] if top else 'no completed traces yet'}"
      "\n")
    for kind, op in sorted(ops.items()):
        segs = " ".join(
            f"{seg}={op['segments'][seg]['share']:.0%}"
            for seg in ("queue", "service", "fsync", "network")
            if seg in op.get("segments", {}))
        w(f"  {kind:<24} n={op['count']:<6} p99={_fmt(op['p99_s'])}s"
          f"  {segs}\n")


def _demo_start():
    """Small live system under a little traffic, telemetry on."""
    _import_repro()
    from repro.core import telemetry
    from repro.core.system import BBConfig, BurstBufferSystem

    telemetry.enable()
    cfg = BBConfig(num_servers=3, num_clients=2, dram_capacity=8 << 20)
    system = BurstBufferSystem(cfg)
    system.start()
    fs = system.fs()
    with telemetry.span("bbtop.demo", "app"):
        f = fs.open("demo/data", "w", policy="batched", lane="checkpoint")
        chunk = os.urandom(64 << 10)
        for i in range(64):
            f.pwrite(chunk, i * len(chunk))
        f.close()
    system.flush(1)
    return system


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="bbtop", description=__doc__)
    ap.add_argument("doc", nargs="?", metavar="HEALTH.json",
                    help="saved health / pressure / frame document")
    ap.add_argument("--demo", action="store_true",
                    help="run a small live system and watch it")
    ap.add_argument("--watch", type=float, metavar="SECS",
                    help="refresh every SECS seconds until interrupted")
    ap.add_argument("--once", action="store_true",
                    help="render exactly one frame (the default without "
                         "--watch)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="print the frame as JSON instead of rendering")
    ap.add_argument("--frames", type=int, metavar="N",
                    help="with --watch: stop after N frames (scripting)")
    args = ap.parse_args(argv)
    if not args.demo and not args.doc:
        ap.error("either HEALTH.json or --demo is required")

    system = _demo_start() if args.demo else None

    def frame() -> dict:
        if system is not None:
            return {"health": system.health(),
                    "pressure": system.pressure()}
        with open(args.doc) as fh:
            return as_frame(json.load(fh))

    status = "unknown"
    try:
        n = 0
        while True:
            f = frame()
            status = (f.get("health") or {}).get("status", "unknown")
            if args.as_json:
                json.dump(f, sys.stdout, indent=2, sort_keys=True,
                          default=repr)
                sys.stdout.write("\n")
            else:
                if args.watch and not args.once:
                    sys.stdout.write("\x1b[2J\x1b[H")   # clear screen
                render(f)
            n += 1
            if args.once or not args.watch \
                    or (args.frames and n >= args.frames):
                break
            time.sleep(args.watch)
    except KeyboardInterrupt:
        pass
    finally:
        if system is not None:
            system.stop()
            from repro.core import telemetry
            telemetry.disable()
    return 4 if status == "critical" else 0


if __name__ == "__main__":
    sys.exit(main())
