"""bbstat: human-readable view of a telemetry scrape (ISSUE 9).

Reads either a saved ``BurstBufferSystem.scrape()`` JSON document or — with
``--demo`` — spins up a small live system with telemetry enabled, pushes a
little traffic through it, and scrapes that. Histograms print count / mean /
max plus an approximate p50/p99 interpolated from the fixed buckets;
counters, gauges and poll snapshots print flat.

Exit code 3 when any configured server is missing from the scrape (the
document's ``expected``/``missing`` fields, ISSUE 10) — a dead server is
skipped by ``transport.alive()``, so without this a partial scrape looks
exactly like a healthy one to CI.

Usage:
  python -m tools.bbstat SCRAPE.json            pretty-print a saved scrape
  python -m tools.bbstat SCRAPE.json --watch 5  re-read + re-print loop
  python -m tools.bbstat --demo                 live demo system, then scrape
  python -m tools.bbstat --demo --watch 1       live demo, periodic re-scrape
  python -m tools.bbstat --demo --trace T.json  also export Chrome trace JSON
  python -m tools.bbstat --demo --json S.json   also save the raw scrape
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _import_repro():
    try:
        from repro.core import telemetry     # noqa: F401
    except ImportError:
        src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
        sys.path.insert(0, os.path.abspath(src))


def _quantile(bounds, buckets, count, q):
    """Approximate quantile from cumulative bucket counts: linear within
    the winning bucket, upper bound for the overflow bucket."""
    target = count * q
    seen = 0
    for i, n in enumerate(buckets):
        if not n:
            continue
        if seen + n >= target:
            if i >= len(bounds):
                return bounds[-1]
            lo = bounds[i - 1] if i else 0.0
            frac = (target - seen) / n
            return lo + (bounds[i] - lo) * frac
        seen += n
    return bounds[-1] if bounds else 0.0


def _fmt_s(v: float) -> str:
    if v >= 1.0:
        return f"{v:.2f}s"
    if v >= 1e-3:
        return f"{v * 1e3:.2f}ms"
    return f"{v * 1e6:.0f}us"


def print_scrape(doc: dict, out=None):
    out = out or sys.stdout             # resolved late: capture-friendly
    reg = doc.get("registry", doc)      # accept a bare registry snapshot
    w = out.write
    for name, series in sorted(reg.get("counters", {}).items()):
        w(f"counter   {name}\n")
        for label, v in sorted(series.items()):
            w(f"  {label or '-':<28} {v:>12.0f}\n")
    for name, series in sorted(reg.get("gauges", {}).items()):
        w(f"gauge     {name}\n")
        for label, v in sorted(series.items()):
            w(f"  {label or '-':<28} {v:>12.4f}\n")
    for name, h in sorted(reg.get("histograms", {}).items()):
        bounds = h.get("bounds", [])
        w(f"histogram {name}\n")
        for label, st in sorted(h.get("series", {}).items()):
            n = st["count"]
            mean = st["sum"] / n if n else 0.0
            p50 = _quantile(bounds, st["buckets"], n, 0.50)
            p99 = _quantile(bounds, st["buckets"], n, 0.99)
            w(f"  {label or '-':<28} n={n:<8d} mean={_fmt_s(mean):<10}"
              f" p50~{_fmt_s(p50):<10} p99~{_fmt_s(p99):<10}"
              f" max={_fmt_s(st['max'])}\n")
    for name, samples in sorted(reg.get("rings", {}).items()):
        w(f"ring      {name}  ({len(samples)} samples)\n")
        by_label: dict = {}
        for t, label, v in samples:
            by_label.setdefault(label, []).append(v)
        for label, vals in sorted(by_label.items()):
            w(f"  {label or '-':<28} last={vals[-1]:.4f}"
              f" min={min(vals):.4f} max={max(vals):.4f}\n")
    for name, by_label in sorted(reg.get("polls", {}).items()):
        w(f"poll      {name}\n")
        for label, snap in sorted(by_label.items()):
            w(f"  {label or '-':<28} {json.dumps(snap, sort_keys=True, default=repr)}\n")
    for server, payload in sorted(doc.get("servers", {}).items()):
        w(f"server    {server}\n")
        stats = payload.get("stats", payload)
        w(f"  {json.dumps(stats, sort_keys=True, default=repr)}\n")


def check_missing(doc: dict, out=None) -> int:
    """Nonzero (3) when any configured server is absent from the scrape.
    Pre-ISSUE-10 documents without membership fields fall back to
    ``expected`` minus the answering set, else pass vacuously."""
    out = out or sys.stdout
    missing = doc.get("missing")
    if missing is None and "expected" in doc:
        missing = sorted(set(doc["expected"]) - set(doc.get("servers", {})))
    if missing:
        out.write(f"bbstat: MISSING servers: {', '.join(missing)}\n")
        return 3
    return 0


def _demo_start():
    """Small live system under real traffic, telemetry on. The system is
    returned running so --watch can re-scrape it; _demo_stop tears down."""
    _import_repro()
    from repro.core import telemetry
    from repro.core.system import BBConfig, BurstBufferSystem

    telemetry.enable()
    cfg = BBConfig(num_servers=3, num_clients=2, dram_capacity=8 << 20)
    system = BurstBufferSystem(cfg)
    system.start()
    fs = system.fs()
    with telemetry.span("bbstat.demo", "app"):
        f = fs.open("demo/data", "w", policy="batched",
                    lane="checkpoint")
        chunk = os.urandom(64 << 10)
        for i in range(64):
            f.pwrite(chunk, i * len(chunk))
        f.close()
    system.flush(1)
    return system


def _demo_stop(system, trace_path=None):
    from repro.core import telemetry
    system.stop()
    if trace_path:
        telemetry.export_chrome(trace_path)
        print(f"bbstat: Chrome trace at {trace_path} "
              f"(open in https://ui.perfetto.dev)")
    telemetry.disable()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="bbstat", description=__doc__)
    ap.add_argument("scrape", nargs="?", metavar="SCRAPE.json",
                    help="saved scrape document to pretty-print")
    ap.add_argument("--demo", action="store_true",
                    help="run a small live system and scrape it")
    ap.add_argument("--watch", type=float, metavar="SECS",
                    help="re-scrape (or re-read the file) every SECS "
                         "seconds until interrupted")
    ap.add_argument("--frames", type=int, metavar="N",
                    help="with --watch: stop after N frames (scripting)")
    ap.add_argument("--trace", metavar="PATH",
                    help="with --demo: export Chrome trace-event JSON")
    ap.add_argument("--json", metavar="PATH",
                    help="also write the raw scrape document to PATH")
    args = ap.parse_args(argv)
    if not args.demo and not args.scrape:
        ap.error("either SCRAPE.json or --demo is required")

    system = _demo_start() if args.demo else None
    rc = 0
    try:
        n = 0
        while True:
            if system is not None:
                doc = system.scrape()
            else:
                with open(args.scrape) as fh:
                    doc = json.load(fh)
            if args.json:
                with open(args.json, "w") as fh:
                    json.dump(doc, fh, indent=2, sort_keys=True,
                              default=repr)
                print(f"bbstat: scrape saved to {args.json}")
            if args.watch:
                sys.stdout.write("\x1b[2J\x1b[H")       # clear screen
            print_scrape(doc)
            # a partial scrape must fail loud, in every mode (ISSUE 10)
            rc = check_missing(doc) or rc
            n += 1
            if not args.watch or (args.frames and n >= args.frames):
                break
            time.sleep(args.watch)
    except KeyboardInterrupt:
        pass
    finally:
        if system is not None:
            _demo_stop(system, args.trace)
    return rc


if __name__ == "__main__":
    sys.exit(main())
