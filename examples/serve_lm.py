"""Batched serving example: prefill + greedy decode over request batches,
with weights restorable from the burst buffer (hot restart path).

  PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-9b
(uses the reduced config on CPU; drop --reduced on real hardware)
"""
import sys

from repro.launch import serve

if __name__ == "__main__":
    if "--reduced" not in sys.argv and "--help" not in sys.argv:
        sys.argv.append("--reduced")
    serve.main()
