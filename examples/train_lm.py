"""End-to-end driver: train a ~100M-param LM with burst-buffer checkpoints.

Default config is a 12L/768d GPT-small-class model (~110M params). On a TPU
pod this runs a few hundred steps in minutes; on this CPU container use
--preset tiny (the same code path at toy scale):

  PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 30
  PYTHONPATH=src python examples/train_lm.py --steps 300      # ~100M model
"""
import argparse
import dataclasses

from repro.configs.base import ModelConfig, get_config, reduced
from repro.launch.train import train_loop


def config_100m() -> ModelConfig:
    base = get_config("starcoder2-3b")
    return dataclasses.replace(
        base, name="lm-110m", d_model=768, num_heads=12, num_kv_heads=4,
        head_dim=64, d_ff=3072, vocab_size=32768,
        segments=((("attn",), 12),),
        param_dtype="float32", compute_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=("100m", "tiny"), default="100m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    if args.preset == "tiny":
        cfg = reduced(config_100m())
        args.seq = min(args.seq, 64)
        args.ckpt_every = min(args.ckpt_every, 10)
    else:
        cfg = config_100m()

    from repro.models.registry import count_params
    print(f"[train_lm] {cfg.name}: {count_params(cfg)/1e6:.0f}M params, "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}")
    state, history, mgr = train_loop(
        cfg, steps=args.steps, global_batch=args.batch, seq_len=args.seq,
        ckpt_every=args.ckpt_every, quantize_ckpt=True, log_every=10)
    print("[train_lm] loss trajectory:",
          [f"{s}:{l:.3f}" for s, l in history])


if __name__ == "__main__":
    main()
