"""Failure + eviction demo: a burst-buffer server dies mid-training AND the
checkpoint is fully evicted to the PFS (what the drain engine does to cold
data); the job stages the checkpoint back into the buffer (`fs.stage`, each
surviving server re-ingesting its own domain in parallel), restores through
a prefetching handle, and continues BIT-EXACTLY as if nothing happened
(compared against an uninterrupted reference run).

  PYTHONPATH=src python examples/restart_demo.py
"""
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.bbckpt import BBCheckpointManager
from repro.configs.base import get_config, reduced
from repro.core import BBConfig, BurstBufferSystem
from repro.data.pipeline import SyntheticLMPipeline
from repro.models.registry import build_model
from repro.runtime.train_step import (TrainState, init_train_state,
                                      make_optimizer, make_train_step)

STEPS, CKPT_AT = 10, 5


def fresh(cfg, model, optimizer, seed=0):
    state = init_train_state(cfg, model, optimizer, jax.random.PRNGKey(seed))
    pipe = SyntheticLMPipeline(vocab_size=cfg.vocab_size, seq_len=32,
                               global_batch=4, seed=42)
    return state, pipe


def _wait_unbuffered(bb, path, timeout=15.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        st = bb.fs().stat(path)
        if st["residency"]["dram"] == 0 and st["residency"]["ssd"] == 0:
            return
        time.sleep(0.05)
    raise RuntimeError(f"{path} still buffered after evict")


def main():
    cfg = reduced(get_config("h2o-danube-1.8b"))
    model = build_model(cfg)
    optimizer = make_optimizer(cfg)
    step_fn = jax.jit(make_train_step(cfg, model, optimizer, accum_steps=1))

    # ---- reference: uninterrupted run ----
    state, pipe = fresh(cfg, model, optimizer)
    for _ in range(STEPS):
        state, _ = step_fn(state, next(pipe))
    ref = state

    # ---- run with failure + full eviction ----
    state, pipe = fresh(cfg, model, optimizer)
    with BurstBufferSystem(BBConfig(num_servers=4, num_clients=4,
                                    dram_capacity=128 << 20,
                                    stabilize_interval=0.1)) as bb:
        mgr = BBCheckpointManager(bb, quantize=False)
        for step in range(CKPT_AT):
            state, _ = step_fn(state, next(pipe))
        fname = f"ckpt_{CKPT_AT:08d}"
        mgr.save(CKPT_AT, {"params": state.params,
                           "opt_state": state.opt_state,
                           "data": {"step": jnp.asarray(pipe.step)}},
                 blocking_flush=True)           # durable on the PFS
        print(f"[demo] checkpoint at step {CKPT_AT} ingested + flushed")

        bb.kill_server("server/0")
        print("[demo] killed server/0 (stabilization + manager broadcast)")
        time.sleep(1.0)
        for c in bb.clients:
            c.put_timeout = 0.8

        # the drain engine's endgame for cold data: every buffered copy
        # tombstoned, bytes only on the PFS
        bb.evict(fname)
        _wait_unbuffered(bb, fname)
        st = bb.fs().stat(fname)
        print(f"[demo] checkpoint fully evicted: residency={st['residency']}")

        # stage-in: one manager-coordinated bulk load; each surviving
        # server re-ingests its own lookup-table domain in parallel
        staged = bb.fs().stage(fname)
        st = bb.fs().stat(fname)
        print(f"[demo] fs.stage({fname!r}) -> {staged}, "
              f"stage_stats={bb.manager.stage_stats}, "
              f"residency={st['residency']}")

        print("[demo] simulating job crash: discarding training state")
        state2, pipe2 = fresh(cfg, model, optimizer, seed=123)   # wrong seed!
        target = {"params": state2.params, "opt_state": state2.opt_state,
                  "data": {"step": jnp.asarray(0)}}
        # restore() stages (cheap no-op here — already staged) and reads
        # through a prefetching handle with parallel fan-out
        restored, ck = mgr.restore(target)
        print(f"[demo] restored step {ck} from staged burst-buffer chunks")
        state2 = TrainState(restored["params"], restored["opt_state"])
        pipe2.load_state_dict({"step": int(restored["data"]["step"]),
                               "seed": 42, "shard_id": 0, "num_shards": 1})
        for _ in range(STEPS - CKPT_AT):
            state2, _ = step_fn(state2, next(pipe2))

    same = all(bool(jnp.array_equal(a, b)) for a, b in zip(
        jax.tree.leaves(state2.params), jax.tree.leaves(ref.params)))
    print(f"[demo] continuation bit-exact vs uninterrupted run: {same}")
    assert same


if __name__ == "__main__":
    main()
