"""Quickstart: train a small LM with burst-buffer checkpointing, then serve.

Checkpoints ride the BBFileSystem file-session API: ``bb.fs()`` opens
striped file handles over the burst buffer, every write returns a BBFuture,
and ``sync()``/``close()`` are the ingest barriers (failures raise there —
no error lists to poll). BBCheckpointManager uses the same handles
internally.

Runs on CPU in about a minute:
  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.checkpoint.bbckpt import BBCheckpointManager
from repro.configs.base import get_config, reduced
from repro.core import BBConfig, BurstBufferSystem
from repro.data.pipeline import SyntheticLMPipeline
from repro.models.registry import build_model
from repro.runtime.serve_step import greedy_token
from repro.runtime.train_step import (init_train_state, make_optimizer,
                                      make_train_step)


def main():
    cfg = reduced(get_config("gemma3-4b"), d_model=128, vocab=512)
    model = build_model(cfg)
    optimizer = make_optimizer(cfg, peak_lr=1e-3)
    state = init_train_state(cfg, model, optimizer, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(cfg, model, optimizer, accum_steps=1))
    pipe = SyntheticLMPipeline(vocab_size=cfg.vocab_size, seq_len=64,
                               global_batch=8).start_prefetch()

    print(f"== training {cfg.name} ({cfg.num_layers} layers, "
          f"d={cfg.d_model}) with async burst-buffer checkpoints ==")
    with BurstBufferSystem(BBConfig(num_servers=4, num_clients=4,
                                    dram_capacity=128 << 20)) as bb:
        mgr = BBCheckpointManager(bb, quantize=True)
        for step in range(20):
            state, metrics = step_fn(state, next(pipe))
            if step % 5 == 4:
                ckpt = {"params": state.params,
                        "opt_state": state.opt_state,
                        "data": {"step": jnp.asarray(pipe.step)}}
                dt = mgr.save(step, ckpt)
                print(f"step {step:3d} loss {float(metrics['loss']):.4f}  "
                      f"[ckpt ingest {dt * 1e3:.0f} ms, flush async]")
            else:
                print(f"step {step:3d} loss {float(metrics['loss']):.4f}")
        mgr.wait_flushes()
        print("checkpoint timings:", {k: f"{v['ingest_s']*1e3:.0f}ms ingest/"
                                         f"{v.get('flush_s', 0)*1e3:.0f}ms flush"
                                      for k, v in sorted(mgr.metrics.items())})

        # control-plane view (ISSUE 5): where the latest checkpoint's bytes
        # physically sit, and the cluster pressure the QoS engine acts on
        fs = bb.fs()
        last = max(mgr.metrics)
        st = fs.stat(f"ckpt_{last:08d}")
        print(f"ckpt_{last:08d} residency:",
              {t: f"{n/1e6:.1f} MB" for t, n in st["residency"].items()},
              f"({st['evicted_chunks']} chunks evicted to PFS)")
        pr = bb.pressure()
        q = pr["qos"]
        print("cluster pressure:",
              f"occupancy max {q['max_occupancy']:.2f} / "
              f"mean {q['mean_occupancy']:.2f},",
              f"ingest {q['aggregate_ingest_bps']/1e6:.0f} MB/s,",
              f"{q['draining']} draining;",
              f"drain epochs {pr['drain']['epochs']}"
              f" ({pr['drain']['drained_bytes']/1e6:.1f} MB drained),",
              f"stage epochs {pr['stage']['epochs']}")

        # the same file-session API, used directly: write a run manifest
        # next to the checkpoints and read it back through the buffer
        with fs.open("run_info.txt", "w", policy="batched") as f:
            f.write(f"arch={cfg.name} steps=20 ckpts="
                    f"{sorted(mgr.metrics)}\n".encode())
        with fs.open("run_info.txt", "r") as f:
            print("run manifest (via burst buffer):",
                  f.read().decode().strip())
        print("buffered files:", fs.listdir())

    print("== greedy decode from the trained model ==")
    cache = model.init_cache(2, 96)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 1,
                                 cfg.vocab_size)
    logits, cache = model.prefill(state.params, cache, prompts)
    tok = greedy_token(cfg, logits)
    out = [tok]
    for i in range(8):
        logits, cache = model.decode_step(state.params, cache, tok,
                                          jnp.asarray(16 + i, jnp.int32))
        tok = greedy_token(cfg, logits)
        out.append(tok)
    gen = jnp.concatenate(out, axis=1)
    print("generated tokens:", gen.tolist())


if __name__ == "__main__":
    main()
