"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.mlstm import mlstm_pallas
from repro.kernels.quantize import (dequantize_blockwise_pallas,
                                    quantize_blockwise_pallas)
from repro.kernels.rg_lru import rg_lru_pallas

RNG = np.random.default_rng(7)


def _rand(shape, dtype):
    return jnp.asarray(RNG.normal(size=shape), dtype)


# ---------------------------------------------------------------- attention

ATTN_CASES = [
    # (B, Sq, Sk, H, KV, D, causal, window, softcap, dtype, tol)
    (1, 128, 128, 4, 4, 64, True, 0, 0.0, jnp.float32, 2e-5),
    (2, 96, 96, 4, 2, 32, True, 0, 0.0, jnp.float32, 2e-5),
    (1, 128, 128, 8, 2, 64, True, 48, 0.0, jnp.float32, 2e-5),
    (1, 64, 64, 2, 1, 128, False, 0, 0.0, jnp.float32, 2e-5),
    (1, 128, 128, 4, 4, 64, True, 0, 20.0, jnp.float32, 2e-5),
    (1, 128, 128, 4, 2, 64, True, 0, 0.0, jnp.bfloat16, 3e-2),
    (2, 80, 80, 4, 4, 48, True, 0, 0.0, jnp.float32, 2e-5),  # ragged seq
]


@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_attention_vs_oracle(case):
    b, sq, sk, h, kv, d, causal, window, cap, dtype, tol = case
    q = _rand((b, sq, h, d), dtype)
    k = _rand((b, sk, kv, d), dtype)
    v = _rand((b, sk, kv, d), dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 softcap=cap, block_q=64, block_k=64,
                                 interpret=True)
    exp = ref.flash_attention(q, k, v, causal=causal, window=window,
                              softcap=cap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("case", ATTN_CASES[:4])
def test_chunked_jnp_flash_vs_oracle(case):
    b, sq, sk, h, kv, d, causal, window, cap, dtype, tol = case
    q = _rand((b, sq, h, d), dtype)
    k = _rand((b, sk, kv, d), dtype)
    v = _rand((b, sk, kv, d), dtype)
    out = ops._flash_chunked_jnp(q, k, v, causal=causal, window=window,
                                 softcap=cap, q_offset=0, chunk=48)
    exp = ref.flash_attention(q, k, v, causal=causal, window=window,
                              softcap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=tol, rtol=tol)


def test_flash_attention_q_offset_decode_consistency():
    """Chunked attention over a prefix + offset q equals full attention."""
    b, s, h, d = 1, 96, 2, 32
    q = _rand((b, s, h, d), jnp.float32)
    k = _rand((b, s, h, d), jnp.float32)
    v = _rand((b, s, h, d), jnp.float32)
    full = ref.flash_attention(q, k, v, causal=True)
    tail = ops._flash_chunked_jnp(q[:, -16:], k, v, causal=True, window=0,
                                  softcap=0.0, q_offset=s - 16, chunk=32)
    np.testing.assert_allclose(np.asarray(tail), np.asarray(full[:, -16:]),
                               atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------------- rg_lru

@pytest.mark.parametrize("shape,blocks", [
    ((2, 256, 128), (128, 128)),
    ((1, 512, 256), (256, 128)),
    ((3, 128, 384), (64, 128)),
])
def test_rg_lru_vs_oracle(shape, blocks):
    b, s, d = shape
    bs, bd = blocks
    a = jnp.asarray(RNG.uniform(0.7, 0.999, shape), jnp.float32)
    gx = _rand(shape, jnp.float32) * 0.1
    h0 = _rand((b, d), jnp.float32) * 0.1
    hp, hl = rg_lru_pallas(a, gx, h0, block_s=bs, block_d=bd, interpret=True)
    hr, hlr = ref.rg_lru(a, gx, h0)
    np.testing.assert_allclose(np.asarray(hp), np.asarray(hr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(hlr), atol=1e-5)


def test_rg_lru_assoc_scan_matches():
    b, s, d = 2, 300, 64
    a = jnp.asarray(RNG.uniform(0.7, 0.999, (b, s, d)), jnp.float32)
    gx = _rand((b, s, d), jnp.float32) * 0.1
    ha, hla = ops._rg_lru_assoc(a, gx, None)
    hr, hlr = ref.rg_lru(a, gx, None)
    np.testing.assert_allclose(np.asarray(ha), np.asarray(hr), atol=1e-5)


# -------------------------------------------------------------------- mlstm

@pytest.mark.parametrize("shape,chunk", [
    ((1, 128, 2, 32), 64),
    ((2, 256, 1, 64), 128),
    ((1, 192, 4, 16), 64),
])
def test_mlstm_vs_oracle(shape, chunk):
    b, s, h, d = shape
    q = _rand(shape, jnp.float32)
    k = _rand(shape, jnp.float32)
    v = _rand(shape, jnp.float32)
    lf = jnp.asarray(np.log(RNG.uniform(0.85, 0.999, (b, s, h))), jnp.float32)
    li = _rand((b, s, h), jnp.float32) * 0.5
    hp, (Cp, np_, mp) = mlstm_pallas(q, k, v, lf, li, chunk=chunk,
                                     interpret=True)
    hr, (Cr, nr, mr) = ref.mlstm(q, k, v, lf, li)
    np.testing.assert_allclose(np.asarray(hp), np.asarray(hr),
                               atol=5e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(Cp), np.asarray(Cr),
                               atol=5e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(mp), np.asarray(mr), atol=1e-5)


def test_mlstm_stateful_decode_matches_full():
    """Running the ref cell over a split sequence with carried state equals
    one full pass (the decode path contract)."""
    b, s, h, d = 1, 64, 2, 16
    q = _rand((b, s, h, d), jnp.float32)
    k = _rand((b, s, h, d), jnp.float32)
    v = _rand((b, s, h, d), jnp.float32)
    lf = jnp.asarray(np.log(RNG.uniform(0.9, 0.999, (b, s, h))), jnp.float32)
    li = _rand((b, s, h), jnp.float32) * 0.5
    full, _ = ref.mlstm(q, k, v, lf, li)
    cut = 40
    h1, st = ref.mlstm(q[:, :cut], k[:, :cut], v[:, :cut],
                       lf[:, :cut], li[:, :cut])
    h2, _ = ref.mlstm(q[:, cut:], k[:, cut:], v[:, cut:],
                      lf[:, cut:], li[:, cut:], *st)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], 1)),
                               np.asarray(full), atol=1e-4, rtol=1e-4)


# ----------------------------------------------------------------- quantize

@given(st.integers(1, 6), st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_quantize_roundtrip_error_bound(nblocks, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 3.0, nblocks * 2048), jnp.float32)
    q, s = quantize_blockwise_pallas(x, interpret=True)
    qr, sr = ref.quantize_blockwise(x)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    xd = dequantize_blockwise_pallas(q, s, interpret=True)
    # error bounded by half a quantization step per block, with f32
    # round-trip slack: |x/s| reaches 127, so x/s, round, *s accumulates
    # ~127*eps_f32 of relative error on top of the half-step
    err = np.abs(np.asarray(xd) - np.asarray(x)).reshape(nblocks, 2048)
    bound = np.asarray(s)[:, None] * (0.5 + 1e-4) + 1e-7
    assert (err <= bound).all()


def test_quantize_preserves_zero_and_extremes():
    x = jnp.asarray([0.0] * 2047 + [12.5], jnp.float32)
    q, s = ref.quantize_blockwise(x)
    xd = ref.dequantize_blockwise(q, s)
    assert float(xd[-1]) == pytest.approx(12.5, rel=1e-2)
    np.testing.assert_allclose(np.asarray(xd[:-1]), 0.0, atol=1e-7)
