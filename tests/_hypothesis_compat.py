"""Optional-hypothesis shim: property tests skip when hypothesis is absent.

Test modules import ``given``, ``settings`` and ``st`` from here instead of
hard-importing hypothesis (which is not part of the baked container image).
With hypothesis installed this re-exports the real API unchanged. Without
it, module-level strategy construction still works (``st.<anything>``
returns an inert stand-in) and ``@given`` replaces the test with a skip —
so every non-property test in the same file keeps running and the module
always collects cleanly.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Inert stand-in accepted anywhere a strategy (or @st.composite
        function) appears; any call or attribute access returns itself."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    class _Strategies:
        def __getattr__(self, name):
            return _Strategy()

    st = _Strategies()

    def given(*args, **kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped():
                pass
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            _skipped.__module__ = fn.__module__
            return _skipped
        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco
