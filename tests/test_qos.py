"""Traffic-aware QoS engine (ISSUE 5): classifier, priority lanes,
congestion windows, the unified background-bandwidth arbiter, and the
write-through bypass — including the satellite fault-injection case: a
``policy="through"`` stream must read back byte-exact while concurrent
bursty writers fill the buffer, and after a server kill."""
import threading
import time

import pytest

from repro.core import (BandwidthArbiter, BBConfig, BurstBufferSystem,
                        CongestionWindows, DrainConfig, DrainEngine,
                        LaneQueue, QoSConfig)
from repro.core import qos


def _pattern(offset: int, length: int) -> bytes:
    return bytes(((offset >> 4) + i) % 251 for i in range(length))


# ------------------------------------------------------------- lane naming

def test_lane_index_names_and_bounds():
    assert qos.lane_index("checkpoint") == qos.LANE_CHECKPOINT
    assert qos.lane_index("background") == qos.LANE_BACKGROUND
    assert qos.lane_index(3) == qos.LANE_DRAIN
    with pytest.raises(ValueError):
        qos.lane_index("vip")
    with pytest.raises(ValueError):
        qos.lane_index(7)


# ------------------------------------------------------- traffic classifier

def _clf_cfg(**kw):
    base = dict(window_s=1.0, bursty_bytes_per_s=1000, seq_min_run=3,
                classify_min_bytes=500, idle_s=5.0)
    base.update(kw)
    return QoSConfig(**base)


def test_classifier_bursty_until_proven_boring():
    clf = qos.TrafficClassifier(_clf_cfg(), now=0.0)
    assert clf.classify(now=0.0) == qos.IDLE       # nothing observed yet
    clf.observe(0, 100, now=1.0)
    # in-order but neither enough bytes nor a long enough run
    assert clf.classify(now=1.0) == qos.BURSTY


def test_classifier_rate_keeps_stream_bursty():
    clf = qos.TrafficClassifier(_clf_cfg(), now=0.0)
    for i in range(10):                            # 2000 B/s, in order
        clf.observe(i * 200, 200, now=1.0 + i * 0.1)
    assert clf.rate(now=2.0) >= 1000
    assert clf.classify(now=2.0) == qos.BURSTY     # fast => buffer it


def test_classifier_sequential_after_evidence_and_seek_resets():
    clf = qos.TrafficClassifier(_clf_cfg(), now=0.0)
    for i in range(4):                             # 200 B/s, in order
        clf.observe(i * 200, 200, now=1.0 + i)
    assert clf.classify(now=4.0) == qos.SEQUENTIAL
    clf.observe(10_000, 200, now=5.0)              # seek breaks the run
    assert clf.classify(now=5.0) == qos.BURSTY
    clf.observe(10_200, 200, now=6.0)
    clf.observe(10_400, 200, now=7.0)
    assert clf.classify(now=7.0) == qos.SEQUENTIAL


def test_classifier_idle_after_silence():
    clf = qos.TrafficClassifier(_clf_cfg(idle_s=2.0), now=0.0)
    clf.observe(0, 100, now=1.0)
    assert clf.classify(now=1.5) == qos.BURSTY
    assert clf.classify(now=4.0) == qos.IDLE


# --------------------------------------------------------------- lane queue

def test_lane_queue_priority_and_fifo_within_lane():
    q = LaneQueue(weights=(8, 4, 2, 1), quantum=1024)
    q.push(qos.LANE_BACKGROUND, "bg1", 100)
    q.push(qos.LANE_BACKGROUND, "bg2", 100)
    q.push(qos.LANE_CHECKPOINT, "ck1", 100)
    assert q.pop() == "ck1"                        # priority first
    assert q.pop() == "bg1"                        # FIFO within a lane
    assert q.pop() == "bg2"
    assert q.pop() is None
    assert len(q) == 0


def test_lane_queue_weighted_shares_under_backlog():
    q = LaneQueue(weights=(8, 4, 2, 1), quantum=256)
    for i in range(400):
        for lane in range(4):
            q.push(lane, (lane, i), 100)
    counts = [0, 0, 0, 0]
    for _ in range(800):
        lane, _i = q.pop()
        counts[lane] += 1
    assert counts[0] > counts[1] > counts[2] > counts[3] > 0
    assert counts[0] >= 3 * counts[3]


def test_lane_queue_veto_skips_lane_and_big_item_cannot_wedge():
    q = LaneQueue(weights=(8, 4, 2, 1), quantum=256)
    q.push(qos.LANE_CHECKPOINT, "ck", 100)
    q.push(qos.LANE_BACKGROUND, "big", 1 << 20)    # >> quantum * weight
    assert q.pop(lambda lane, nb: lane != qos.LANE_CHECKPOINT) == "big"
    assert q.pop() == "ck"
    # a single huge entry on the lowest lane must pop on the first call
    q.push(qos.LANE_DRAIN, "huge", 64 << 20)
    assert q.pop() == "huge"


def test_lane_queue_discard():
    q = LaneQueue()
    for i in range(6):
        q.push(i % 2, f"item{i}", 10)
    removed = q.discard(lambda it: it in ("item2", "item5"))
    assert removed == 2
    assert len(q) == 4
    assert "item2" not in q.entries() and "item5" not in q.entries()


# ------------------------------------------------------- congestion windows

def test_congestion_windows_shrink_background_first():
    cfg = QoSConfig(window_bytes=(64 << 20, 16 << 20, 4 << 20, 4 << 20),
                    window_floor=1 << 10, low_occupancy=0.5,
                    high_occupancy=0.9)
    w = CongestionWindows(cfg)
    w.on_pressure(0.0)
    full = [w.window(lane) for lane in range(4)]
    assert full == [64 << 20, 16 << 20, 4 << 20, 4 << 20]
    for _ in range(50):                            # EWMA converges to 0.7
        w.on_pressure(0.7)
    mid = [w.window(lane) for lane in range(4)]
    assert mid[0] == full[0]                       # checkpoint never shrinks
    assert mid[1] < full[1]
    # deeper lanes shrink by a strictly larger factor
    assert mid[2] / full[2] < mid[1] / full[1]
    assert mid[3] / full[3] < mid[2] / full[2]
    for _ in range(50):
        w.on_pressure(1.0)
    sat = [w.window(lane) for lane in range(4)]
    assert sat[0] == full[0]
    assert sat[1] == sat[2] == sat[3] == cfg.window_floor


# ------------------------------------------- unified background arbiter

def test_arbiter_budget_overdraft_and_refund():
    arb = BandwidthArbiter(QoSConfig(window_s=1.0, hot_bytes_per_s=1000,
                                     arb_hot_frac=0.25), 1000, now=0.0)
    assert arb.peek(now=0.0) == 1000               # starts full
    arb.take(1500, now=0.0)                        # overdraft allowed
    assert arb.peek(now=0.0) == 0
    assert arb.peek(now=0.5) == 0                  # paying the debt back
    assert arb.peek(now=1.0) == 500
    arb.refund(10_000)
    assert arb.peek(now=1.0) == 1000               # clamped at one bucket


def test_arbiter_throttles_while_foreground_hot():
    arb = BandwidthArbiter(QoSConfig(window_s=1.0, hot_bytes_per_s=1000,
                                     arb_hot_frac=0.25), 1000, now=0.0)
    arb.take(1000, now=0.0)
    arb.note_foreground(5000, now=0.0)             # 5000 B/s >> hot
    assert arb.foreground_hot(now=0.5)
    assert arb.peek(now=1.0) == 250                # refill at 25% while hot
    assert not arb.foreground_hot(now=2.0)         # window slid past burst
    assert arb.peek(now=2.0) > 250                 # full-rate refill resumed


def test_drain_engine_delegates_to_shared_bucket():
    arb = BandwidthArbiter(QoSConfig(), 1000, now=0.0)
    eng = DrainEngine(DrainConfig(bw_bytes_per_s=1 << 30), now=0.0,
                      bucket=arb)
    assert eng.peek(now=0.0) == 1000               # arbiter's, not its own
    eng.take(600, now=0.0)
    assert arb.peek(now=0.0) == 400                # debited the shared pool
    assert eng.stats["granted_bytes"] == 600
    eng.refund(600)
    assert arb.peek(now=0.0) == 1000
    assert eng.stats["refunded_bytes"] == 600


# ------------------------------------------------------ integration: lanes

def _sys_cfg(**kw):
    base = dict(num_servers=2, num_clients=2, placement="iso",
                dram_capacity=32 << 20, ssd_capacity=128 << 20,
                chunk_bytes=64 << 10, coalesce_threshold=32 << 10,
                stabilize_interval=0.5)
    base.update(kw)
    return BBConfig(**base)


def test_checkpoint_lane_overtakes_background_flood():
    """Pre-queue a background flood, then sync a checkpoint-lane file: the
    checkpoint barrier must complete while background ops are still
    outstanding — with FIFO ordering it would drain strictly behind the
    whole flood."""
    chunk = 64 << 10
    with BurstBufferSystem(_sys_cfg(
            drain=DrainConfig(enabled=False))) as sys_:
        fs = sys_.fs()
        bg = fs.open("bg", "w", policy="batched", chunk_bytes=chunk,
                     lane="background")
        data = _pattern(0, chunk)
        for off in range(0, 64 << 20, chunk):
            bg.pwrite(data, off)       # same bytes at every offset is fine
        for c in fs.clients:
            c.flush_coalesced()
        ck = fs.open("ck", "w", policy="async", chunk_bytes=chunk,
                     lane="checkpoint")
        ckdata = _pattern(7, 1 << 20)
        ck.pwrite(ckdata, 0)
        ck.close(60.0)
        still_queued = sum(c.outstanding() for c in fs.clients)
        bg.close(120.0)
        assert still_queued > 0, \
            "checkpoint barrier should finish before the flood drains"
        assert fs.open("ck", "r").pread(0, 1 << 20) == ckdata
        got = fs.open("bg", "r").pread(0, 64 << 20)
        assert all(got[o:o + chunk] == data
                   for o in range(0, 64 << 20, chunk))
        lanes = [s["puts_by_lane"]
                 for s in sys_.server_stats().values()]
        assert sum(l[qos.LANE_CHECKPOINT] for l in lanes) > 0
        assert sum(l[qos.LANE_BACKGROUND] for l in lanes) > 0


def test_ack_piggyback_feeds_client_windows():
    with BurstBufferSystem(_sys_cfg()) as sys_:
        fs = sys_.fs()
        with fs.open("f", "w", policy="async") as f:
            f.pwrite(_pattern(0, 4 << 20), 0)
        assert any(c._cwnd is not None and c._cwnd.occupancy() > 0
                   for c in sys_.clients)


def test_qos_disabled_is_plain_fifo():
    cfg = _sys_cfg(qos=QoSConfig(enabled=False))
    with BurstBufferSystem(cfg) as sys_:
        fs = sys_.fs()
        assert all(c._laneq is None for c in sys_.clients)
        assert all(s._laneq is None and s.arbiter is None
                   for s in sys_.servers.values())
        data = _pattern(3, 1 << 20)
        with fs.open("f", "w", policy="batched", lane="checkpoint") as f:
            f.pwrite(data, 0)
        assert fs.open("f", "r").pread(0, len(data)) == data


# --------------------------------------------------- write-through bypass

def test_through_stream_under_bursty_writers_and_server_kill(tmp_path):
    """Satellite: a policy="through" stream reads back byte-exact via
    pread (manifest + PFS fallback) while concurrent BURSTY writers fill
    the buffer — and still after a server kill, because its bytes live on
    the PFS, not in any server's store."""
    with BurstBufferSystem(_sys_cfg(num_servers=3, num_clients=3)) as sys_:
        fs = sys_.fs()
        total = 4 << 20
        thr_data = _pattern(11, total)
        stop = threading.Event()

        def bursty(idx):
            f = fs.open(f"burst_{idx}", "w", policy="batched",
                        chunk_bytes=64 << 10)
            data = _pattern(idx, 64 << 10)
            off = 0
            while not stop.is_set():
                f.pwrite(data, off)
                off += 64 << 10
            f.close(60.0)

        writers = [threading.Thread(target=bursty, args=(i,), daemon=True)
                   for i in range(2)]
        for t in writers:
            t.start()
        thr = fs.open("thr", "w", policy="through")
        for off in range(0, total, 256 << 10):
            thr.pwrite(thr_data[off:off + 256 << 10], off)
        thr.close(30.0)
        stop.set()
        for t in writers:
            t.join(60.0)

        st = fs.stat("thr")
        assert st["size"] == total
        assert st["residency"]["dram"] == 0
        assert st["residency"]["ssd"] == 0          # never touched the BB
        assert fs.open("thr", "r").pread(0, total) == thr_data

        sys_.kill_server("server/0")
        time.sleep(0.3)
        assert fs.open("thr", "r").pread(0, total) == thr_data


def test_auto_bypass_routes_sequential_stream_to_pfs():
    cfg = _sys_cfg(qos=QoSConfig(classify_min_bytes=256 << 10,
                                 bursty_bytes_per_s=1 << 40,
                                 seq_min_run=2))
    total = 2 << 20
    data = _pattern(5, total)
    with BurstBufferSystem(cfg) as sys_:
        fs = sys_.fs()
        f = fs.open("seq", "w", policy="async", chunk_bytes=64 << 10)
        for off in range(0, total, 64 << 10):
            f.pwrite(data[off:off + (64 << 10)], off)
        f.close(60.0)
        assert f.bypassed_bytes > 0                 # classifier flipped it
        assert fs.open("seq", "r").pread(0, total) == data
        st = fs.stat("seq")
        # the bypassed tail lives on the PFS only; early (pre-evidence)
        # chunks may be buffered
        assert st["residency"]["pfs"] > 0
        assert st["residency"]["dram"] + st["residency"]["ssd"] < total


def test_checkpoint_lane_never_auto_bypasses():
    cfg = _sys_cfg(qos=QoSConfig(classify_min_bytes=64 << 10,
                                 bursty_bytes_per_s=1 << 40,
                                 seq_min_run=1))
    total = 1 << 20
    data = _pattern(9, total)
    with BurstBufferSystem(cfg) as sys_:
        fs = sys_.fs()
        f = fs.open("ck", "w", policy="async", chunk_bytes=64 << 10,
                    lane="checkpoint")
        for off in range(0, total, 64 << 10):
            f.pwrite(data[off:off + (64 << 10)], off)
        f.close(60.0)
        assert f.bypassed_bytes == 0                # bursts stay buffered
        st = fs.stat("ck")
        assert st["residency"]["dram"] + st["residency"]["ssd"] > 0


def test_bypass_metadata_tombstones_and_kv_fallthrough():
    with BurstBufferSystem(_sys_cfg()) as sys_:
        fs = sys_.fs()
        data = _pattern(2, 128 << 10)
        with fs.open("thr2", "w", policy="through",
                     chunk_bytes=64 << 10) as f:
            f.pwrite(data, 0)
        # bypass reports are fire-and-forget: poll for the tombstones
        deadline = time.monotonic() + 3.0
        while time.monotonic() < deadline:
            if fs.stat("thr2")["evicted_chunks"] >= 2:
                break
            time.sleep(0.02)
        st = fs.stat("thr2")
        assert st["evicted_chunks"] >= 2       # chunk-granular, whole run
        assert st["residency"]["pfs"] == len(data)
        c = sys_.clients[0]
        # lookup-table range read works cluster-wide (size was broadcast)
        assert c.read_file("thr2", 0, len(data)) == data
        # a direct KV get of ANY chunk key inside the run falls through
        # like any evicted chunk: the miss carries residency, the bytes
        # come back from the PFS
        assert c.get("thr2:0") == data[:64 << 10]
        assert c.get(f"thr2:{64 << 10}") == data[64 << 10:]


def test_truncate_supersedes_parked_writes():
    """Re-opening a file for write must defeat un-synced writes of the
    previous incarnation even when they are still PARKED in lane queues
    (client or server) — pre-QoS FIFO applied them strictly before the
    truncate; with parking they would otherwise re-land afterwards and
    resurrect stale bytes."""
    with BurstBufferSystem(_sys_cfg()) as sys_:
        fs = sys_.fs()
        old = fs.open("tp", "w", policy="async", chunk_bytes=64 << 10,
                      lane="background")
        futs = [old.pwrite(_pattern(1, 64 << 10), off)
                for off in range(0, 4 << 20, 64 << 10)]
        new_data = _pattern(9, 100)
        with fs.open("tp", "w", policy="async") as g:   # truncates
            g.pwrite(new_data, 0)
        for fut in futs:          # every old write resolves (cancelled ops
            fut.result(30.0)      # complete as applied-then-truncated)
        assert fs.stat("tp")["size"] == len(new_data)
        assert fs.open("tp", "r").read() == new_data


def test_through_rewrite_of_buffered_chunks_supersedes_them():
    """A bypassed run over offsets that live buffered chunks fully cover
    must evict those chunks on every server — otherwise the older BB
    bytes shadow the newer PFS copy forever (manifest chunks win over
    gap fills on the read path)."""
    with BurstBufferSystem(_sys_cfg()) as sys_:
        fs = sys_.fs()
        old = _pattern(4, 256 << 10)
        with fs.open("mix", "w", policy="async", chunk_bytes=64 << 10) as f:
            f.pwrite(old, 0)                       # buffered + replicated
        new = _pattern(6, 256 << 10)
        with fs.open("mix", "a", policy="through") as f:
            f.pwrite(new, 0)                       # straight to the PFS
        deadline = time.monotonic() + 3.0          # reports are async
        while time.monotonic() < deadline:
            if fs.open("mix", "r").pread(0, len(new)) == new:
                break
            time.sleep(0.02)
        assert fs.open("mix", "r").pread(0, len(new)) == new
        st = fs.stat("mix")
        assert st["residency"]["dram"] + st["residency"]["ssd"] == 0


def test_through_rewrite_truncates_old_incarnation():
    with BurstBufferSystem(_sys_cfg()) as sys_:
        fs = sys_.fs()
        with fs.open("t", "w", policy="through") as f:
            f.pwrite(_pattern(1, 1 << 20), 0)
        short = _pattern(8, 64 << 10)
        with fs.open("t", "w", policy="through") as f:
            f.pwrite(short, 0)
        assert fs.stat("t")["size"] == len(short)
        assert fs.open("t", "r").read() == short


# -------------------------------------------------------- control timeouts

def test_control_timeout_is_wired_through():
    cfg = _sys_cfg(control_timeout=0.5)
    with BurstBufferSystem(cfg) as sys_:
        assert all(c.control_timeout == 0.5 for c in sys_.clients)
        assert sys_.fs().control_timeout == 0.5
