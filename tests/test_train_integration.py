"""Flagship integration test: training with async burst-buffer checkpoints
survives a burst-buffer server failure and restores to a bit-exact state —
the end-to-end property the paper's system exists to provide."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.bbckpt import BBCheckpointManager
from repro.configs.base import get_config, reduced
from repro.core import BBConfig, BurstBufferSystem
from repro.data.pipeline import SyntheticLMPipeline
from repro.models.registry import build_model
from repro.runtime.train_step import (TrainState, init_train_state,
                                      make_optimizer, make_train_step)

ARCH = "starcoder2-3b"


def _setup(seed=0):
    cfg = reduced(get_config(ARCH))
    model = build_model(cfg)
    opt = make_optimizer(cfg)
    state = init_train_state(cfg, model, opt, jax.random.PRNGKey(seed))
    step_fn = jax.jit(make_train_step(cfg, model, opt, accum_steps=1))
    pipe = SyntheticLMPipeline(vocab_size=cfg.vocab_size, seq_len=16,
                               global_batch=4, seed=11)
    return cfg, model, opt, state, step_fn, pipe


def _params_equal(a, b) -> bool:
    return all(bool(jnp.array_equal(x, y)) for x, y in
               zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@pytest.mark.slow
def test_failure_restore_bit_exact_continuation():
    cfg, model, opt, state, step_fn, pipe = _setup()

    # ---- uninterrupted reference run: 8 steps ----
    ref_state = state
    ref_pipe = SyntheticLMPipeline(vocab_size=cfg.vocab_size, seq_len=16,
                                   global_batch=4, seed=11)
    for _ in range(8):
        ref_state, _ = step_fn(ref_state, next(ref_pipe))

    # ---- run with BB checkpointing, kill a server, restore, continue ----
    with BurstBufferSystem(BBConfig(num_servers=4, num_clients=4,
                                    dram_capacity=64 << 20,
                                    stabilize_interval=0.1)) as bb:
        mgr = BBCheckpointManager(bb, quantize=False)
        for _ in range(4):
            state, _ = step_fn(state, next(pipe))
        ckpt = {"params": state.params, "opt_state": state.opt_state,
                "data": {"step": jnp.asarray(pipe.step, jnp.int32)}}
        mgr.save(4, ckpt, blocking_flush=False)

        # kill a burst-buffer server while the flush drains
        bb.kill_server("server/0")
        time.sleep(0.8)
        for c in bb.clients:
            c.put_timeout = 0.8

        # "crash": rebuild fresh state, restore from the BB (replicas)
        state2 = init_train_state(cfg, model, opt, jax.random.PRNGKey(99))
        target = {"params": state2.params, "opt_state": state2.opt_state,
                  "data": {"step": jnp.asarray(0, jnp.int32)}}
        restored, ck_step = mgr.restore(target)
        assert ck_step == 4
        state2 = TrainState(restored["params"], restored["opt_state"])
        pipe2 = SyntheticLMPipeline(vocab_size=cfg.vocab_size, seq_len=16,
                                    global_batch=4, seed=11)
        pipe2.load_state_dict({"step": int(restored["data"]["step"]),
                               "seed": 11, "shard_id": 0, "num_shards": 1})
        for _ in range(4):
            state2, _ = step_fn(state2, next(pipe2))

    assert _params_equal(state2.params, ref_state.params), \
        "restored continuation diverged from the uninterrupted run"


@pytest.mark.slow
def test_checkpoint_overlap_does_not_block_training():
    """Ingest time (critical path) must be far below the full flush time of
    the same bytes — the paper's core value proposition."""
    cfg, model, opt, state, step_fn, pipe = _setup()
    with BurstBufferSystem(BBConfig(num_servers=4, num_clients=4,
                                    dram_capacity=256 << 20)) as bb:
        mgr = BBCheckpointManager(bb, quantize=False)
        state, _ = step_fn(state, next(pipe))      # warm the jit
        ckpt = {"params": state.params, "opt_state": state.opt_state,
                "data": {"step": jnp.asarray(1, jnp.int32)}}
        mgr.save(1, ckpt, blocking_flush=False)    # warm serialize path
        mgr.wait_flushes()
        t0 = time.perf_counter()
        ingest = mgr.save(2, ckpt, blocking_flush=False)
        t_return = time.perf_counter() - t0
        mgr.wait_flushes()
        flush = mgr.metrics[2].get("flush_s", 0)
        assert t_return == pytest.approx(ingest, abs=0.5)
        assert ingest < 5.0
        # training resumed before flush finished (async overlap)
        assert "flush_s" in mgr.metrics[2]
