import os
import sys
import tempfile

# Tests run on the single real CPU device (the dry-run sets its own
# XLA_FLAGS in a separate process). Multi-device tests spawn subprocesses.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root, so the tools/ package (bbcheck) is importable from tests
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
# make the _hypothesis_compat shim importable regardless of invocation dir
sys.path.insert(0, os.path.dirname(__file__))

import pytest  # noqa: E402

from repro.core import locktrack, telemetry  # noqa: E402


@pytest.fixture(scope="session", autouse=True)
def _lock_order_tracking():
    """Run the whole suite with instrumented locks (bbcheck rule 2's
    runtime half): every lock the core creates during the session records
    real acquisition orders, and any inversion fails the run.

    Telemetry rides along (ISSUE 9): the whole suite runs with live
    instruments — registry, tracer, flight recorder — so its locks join
    the inversion check and every test failure can dump the flight ring."""
    telemetry.enable()
    tr = locktrack.enable()
    yield
    locktrack.disable()
    telemetry.disable()
    if tr.inversions:
        # post-mortem artifact: acquisition digraph, inversion stacks,
        # and every live thread's current stack
        path = os.environ.get(
            "BB_LOCK_ARTIFACT",
            os.path.join(tempfile.gettempdir(), "bb-lock-inversions.json"))
        tr.dump(path)
        pytest.fail(
            f"lock-order inversions recorded during test run "
            f"(digraph + thread stacks dumped to {path}): "
            f"{[{k: v for k, v in inv.items() if k != 'stack'} for inv in tr.inversions]}")


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_makereport(item, call):
    """Flight-recorder post-mortem (ISSUE 9): any failing test phase dumps
    the bounded per-component event rings to a JSON artifact, next to the
    lock-order artifact — a red test ships its own recent-event history."""
    outcome = yield
    report = outcome.get_result()
    if report.failed and telemetry.enabled():
        path = os.environ.get(
            "BB_FLIGHT_ARTIFACT",
            os.path.join(tempfile.gettempdir(), "bb-flight.json"))
        try:
            telemetry.dump_flight(path, test=item.nodeid, phase=report.when)
            report.sections.append(
                ("flight recorder", f"event rings dumped to {path}"))
        except OSError:
            pass
