import os
import sys

# Tests run on the single real CPU device (the dry-run sets its own
# XLA_FLAGS in a separate process). Multi-device tests spawn subprocesses.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# make the _hypothesis_compat shim importable regardless of invocation dir
sys.path.insert(0, os.path.dirname(__file__))
