import os
import sys
import tempfile

# Tests run on the single real CPU device (the dry-run sets its own
# XLA_FLAGS in a separate process). Multi-device tests spawn subprocesses.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root, so the tools/ package (bbcheck) is importable from tests
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
# make the _hypothesis_compat shim importable regardless of invocation dir
sys.path.insert(0, os.path.dirname(__file__))

import pytest  # noqa: E402

from repro.core import locktrack  # noqa: E402


@pytest.fixture(scope="session", autouse=True)
def _lock_order_tracking():
    """Run the whole suite with instrumented locks (bbcheck rule 2's
    runtime half): every lock the core creates during the session records
    real acquisition orders, and any inversion fails the run."""
    tr = locktrack.enable()
    yield
    locktrack.disable()
    if tr.inversions:
        # post-mortem artifact: acquisition digraph, inversion stacks,
        # and every live thread's current stack
        path = os.environ.get(
            "BB_LOCK_ARTIFACT",
            os.path.join(tempfile.gettempdir(), "bb-lock-inversions.json"))
        tr.dump(path)
        pytest.fail(
            f"lock-order inversions recorded during test run "
            f"(digraph + thread stacks dumped to {path}): "
            f"{[{k: v for k, v in inv.items() if k != 'stack'} for inv in tr.inversions]}")
