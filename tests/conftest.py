import json
import os
import sys
import tempfile

# Tests run on the single real CPU device (the dry-run sets its own
# XLA_FLAGS in a separate process). Multi-device tests spawn subprocesses.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root, so the tools/ package (bbcheck) is importable from tests
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
# make the _hypothesis_compat shim importable regardless of invocation dir
sys.path.insert(0, os.path.dirname(__file__))

import pytest  # noqa: E402

from repro.core import locktrack, telemetry  # noqa: E402


def _artifact(env_key: str, filename: str) -> str:
    """Failure-artifact path resolution (ISSUE 10): each artifact is
    individually overridable by its own env var, and all of them default
    under one collection directory ($BB_ARTIFACT_DIR, else the system
    tempdir) so CI uploads a single folder."""
    override = os.environ.get(env_key)
    if override:
        return override
    adir = os.environ.get("BB_ARTIFACT_DIR") or tempfile.gettempdir()
    try:
        os.makedirs(adir, exist_ok=True)
    except OSError:
        adir = tempfile.gettempdir()
    return os.path.join(adir, filename)


@pytest.fixture(scope="session", autouse=True)
def _lock_order_tracking():
    """Run the whole suite with instrumented locks (bbcheck rule 2's
    runtime half): every lock the core creates during the session records
    real acquisition orders, and any inversion fails the run.

    Telemetry rides along (ISSUE 9): the whole suite runs with live
    instruments — registry, tracer, flight recorder — so its locks join
    the inversion check and every test failure can dump the flight ring."""
    telemetry.enable()
    tr = locktrack.enable()
    yield
    locktrack.disable()
    telemetry.disable()
    if tr.inversions:
        # post-mortem artifact: acquisition digraph, inversion stacks,
        # and every live thread's current stack
        path = _artifact("BB_LOCK_ARTIFACT", "bb-lock-inversions.json")
        tr.dump(path)
        pytest.fail(
            f"lock-order inversions recorded during test run "
            f"(digraph + thread stacks dumped to {path}): "
            f"{[{k: v for k, v in inv.items() if k != 'stack'} for inv in tr.inversions]}")


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_makereport(item, call):
    """Failure post-mortems (ISSUES 9/10): any failing test phase dumps
    the flight recorder's bounded per-component event rings AND a health
    engine evaluation over the live registry — a red test ships its own
    recent-event history plus the SLO/watchdog verdicts at death, next to
    the lock-order artifact under $BB_ARTIFACT_DIR."""
    outcome = yield
    report = outcome.get_result()
    if report.failed and telemetry.enabled():
        path = _artifact("BB_FLIGHT_ARTIFACT", "bb-flight.json")
        try:
            telemetry.dump_flight(path, test=item.nodeid, phase=report.when)
            report.sections.append(
                ("flight recorder", f"event rings dumped to {path}"))
        except OSError:
            pass
        hpath = _artifact("BB_HEALTH_ARTIFACT", "bb-health.json")
        try:
            from repro.core.health import HealthEngine
            verdict = HealthEngine().evaluate(telemetry.snapshot())
            with open(hpath, "w") as fh:
                json.dump({"health": verdict, "test": item.nodeid,
                           "phase": report.when}, fh, indent=2,
                          sort_keys=True, default=repr)
            report.sections.append(
                ("health engine", f"verdicts dumped to {hpath}"))
        except Exception:
            pass
