"""Telemetry (ISSUE 9): instruments, causal tracing, flight recorder.

Unit tests drive a private Registry with a fake clock; integration tests
lean on the session-wide registry conftest enables (filtering tracer
events by trace id, so parallel history from other tests never bleeds
in)."""
import json
import os
import threading
import time

import numpy as np
import pytest

import tools.bbcheck.metrics as metrics_doc
from repro.checkpoint.bbckpt import BBCheckpointManager
from repro.core import telemetry
from repro.core.drain import DrainConfig
from repro.core.system import BBConfig, BurstBufferSystem


# ------------------------------------------------------------- instruments

class FakeClock:
    def __init__(self, t=100.0):
        self.t = t

    def __call__(self):
        return self.t


def test_counter_gauge_units():
    reg = telemetry.Registry(clock=FakeClock())
    c = reg.counter("transport.msgs")
    c.inc(label="put")
    c.inc(3, label="put")
    c.add(2)
    assert c.snapshot() == {"put": 4, "": 2}
    g = reg.gauge("qos.occupancy_ewma")
    g.set(0.25, label="c0")
    g.set(0.75, label="c0")
    assert g.snapshot() == {"c0": 0.75}


def test_histogram_buckets_and_stats():
    reg = telemetry.Registry(clock=FakeClock())
    h = reg.histogram("ckpt.save_s")
    for v in (5e-6, 2e-3, 2e-3, 0.5, 99.0):    # 99s lands in overflow
        h.observe(v)
    snap = h.snapshot()
    st = snap["series"][""]
    assert st["count"] == 5
    assert st["min"] == 5e-6 and st["max"] == 99.0
    assert st["sum"] == pytest.approx(5e-6 + 2e-3 + 2e-3 + 0.5 + 99.0)
    assert len(st["buckets"]) == len(snap["bounds"]) + 1
    assert sum(st["buckets"]) == 5
    assert st["buckets"][0] == 1          # 5us < first bound (10us)
    assert st["buckets"][-1] == 1         # overflow
    # 2ms falls in the (1e-3, 3.16e-3] bucket
    idx = snap["bounds"].index(3.16e-3)
    assert st["buckets"][idx] == 2


def test_ring_bounded_and_clock_stamped():
    clock = FakeClock()
    reg = telemetry.Registry(clock=clock)
    r = reg.ring("server.occupancy")
    for i in range(telemetry.Ring.MAXLEN + 10):
        clock.t = 100.0 + i
        r.note(i / 1000.0, label="s0")
    snap = r.snapshot()
    assert len(snap) == telemetry.Ring.MAXLEN      # oldest 10 dropped
    assert snap[0][0] == 110.0 and snap[0][1] == "s0"
    assert snap[-1][2] == pytest.approx(
        (telemetry.Ring.MAXLEN + 9) / 1000.0)


def test_unknown_instrument_rejected():
    reg = telemetry.Registry(clock=FakeClock())
    with pytest.raises(ValueError, match="CATALOG"):
        reg.counter("nope.not_declared")
    with pytest.raises(ValueError, match="CATALOG"):
        reg.histogram("transport.msgs")     # declared, but as a counter
    with pytest.raises(ValueError, match="CATALOG"):
        reg.poll("nope.poll", dict)


def test_poll_replacement_and_snapshot():
    reg = telemetry.Registry(clock=FakeClock())
    reg.poll("client.ops", lambda: {"puts": 1}, label="c0")
    reg.poll("client.ops", lambda: {"puts": 7}, label="c0")   # replaces
    reg.poll("client.ops", lambda: 1 / 0, label="dead")       # skipped
    snap = reg.snapshot()
    assert snap["polls"]["client.ops"] == {"c0": {"puts": 7}}


def test_disabled_module_api_is_noop(monkeypatch):
    monkeypatch.setattr(telemetry, "_registry", None)
    assert not telemetry.enabled()
    assert telemetry.counter("transport.msgs") is telemetry.NOOP
    assert telemetry.histogram("ckpt.save_s") is telemetry.NOOP
    assert telemetry.span("x") is telemetry.NOOP
    assert telemetry.msg_span("x", "c", {"_trace": [1, 2]}) is telemetry.NOOP
    assert telemetry.snapshot() == {}
    p = {"k": 1}
    assert telemetry.trace_inject(p) is p and "_trace" not in p
    telemetry.record("c", "event")          # swallowed, no crash


def test_registry_thread_safety_hammer():
    reg = telemetry.Registry(clock=time.monotonic)
    c = reg.counter("transport.msgs")
    h = reg.histogram("server.dispatch_s")
    n_threads, n_iter = 8, 500
    errors = []

    def hammer(i):
        try:
            for j in range(n_iter):
                c.inc(label=f"t{i % 4}")
                h.observe(j * 1e-6, label=f"t{i % 4}")
                if j % 100 == 0:
                    reg.snapshot()
        except Exception as e:      # pragma: no cover
            errors.append(e)

    ts = [threading.Thread(target=hammer, args=(i,))
          for i in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errors
    assert sum(c.snapshot().values()) == n_threads * n_iter
    hs = reg.histogram("server.dispatch_s").snapshot()["series"]
    assert sum(st["count"] for st in hs.values()) == n_threads * n_iter


# ----------------------------------------------------------------- tracing

def test_span_tree_and_chrome_export(tmp_path):
    clock = FakeClock()
    reg = telemetry.Registry(clock=clock)
    with reg.tracer.root("op", "app", step=7) as root:
        ctx = reg.tracer.current_ctx()
        assert ctx == [root.trace_id, root.span_id]
        with reg.tracer.span("child", "worker"):
            clock.t += 0.5
    events = reg.tracer.events()
    assert len(events) == 2
    (child, parent) = events         # child finishes first
    assert child[3] == "child" and parent[3] == "op"
    assert child[0] == parent[0]               # same trace
    assert child[2] == parent[1]               # parented by root
    chrome = reg.tracer.chrome_events()
    xs = [e for e in chrome if e["ph"] == "X"]
    metas = [e for e in chrome if e["ph"] == "M"]
    assert {e["name"] for e in xs} == {"op", "child"}
    assert {m["args"]["name"] for m in metas} == {"app", "worker"}
    assert xs[0]["dur"] == pytest.approx(0.5e6)    # microseconds


def test_untraced_message_costs_nothing():
    reg = telemetry.Registry(clock=FakeClock())
    # no message context, no active span: msg_span refuses to open a root
    assert reg.tracer.span("s", "c") is telemetry.NOOP
    assert reg.tracer.events() == []


def _trace_components(trace_id):
    comps = set()
    for e in telemetry.export_chrome():
        if e.get("ph") == "X" and e["args"]["trace"] == trace_id:
            comps.add(e["cat"])
    return comps


def _trace_names(trace_id):
    names = set()
    for e in telemetry.export_chrome():
        if e.get("ph") == "X" and e["args"]["trace"] == trace_id:
            names.add(e["name"])
    return names


def test_put_trace_crosses_client_server_replica():
    sys_ = BurstBufferSystem(BBConfig(num_servers=3, num_clients=1,
                                      replication=2)).start()
    try:
        cli = sys_.clients[0]
        with telemetry.span("test.put", "test") as root:
            trace = root.trace_id
            cli.put("k1", b"x" * 1024)
        cli.drain(5.0)
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline:
            comps = _trace_components(trace)
            if sum(1 for c in comps if c.startswith("server/")) >= 2 \
                    and any(c.startswith("client/") for c in comps):
                break
            time.sleep(0.05)
        comps = _trace_components(trace)
        # primary + replica hop + client-side ack processing, one trace
        assert sum(1 for c in comps if c.startswith("server/")) >= 2, comps
        assert any(c.startswith("client/") for c in comps), comps
        names = _trace_names(trace)
        assert "server.put" in names or "server.put_batch" in names, names
        assert "server.replica_put" in names \
            or "server.replica_put_batch" in names, names
    finally:
        sys_.stop()


def test_drain_epoch_trace_crosses_server_and_manager():
    dk = dict(high_watermark=0.5, low_watermark=0.25,
              request_interval=0.02, pressure_interval=0.05,
              max_epoch_bytes=2 << 20, epoch_timeout_s=5.0)
    sys_ = BurstBufferSystem(BBConfig(
        num_servers=3, num_clients=3, placement="iso",
        dram_capacity=1 << 20, ssd_capacity=2 << 20,
        segment_bytes=128 << 10, chunk_bytes=64 << 10,
        drain=DrainConfig(**dk))).start()
    try:
        data = np.random.default_rng(0).integers(
            0, 256, 6 << 20, dtype=np.uint8).tobytes()
        f = sys_.fs().open("big", "w", policy="batched")
        f.pwrite(data, 0)
        f.close(60.0)
        deadline = time.monotonic() + 20.0
        roots = []
        while time.monotonic() < deadline:
            roots = [e for e in telemetry.export_chrome()
                     if e.get("ph") == "X"
                     and e["name"] == "server.drain_request"]
            done = [r for r in roots
                    if "manager.drain_request"
                    in _trace_names(r["args"]["trace"])]
            if done:
                roots = done
                break
            time.sleep(0.1)
        assert roots, "no drain_request trace recorded"
        comps = _trace_components(roots[0]["args"]["trace"])
        assert "manager" in comps, comps
        assert any(c.startswith("server/") for c in comps), comps
    finally:
        sys_.stop()


def test_ckpt_save_trace_spans_three_components():
    """Acceptance: one bbckpt.save() produces a Chrome trace whose span
    tree crosses >= 3 components (client, server, manager)."""
    sys_ = BurstBufferSystem(BBConfig(num_servers=3, num_clients=2,
                                      dram_capacity=4 << 20)).start()
    try:
        ck = BBCheckpointManager(sys_, io_mode="batched")
        state = {"w": np.arange(1 << 16, dtype=np.float32)}
        ck.save(1, state, blocking_flush=True)
        saves = [e for e in telemetry.export_chrome()
                 if e.get("ph") == "X" and e["name"] == "ckpt.save"]
        assert saves
        comps = _trace_components(saves[-1]["args"]["trace"])
        assert "checkpoint" in comps
        assert any(c.startswith("client/") for c in comps), comps
        assert any(c.startswith("server/") for c in comps), comps
        assert "manager" in comps, comps
        assert len(comps) >= 3
    finally:
        sys_.stop()


# ----------------------------------------------------------------- scrape

def test_scrape_and_metrics_query():
    sys_ = BurstBufferSystem(BBConfig(num_servers=3, num_clients=2,
                                      dram_capacity=4 << 20)).start()
    try:
        f = sys_.fs().open("scr/data", "w", policy="batched",
                           lane="checkpoint")
        chunk = os.urandom(64 << 10)
        for i in range(16):
            f.pwrite(chunk, i * len(chunk))
        f.close(30.0)
        scrape = sys_.scrape()
        reg = scrape["registry"]
        lw = reg["histograms"]["client.lane_wait_s"]["series"]
        assert sum(st["count"] for st in lw.values()) > 0
        assert sum(reg["counters"]["transport.msgs"].values()) > 0
        assert scrape["servers"], "no server answered metrics_query"
        for payload in scrape["servers"].values():
            assert "stats" in payload and "puts" in payload["stats"]
        # remote-scraper path: instruments ride the reply when asked
        probe = sys_.clients[0]
        r = sys_.transport.request(
            probe.ep, next(iter(sys_.servers)), "metrics_query",
            {"instruments": True}, timeout=2.0)
        assert r is not None and r.kind == "metrics"
        assert "histograms" in r.payload["instruments"]
    finally:
        sys_.stop()


def test_spill_fsync_histograms_under_pressure():
    sys_ = BurstBufferSystem(BBConfig(
        num_servers=2, num_clients=2, dram_capacity=256 << 10,
        segment_bytes=64 << 10, chunk_bytes=32 << 10,
        drain=DrainConfig(enabled=False))).start()
    try:
        f = sys_.fs().open("press/data", "w", policy="batched")
        chunk = os.urandom(64 << 10)
        for i in range(24):                     # 1.5MB >> 512KB DRAM
            f.pwrite(chunk, i * len(chunk))
        f.close(30.0)
        reg = telemetry.snapshot()
        spill = reg["histograms"].get("store.spill_s", {"series": {}})
        fsync = reg["histograms"].get("store.fsync_s", {"series": {}})
        assert sum(st["count"] for st in spill["series"].values()) > 0
        assert sum(st["count"] for st in fsync["series"].values()) > 0
    finally:
        sys_.stop()


# --------------------------------------------------------- flight recorder

def test_flight_recorder_round_trip(tmp_path):
    clock = FakeClock()
    reg = telemetry.Registry(clock=clock)
    for i in range(telemetry.FlightRecorder.PER_COMPONENT + 5):
        reg.flight.record("server/0", "redirect", n=i)
    reg.flight.record("manager", "drain_abort", reason="timeout")
    path = reg.flight.dump(str(tmp_path / "flight.json"), test="t1")
    with open(path) as fh:
        doc = json.load(fh)
    assert doc["test"] == "t1"
    ring = doc["flight"]["server/0"]
    assert len(ring) == telemetry.FlightRecorder.PER_COMPONENT  # bounded
    assert ring[-1]["n"] == telemetry.FlightRecorder.PER_COMPONENT + 4
    assert ring[0]["n"] == 5                                    # oldest cut
    assert doc["flight"]["manager"][0]["event"] == "drain_abort"
    assert doc["flight"]["manager"][0]["t"] == 100.0


def test_dump_flight_disabled_still_writes(tmp_path, monkeypatch):
    monkeypatch.setattr(telemetry, "_registry", None)
    path = telemetry.dump_flight(str(tmp_path / "empty.json"), test="t2")
    with open(path) as fh:
        doc = json.load(fh)
    assert doc == {"flight": {}, "test": "t2"}


# -------------------------------------------------------------------- docs

def test_metrics_doc_in_sync():
    """docs/METRICS.md must match telemetry.CATALOG byte-for-byte (the
    --lint drift gate, mirrored as a test)."""
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(here, "docs", "METRICS.md")) as fh:
        committed = fh.read()
    assert committed == metrics_doc.render(), \
        "regenerate with `python -m tools.bbcheck --emit-metrics " \
        "docs/METRICS.md`"


def test_catalog_sorted_and_unique():
    names = [spec[0] for spec in telemetry.CATALOG]
    assert names == sorted(names)
    assert len(names) == len(set(names))
    assert all(spec[1] in ("counter", "gauge", "histogram", "ring", "poll")
               for spec in telemetry.CATALOG)
