"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train step + one decode step on CPU; output shapes + finite."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, list_configs, reduced
from repro.configs.cells import ARCHS
from repro.models.common import padded_vocab
from repro.models.registry import build_model
from repro.runtime.train_step import (init_train_state, make_optimizer,
                                      make_train_step)

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=16):
    tokens = jax.random.randint(KEY, (b, s + 1), 0, cfg.vocab_size)
    batch = {"inputs": tokens[:, :-1], "labels": tokens[:, 1:]}
    enc = None
    if cfg.encoder_seq:
        enc = jax.random.normal(KEY, (b, cfg.encoder_seq, cfg.encoder_dim),
                                jnp.float32)
        batch["enc_input"] = enc
    return batch, enc


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_finite(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(KEY)
    batch, enc = _batch(cfg)
    logits = model.forward(params, batch["inputs"], enc)
    assert logits.shape == (2, 16, padded_vocab(cfg))
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_finite(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    opt = make_optimizer(cfg)
    state = init_train_state(cfg, model, opt, KEY)
    step = jax.jit(make_train_step(cfg, model, opt, accum_steps=2))
    batch, _ = _batch(cfg, b=4)
    state, metrics = step(state, batch)
    state, metrics2 = step(state, batch)
    assert bool(jnp.isfinite(metrics2["loss"]))
    assert float(metrics2["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_and_prefill(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(KEY)
    batch, enc = _batch(cfg)
    cache = model.init_cache(2, 32)
    logits, cache2 = model.prefill(params, cache, batch["inputs"], enc)
    assert logits.shape == (2, 1, padded_vocab(cfg))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache3 = model.decode_step(params, cache2, tok,
                                        jnp.asarray(16, jnp.int32))
    assert logits2.shape == (2, 1, padded_vocab(cfg))
    assert bool(jnp.all(jnp.isfinite(logits2)))
    # cache structure preserved
    assert jax.tree.structure(cache2) == jax.tree.structure(cache3)


def test_all_archs_registered():
    assert set(ARCHS) <= set(list_configs())
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", ["starcoder2-3b", "xlstm-350m",
                                  "recurrentgemma-9b"])
def test_decode_matches_forward_last_token(arch):
    """Greedy decode after prefill agrees with the argmax of the training
    forward at the same position (cache-correctness end to end)."""
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(KEY)
    tokens = jax.random.randint(jax.random.PRNGKey(3), (1, 12), 0,
                                cfg.vocab_size)
    full_logits = model.forward(params, tokens)
    cache = model.init_cache(1, 32)
    pre_logits, _ = model.prefill(params, cache, tokens)
    np.testing.assert_allclose(
        np.asarray(pre_logits[:, 0], np.float32),
        np.asarray(full_logits[:, -1], np.float32), atol=2e-3, rtol=2e-3)
