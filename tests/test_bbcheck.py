"""bbcheck (ISSUE 6 + 7): each rule fires on seeded-violation fixtures,
the allowlist is shrinking-only, the runtime lock tracker records
inversions (and dumps a post-mortem artifact), the server's unknown-kind
black-hole detector reports instead of silently dropping, the generated
protocol registry regenerates byte-identical, and the real core passes
every rule with an empty allowlist.
"""
import ast
import json
import os
import textwrap
import time

import pytest

from repro.core import locktrack
from repro.core.locktrack import LockOrderTracker, TrackedLock
from repro.core.system import BBConfig, BurstBufferSystem
from tools.bbcheck import blocking, clocks, epochs, literals, locks, \
    ownership, protocol, schema
from tools.bbcheck.__main__ import DEFAULT_ALLOWLIST, DEFAULT_ROOT, \
    parse_tree
from tools.bbcheck.report import Violation, apply_allowlist


def trees(**srcs):
    out = {}
    for name, src in srcs.items():
        src = textwrap.dedent(src)
        tree = ast.parse(src)
        tree._bb_source = src       # ownership reads markers off the tree
        out[name] = tree
    return out


def rules_of(violations):
    return {v.rule for v in violations}


# ---------------------------------------------------------------- rule 1
DISPATCHER_SERVER = """
    class FixServer:
        def _dispatch(self, msg):
            handler = getattr(self, f"_on_{msg.kind}", None)
            if handler:
                handler(msg)

        def _on_put(self, msg):
            self.store[msg.payload["key"]] = msg.payload["value"]
"""


def test_protocol_unhandled_kind_fires():
    vs = protocol.check(trees(**{
        "server.py": DISPATCHER_SERVER,
        "client.py": """
            class FixClient:
                def go(self, server):
                    self.transport.send(self.tname, server, "putt",
                                        {"key": "k", "value": b"v"})
            """}))
    assert any(v.ident == "unhandled:putt:server" for v in vs), vs


def test_protocol_dead_handler_fires():
    vs = protocol.check(trees(**{"server.py": DISPATCHER_SERVER}))
    assert any(v.ident == "dead-handler:server:put" for v in vs), vs


def test_protocol_missing_payload_key_fires():
    vs = protocol.check(trees(**{
        "server.py": DISPATCHER_SERVER,
        "client.py": """
            class FixClient:
                def go(self, server):
                    self.transport.send(self.tname, server, "put",
                                        {"key": "k"})
            """}))
    assert any(v.ident == "missing-key:server:put:value" for v in vs), vs


def test_protocol_clean_fixture_passes():
    vs = protocol.check(trees(**{
        "server.py": DISPATCHER_SERVER,
        "client.py": """
            class FixClient:
                def go(self, server):
                    self.transport.send(self.tname, server, "put",
                                        {"key": "k", "value": b"v"})
            """}))
    assert vs == []


# ---------------------------------------------------------------- rule 2
def test_lock_cycle_fires():
    vs = locks.check(trees(**{"m.py": """
        class A:
            def f(self):
                with self._lock:
                    with self._op_lock:
                        pass

            def g(self):
                with self._op_lock:
                    with self._lock:
                        pass
        """}))
    assert any(v.ident.startswith("cycle:") for v in vs), vs


def test_lock_self_nesting_fires():
    vs = locks.check(trees(**{"m.py": """
        class A:
            def f(self):
                with self._lock:
                    with self._lock:
                        pass
        """}))
    assert any(v.ident.startswith("self-nest:") for v in vs), vs


def test_lock_ordered_nesting_passes():
    vs = locks.check(trees(**{"m.py": """
        class A:
            def f(self):
                with self._lock:
                    with self._op_lock:
                        pass

            def g(self):
                with self._lock:
                    with self._op_lock:
                        pass
        """}))
    assert vs == []


# ---------------------------------------------------------------- rule 3
def test_blocking_under_lock_fires():
    vs = blocking.check(trees(**{"m.py": """
        import time
        class A:
            def f(self):
                with self._lock:
                    time.sleep(0.5)
                    r = self.transport.request(self.ep, "x", "k", {})
                    m = self.ep.recv(timeout=1.0)
                    q = self.q.get(timeout=2.0)
        """}))
    msgs = [v.message for v in vs]
    assert len(vs) == 4, msgs
    assert any("time.sleep" in m for m in msgs)
    assert any("transport.request" in m for m in msgs)
    assert any("recv" in m for m in msgs)
    assert any("queue.get" in m for m in msgs)


def test_blocking_outside_lock_passes():
    vs = blocking.check(trees(**{"m.py": """
        import time
        class A:
            def f(self):
                with self._lock:
                    x = self.d.get("key")       # dict lookup: fine
                    y = self.q.get(timeout=0)   # non-blocking poll: fine
                time.sleep(0.5)                 # not under the lock
        """}))
    assert vs == []


# ---------------------------------------------------------------- rule 4
def test_direct_clock_fires_and_guard_passes():
    vs = clocks.check(trees(**{"m.py": """
        import time
        def bad():
            return time.monotonic()
        def also_bad():
            return time.time()
        def guarded(now=None):
            now = time.monotonic() if now is None else now
            return now
        def injected(self):
            return self._clock()
        """}))
    assert len(vs) == 2, vs
    assert {v.ident for v in vs} == {"time.monotonic:bad",
                                     "time.time:also_bad"}


# ---------------------------------------------------------------- rule 5
def test_literal_intervals_fire():
    vs = literals.check(trees(**{"m.py": """
        import time
        class A:
            def f(self, busy):
                self.ep.recv(timeout=0.05)
                self.ep.recv(timeout=0.0 if busy else 0.02)
                time.sleep(0.01)
                self.event.wait(0.25)
        """}))
    assert len(vs) == 4, vs


def test_configured_intervals_pass():
    vs = literals.check(trees(**{"m.py": """
        import time
        class A:
            def f(self):
                self.ep.recv(timeout=self.poll_interval)
                self.ep.recv(timeout=0)        # non-blocking: fine
                time.sleep(self.retry_interval)

            def g(self, timeout: float = 2.0):  # signature default: fine
                pass
        """}))
    assert vs == []


# ------------------------------------------------------------- allowlist
def test_allowlist_is_shrinking_only():
    v = Violation("clocks", "m.py", 3, "time.monotonic:f", "x")
    new, allowed, stale = apply_allowlist([v], [v.key])
    assert (new, allowed, stale) == ([], [v], [])
    new, allowed, stale = apply_allowlist([v], [])
    assert (new, allowed, stale) == ([v], [], [])
    # a fixed violation leaves its entry behind -> stale -> must fail
    new, allowed, stale = apply_allowlist([], [v.key])
    assert new == [] and stale == [v.key]


# ------------------------------------------------------- runtime tracker
def test_locktrack_records_inversion():
    tr = LockOrderTracker()
    a = TrackedLock("A", tr)
    b = TrackedLock("B", tr)
    with a:
        with b:
            pass
    assert tr.inversions == []
    with b:
        with a:
            pass
    assert len(tr.inversions) == 1
    inv = tr.inversions[0]
    assert inv["kind"] == "order-inversion"
    assert "B -> A" in inv["second"]


def test_locktrack_same_name_nesting_is_inversion():
    tr = LockOrderTracker()
    a1 = TrackedLock("Endpoint._lock", tr)
    a2 = TrackedLock("Endpoint._lock", tr)
    with a1:
        with a2:
            pass
    assert tr.inversions and tr.inversions[0]["kind"] == "same-name-nesting"


def test_locktrack_reentrant_and_clean_orders():
    tr = LockOrderTracker()
    r = TrackedLock("R", tr, reentrant=True)
    inner = TrackedLock("I", tr)
    with r:
        with r:                 # reentrant re-acquire: not a nesting event
            with inner:
                pass
    with r:
        with inner:
            pass
    assert tr.inversions == []
    assert tr.edges == {"R": {"I": tr.edges["R"]["I"]}}


def test_locktrack_disabled_factories_are_plain():
    import threading
    assert locktrack.tracker() is not None    # conftest enabled it
    lk = locktrack.lock("x")
    assert isinstance(lk, TrackedLock)
    locktrack.disable()
    try:
        assert isinstance(locktrack.lock("x"), type(threading.Lock()))
    finally:
        locktrack.enable()


# ------------------------------------------- unknown-kind black-hole path
def test_unknown_kind_is_reported_not_dropped():
    cfg = BBConfig(num_servers=2, num_clients=1, dram_capacity=1 << 20)
    with BurstBufferSystem(cfg) as sys_:
        c = sys_.clients[0]
        c.transport.send(c.tname, "server/0", "putt_typo", {"key": "k"})
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:
            if sys_.manager.errors:
                break
            time.sleep(0.02)
        assert any("putt_typo" in e.get("error", "")
                   for e in sys_.manager.errors), sys_.manager.errors
        stats = sys_.server_stats()
        assert stats["server/0"]["unknown_kinds"] == {"putt_typo": 1}
        # repeated strays bump the counter but report server_error once
        c.transport.send(c.tname, "server/0", "putt_typo", {"key": "k"})
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:
            stats = sys_.server_stats()
            if stats.get("server/0", {}).get("unknown_kinds", {}) \
                    .get("putt_typo") == 2:
                break
            time.sleep(0.02)
        assert stats["server/0"]["unknown_kinds"] == {"putt_typo": 2}
        n_errors = sum("putt_typo" in e.get("error", "")
                       for e in sys_.manager.errors)
        assert n_errors == 1
        # aggregate counter rides the drain_pressure report
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:
            servers = sys_.pressure()["servers"]
            if servers.get("server/0", {}).get("unknown_kinds") == 2:
                break
            time.sleep(0.05)
        assert sys_.pressure()["servers"]["server/0"]["unknown_kinds"] == 2


# ---------------------------------------------------------------- rule 6
def test_schema_typo_key_fires():
    vs = schema.check(trees(**{
        "server.py": """
            class FixServer:
                def _dispatch(self, msg):
                    handler = getattr(self, f"_on_{msg.kind}", None)

                def _on_put(self, msg):
                    v = msg.payload["value"]
                    lane = msg.payload.get("lane_idx")
            """,
        "client.py": """
            class FixClient:
                def go(self, server):
                    self.transport.send(self.tname, server, "put",
                                        {"value": b"v", "lane": 0})
            """}))
    assert any(v.ident == "typo:server:put:lane_idx" for v in vs), vs


def test_schema_injected_key_is_not_a_typo():
    vs = schema.check(trees(**{
        "server.py": """
            class FixServer:
                def _dispatch(self, msg):
                    handler = getattr(self, f"_on_{msg.kind}", None)

                def _on_put(self, msg):
                    if msg.payload.get("_stale"):
                        return
                    v = msg.payload["value"]

                def truncate(self):
                    for queued in self._laneq.entries():
                        queued.payload["_stale"] = True
            """,
        "client.py": """
            class FixClient:
                def go(self, server):
                    self.transport.send(self.tname, server, "put",
                                        {"value": b"v"})
            """}))
    assert vs == []


def test_schema_required_read_of_optional_key_fires():
    vs = schema.check(trees(**{
        "server.py": """
            class FixServer:
                def _dispatch(self, msg):
                    handler = getattr(self, f"_on_{msg.kind}", None)

                def _on_put(self, msg):
                    f = msg.payload["file"]
            """,
        "client.py": """
            class FixClient:
                def go(self, server):
                    self.transport.send(self.tname, server, "put",
                                        {"value": b"v", "file": "f"})
                    self.transport.send(self.tname, server, "put",
                                        {"value": b"v"})
            """}))
    assert any(v.ident == "optional:server:put:file" for v in vs), vs
    # .get with a default is the sanctioned escape
    vs = schema.check(trees(**{
        "server.py": """
            class FixServer:
                def _dispatch(self, msg):
                    handler = getattr(self, f"_on_{msg.kind}", None)

                def _on_put(self, msg):
                    f = msg.payload.get("file", None)
            """,
        "client.py": """
            class FixClient:
                def go(self, server):
                    self.transport.send(self.tname, server, "put",
                                        {"value": b"v", "file": "f"})
                    self.transport.send(self.tname, server, "put",
                                        {"value": b"v"})
            """}))
    assert not any(v.ident.startswith("optional:") for v in vs), vs


def test_schema_type_conflict_fires():
    vs = schema.check(trees(**{"client.py": """
        class FixClient:
            def a(self, server):
                self.transport.send(self.tname, server, "flush_begin",
                                    {"epoch": 1})

            def b(self, server):
                self.transport.send(self.tname, server, "flush_begin",
                                    {"epoch": "one"})
        """}))
    assert any(v.ident == "type:flush_begin:epoch" for v in vs), vs


def test_schema_clean_fixture_passes():
    vs = schema.check(trees(**{
        "server.py": """
            class FixServer:
                def _dispatch(self, msg):
                    handler = getattr(self, f"_on_{msg.kind}", None)

                def _on_put(self, msg):
                    k, v = msg.payload["key"], msg.payload["value"]
            """,
        "client.py": """
            class FixClient:
                def go(self, server):
                    self.transport.send(self.tname, server, "put",
                                        {"key": "k", "value": b"v"})
            """}))
    assert vs == []


# ---------------------------------------------------------------- rule 7
def test_epochs_zombie_table_fires():
    vs = epochs.check(trees(**{"m.py": """
        class Coord:
            def _on_flush_begin(self, msg):
                self._flush_epochs[msg.payload["epoch"]] = {"acked": set()}
        """}))
    assert any(v.ident == "zombie:Coord._flush_epochs" for v in vs), vs


def test_epochs_abort_path_makes_table_clean():
    vs = epochs.check(trees(**{"m.py": """
        class Coord:
            def _on_flush_begin(self, msg):
                self._flush_epochs[msg.payload["epoch"]] = {"acked": set()}

            def _on_flush_abort(self, msg):
                self._flush_epochs.pop(msg.payload["epoch"], None)
        """}))
    assert vs == []


def test_epochs_unguarded_abort_delete_fires():
    vs = epochs.check(trees(**{"m.py": """
        class Coord:
            def _on_flush_begin(self, msg):
                self._flush_epochs[msg.payload["epoch"]] = {"acked": set()}

            def _on_flush_abort(self, msg):
                del self._flush_epochs[msg.payload["epoch"]]
        """}))
    assert any(v.ident ==
               "abort-unguarded:Coord._flush_epochs:_on_flush_abort"
               for v in vs), vs


def test_epochs_create_unreachable_fires():
    vs = epochs.check(trees(**{"m.py": """
        class Coord:
            def tick(self):
                self._flush_epochs[1] = {"acked": set()}

            def _on_flush_abort(self, msg):
                self._flush_epochs.pop(msg.payload["epoch"], None)
        """}))
    assert any(v.ident == "create-unreachable:Coord._flush_epochs:tick"
               for v in vs), vs


def test_epochs_singleton_swap_abort_is_clean():
    """The swap-and-check idiom ``d, self._drain = self._drain, None`` is
    an idempotent abort-path delete, not a zombie."""
    vs = epochs.check(trees(**{"m.py": """
        class Coord:
            def _on_drain_request(self, msg):
                self._drain = {"epoch": 1, "done": set()}

            def _abort_drain(self, reason):
                d, self._drain = self._drain, None
                if d is None:
                    return
        """}))
    assert vs == []


def test_epochs_id_space_checks_fire():
    vs = epochs.check(trees(**{"m.py": """
        LOW_EPOCH_BASE = 1 << 20
        DUP_EPOCH_BASE = 1 << 30
        ALSO_DUP_EPOCH_BASE = 1 << 30

        class Coord:
            def __init__(self):
                self._next_drain_epoch = DUP_EPOCH_BASE
                self._next_stage_epoch = DUP_EPOCH_BASE
        """}))
    idents = {v.ident for v in vs}
    assert "id-low:LOW_EPOCH_BASE" in idents, vs
    assert "id-overlap:ALSO_DUP_EPOCH_BASE:DUP_EPOCH_BASE" in idents, vs
    assert "id-shared-base:Coord._next_stage_epoch" in idents, vs


def test_epochs_user_space_guard():
    bad = """
        DRAIN_EPOCH_BASE = 1 << 30

        class Coord:
            def begin_flush(self, epoch):
                self._user_flushes[epoch] = 1.0
        """
    vs = epochs.check(trees(**{"m.py": bad}))
    assert any(v.ident == "user-space-unchecked:Coord.begin_flush"
               for v in vs), vs
    good = """
        DRAIN_EPOCH_BASE = 1 << 30

        class Coord:
            def begin_flush(self, epoch):
                if epoch >= DRAIN_EPOCH_BASE:
                    raise ValueError(epoch)
                self._user_flushes[epoch] = 1.0

            def _on_flush_timeout(self, msg):
                self._user_flushes.pop(msg.payload["epoch"], None)
        """
    vs = epochs.check(trees(**{"m.py": good}))
    assert not any(v.ident.startswith("user-space-unchecked")
                   for v in vs), vs


# ---------------------------------------------------------------- rule 8
def test_ownership_multi_context_unguarded_fires():
    vs = ownership.check(trees(**{"m.py": """
        class Pump:
            def __init__(self):
                self._buf = []

            def run(self):
                self._buf.append(1)

            def push(self, x):
                self._buf.append(x)
        """}))
    assert any(v.ident == "unguarded:Pump._buf" for v in vs), vs


def test_ownership_common_lock_is_clean():
    vs = ownership.check(trees(**{"m.py": """
        class Pump:
            def __init__(self):
                self._lock = locktrack.lock("Pump._lock")
                self._buf = []

            def run(self):
                with self._lock:
                    self._buf.append(1)

            def push(self, x):
                with self._lock:
                    self._buf.append(x)
        """}))
    assert vs == []


def test_ownership_caller_held_lock_is_inferred():
    """A ``*_locked`` helper every call site enters with the lock held
    inherits it — the convention the client pipeline is built on."""
    vs = ownership.check(trees(**{"m.py": """
        class Pump:
            def __init__(self):
                self._lock = locktrack.lock("Pump._lock")
                self._buf = []

            def run(self):
                with self._lock:
                    self._add_locked(1)

            def push(self, x):
                with self._lock:
                    self._add_locked(x)

            def _add_locked(self, x):
                self._buf.append(x)
        """}))
    assert vs == []


def test_ownership_one_unlocked_call_site_defeats_inference():
    vs = ownership.check(trees(**{"m.py": """
        class Pump:
            def __init__(self):
                self._lock = locktrack.lock("Pump._lock")
                self._buf = []

            def run(self):
                with self._lock:
                    self._add_locked(1)

            def push(self, x):
                self._add_locked(x)

            def _add_locked(self, x):
                self._buf.append(x)
        """}))
    assert any(v.ident == "unguarded:Pump._buf" for v in vs), vs


def test_ownership_gil_annotation_is_honored():
    vs = ownership.check(trees(**{"m.py": """
        class Pump:
            def __init__(self):
                self._hits = 0   # bbcheck: shared=gil

            def run(self):
                self._hits = 1

            def poke(self):
                self._hits = 2
        """}))
    assert vs == []


def test_ownership_bad_annotation_fires():
    vs = ownership.check(trees(**{"m.py": """
        class Pump:
            def __init__(self):
                self._hits = 0   # bbcheck: shared=_no_such_lock

            def run(self):
                self._hits = 1

            def poke(self):
                self._hits = 2
        """}))
    assert any(v.ident == "bad-annotation:Pump._hits" for v in vs), vs


def test_ownership_stale_annotation_fires():
    vs = ownership.check(trees(**{"m.py": """
        class Pump:
            def __init__(self):
                self._hits = 0   # bbcheck: shared=gil

            def poke(self):
                self._hits = 2
        """}))
    assert any(v.ident == "stale-annotation:Pump._hits" for v in vs), vs


# --------------------------------------------------- locktrack artifact
def test_locktrack_dump_writes_postmortem_artifact(tmp_path):
    tr = LockOrderTracker()
    a = TrackedLock("A", tr)
    b = TrackedLock("B", tr)
    with a:
        with b:
            pass
    with b:
        with a:
            pass
    path = tr.dump(str(tmp_path / "inversions.json"))
    with open(path) as fh:
        report = json.load(fh)
    assert report["edges"]["A"]["B"]
    assert len(report["inversions"]) == 1
    inv = report["inversions"][0]
    assert inv["kind"] == "order-inversion"
    assert inv["stack"], "inversion must carry the recording stack"
    assert "MainThread" in report["threads"]


# --------------------------------------------------- generated registry
def test_protocol_md_regenerates_byte_identical():
    """docs/PROTOCOL.md is generated; CI fails when it drifts. This is
    the same comparison scripts/ci.sh --lint makes."""
    here = os.path.dirname(__file__)
    committed_path = os.path.join(here, "..", "docs", "PROTOCOL.md")
    with open(committed_path) as fh:
        committed = fh.read()
    regenerated = schema.render(parse_tree(os.path.join(here, "..",
                                                        DEFAULT_ROOT)))
    assert regenerated == committed, \
        "docs/PROTOCOL.md drifted — regenerate with " \
        "`python -m tools.bbcheck --emit-protocol docs/PROTOCOL.md`"


# ------------------------------------------------------------- real core
def test_core_is_clean_under_all_rules():
    """The committed state: every rule passes on src/repro/core with an
    EMPTY allowlist (the shrinking-only end state)."""
    import os
    root = os.path.join(os.path.dirname(__file__), "..", DEFAULT_ROOT)
    trees_ = parse_tree(root)
    assert len(trees_) >= 10
    from tools.bbcheck import ALL_RULES
    from tools.bbcheck.report import load_allowlist
    violations = []
    for rule in ALL_RULES:
        violations.extend(rule.check(trees_))
    allow = load_allowlist(DEFAULT_ALLOWLIST)
    assert allow == [], "allowlist must only ever shrink — and it is empty"
    new, _allowed, stale = apply_allowlist(violations, allow)
    assert new == [], "\n".join(str(v) for v in new)
    assert stale == []
