"""bbcheck (ISSUE 6): each rule fires on seeded-violation fixtures, the
allowlist is shrinking-only, the runtime lock tracker records inversions,
the server's unknown-kind black-hole detector reports instead of silently
dropping, and the real core passes every rule with an empty allowlist.
"""
import ast
import textwrap
import time

import pytest

from repro.core import locktrack
from repro.core.locktrack import LockOrderTracker, TrackedLock
from repro.core.system import BBConfig, BurstBufferSystem
from tools.bbcheck import blocking, clocks, literals, locks, protocol
from tools.bbcheck.__main__ import DEFAULT_ALLOWLIST, DEFAULT_ROOT, \
    parse_tree
from tools.bbcheck.report import Violation, apply_allowlist


def trees(**srcs):
    return {name: ast.parse(textwrap.dedent(src))
            for name, src in srcs.items()}


def rules_of(violations):
    return {v.rule for v in violations}


# ---------------------------------------------------------------- rule 1
DISPATCHER_SERVER = """
    class FixServer:
        def _dispatch(self, msg):
            handler = getattr(self, f"_on_{msg.kind}", None)
            if handler:
                handler(msg)

        def _on_put(self, msg):
            self.store[msg.payload["key"]] = msg.payload["value"]
"""


def test_protocol_unhandled_kind_fires():
    vs = protocol.check(trees(**{
        "server.py": DISPATCHER_SERVER,
        "client.py": """
            class FixClient:
                def go(self, server):
                    self.transport.send(self.tname, server, "putt",
                                        {"key": "k", "value": b"v"})
            """}))
    assert any(v.ident == "unhandled:putt:server" for v in vs), vs


def test_protocol_dead_handler_fires():
    vs = protocol.check(trees(**{"server.py": DISPATCHER_SERVER}))
    assert any(v.ident == "dead-handler:server:put" for v in vs), vs


def test_protocol_missing_payload_key_fires():
    vs = protocol.check(trees(**{
        "server.py": DISPATCHER_SERVER,
        "client.py": """
            class FixClient:
                def go(self, server):
                    self.transport.send(self.tname, server, "put",
                                        {"key": "k"})
            """}))
    assert any(v.ident == "missing-key:server:put:value" for v in vs), vs


def test_protocol_clean_fixture_passes():
    vs = protocol.check(trees(**{
        "server.py": DISPATCHER_SERVER,
        "client.py": """
            class FixClient:
                def go(self, server):
                    self.transport.send(self.tname, server, "put",
                                        {"key": "k", "value": b"v"})
            """}))
    assert vs == []


# ---------------------------------------------------------------- rule 2
def test_lock_cycle_fires():
    vs = locks.check(trees(**{"m.py": """
        class A:
            def f(self):
                with self._lock:
                    with self._op_lock:
                        pass

            def g(self):
                with self._op_lock:
                    with self._lock:
                        pass
        """}))
    assert any(v.ident.startswith("cycle:") for v in vs), vs


def test_lock_self_nesting_fires():
    vs = locks.check(trees(**{"m.py": """
        class A:
            def f(self):
                with self._lock:
                    with self._lock:
                        pass
        """}))
    assert any(v.ident.startswith("self-nest:") for v in vs), vs


def test_lock_ordered_nesting_passes():
    vs = locks.check(trees(**{"m.py": """
        class A:
            def f(self):
                with self._lock:
                    with self._op_lock:
                        pass

            def g(self):
                with self._lock:
                    with self._op_lock:
                        pass
        """}))
    assert vs == []


# ---------------------------------------------------------------- rule 3
def test_blocking_under_lock_fires():
    vs = blocking.check(trees(**{"m.py": """
        import time
        class A:
            def f(self):
                with self._lock:
                    time.sleep(0.5)
                    r = self.transport.request(self.ep, "x", "k", {})
                    m = self.ep.recv(timeout=1.0)
                    q = self.q.get(timeout=2.0)
        """}))
    msgs = [v.message for v in vs]
    assert len(vs) == 4, msgs
    assert any("time.sleep" in m for m in msgs)
    assert any("transport.request" in m for m in msgs)
    assert any("recv" in m for m in msgs)
    assert any("queue.get" in m for m in msgs)


def test_blocking_outside_lock_passes():
    vs = blocking.check(trees(**{"m.py": """
        import time
        class A:
            def f(self):
                with self._lock:
                    x = self.d.get("key")       # dict lookup: fine
                    y = self.q.get(timeout=0)   # non-blocking poll: fine
                time.sleep(0.5)                 # not under the lock
        """}))
    assert vs == []


# ---------------------------------------------------------------- rule 4
def test_direct_clock_fires_and_guard_passes():
    vs = clocks.check(trees(**{"m.py": """
        import time
        def bad():
            return time.monotonic()
        def also_bad():
            return time.time()
        def guarded(now=None):
            now = time.monotonic() if now is None else now
            return now
        def injected(self):
            return self._clock()
        """}))
    assert len(vs) == 2, vs
    assert {v.ident for v in vs} == {"time.monotonic:bad",
                                     "time.time:also_bad"}


# ---------------------------------------------------------------- rule 5
def test_literal_intervals_fire():
    vs = literals.check(trees(**{"m.py": """
        import time
        class A:
            def f(self, busy):
                self.ep.recv(timeout=0.05)
                self.ep.recv(timeout=0.0 if busy else 0.02)
                time.sleep(0.01)
                self.event.wait(0.25)
        """}))
    assert len(vs) == 4, vs


def test_configured_intervals_pass():
    vs = literals.check(trees(**{"m.py": """
        import time
        class A:
            def f(self):
                self.ep.recv(timeout=self.poll_interval)
                self.ep.recv(timeout=0)        # non-blocking: fine
                time.sleep(self.retry_interval)

            def g(self, timeout: float = 2.0):  # signature default: fine
                pass
        """}))
    assert vs == []


# ------------------------------------------------------------- allowlist
def test_allowlist_is_shrinking_only():
    v = Violation("clocks", "m.py", 3, "time.monotonic:f", "x")
    new, allowed, stale = apply_allowlist([v], [v.key])
    assert (new, allowed, stale) == ([], [v], [])
    new, allowed, stale = apply_allowlist([v], [])
    assert (new, allowed, stale) == ([v], [], [])
    # a fixed violation leaves its entry behind -> stale -> must fail
    new, allowed, stale = apply_allowlist([], [v.key])
    assert new == [] and stale == [v.key]


# ------------------------------------------------------- runtime tracker
def test_locktrack_records_inversion():
    tr = LockOrderTracker()
    a = TrackedLock("A", tr)
    b = TrackedLock("B", tr)
    with a:
        with b:
            pass
    assert tr.inversions == []
    with b:
        with a:
            pass
    assert len(tr.inversions) == 1
    inv = tr.inversions[0]
    assert inv["kind"] == "order-inversion"
    assert "B -> A" in inv["second"]


def test_locktrack_same_name_nesting_is_inversion():
    tr = LockOrderTracker()
    a1 = TrackedLock("Endpoint._lock", tr)
    a2 = TrackedLock("Endpoint._lock", tr)
    with a1:
        with a2:
            pass
    assert tr.inversions and tr.inversions[0]["kind"] == "same-name-nesting"


def test_locktrack_reentrant_and_clean_orders():
    tr = LockOrderTracker()
    r = TrackedLock("R", tr, reentrant=True)
    inner = TrackedLock("I", tr)
    with r:
        with r:                 # reentrant re-acquire: not a nesting event
            with inner:
                pass
    with r:
        with inner:
            pass
    assert tr.inversions == []
    assert tr.edges == {"R": {"I": tr.edges["R"]["I"]}}


def test_locktrack_disabled_factories_are_plain():
    import threading
    assert locktrack.tracker() is not None    # conftest enabled it
    lk = locktrack.lock("x")
    assert isinstance(lk, TrackedLock)
    locktrack.disable()
    try:
        assert isinstance(locktrack.lock("x"), type(threading.Lock()))
    finally:
        locktrack.enable()


# ------------------------------------------- unknown-kind black-hole path
def test_unknown_kind_is_reported_not_dropped():
    cfg = BBConfig(num_servers=2, num_clients=1, dram_capacity=1 << 20)
    with BurstBufferSystem(cfg) as sys_:
        c = sys_.clients[0]
        c.transport.send(c.tname, "server/0", "putt_typo", {"key": "k"})
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:
            if sys_.manager.errors:
                break
            time.sleep(0.02)
        assert any("putt_typo" in e.get("error", "")
                   for e in sys_.manager.errors), sys_.manager.errors
        stats = sys_.server_stats()
        assert stats["server/0"]["unknown_kinds"] == {"putt_typo": 1}
        # repeated strays bump the counter but report server_error once
        c.transport.send(c.tname, "server/0", "putt_typo", {"key": "k"})
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:
            stats = sys_.server_stats()
            if stats.get("server/0", {}).get("unknown_kinds", {}) \
                    .get("putt_typo") == 2:
                break
            time.sleep(0.02)
        assert stats["server/0"]["unknown_kinds"] == {"putt_typo": 2}
        n_errors = sum("putt_typo" in e.get("error", "")
                       for e in sys_.manager.errors)
        assert n_errors == 1
        # aggregate counter rides the drain_pressure report
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:
            servers = sys_.pressure()["servers"]
            if servers.get("server/0", {}).get("unknown_kinds") == 2:
                break
            time.sleep(0.05)
        assert sys_.pressure()["servers"]["server/0"]["unknown_kinds"] == 2


# ------------------------------------------------------------- real core
def test_core_is_clean_under_all_rules():
    """The committed state: every rule passes on src/repro/core with an
    EMPTY allowlist (the shrinking-only end state)."""
    import os
    root = os.path.join(os.path.dirname(__file__), "..", DEFAULT_ROOT)
    trees_ = parse_tree(root)
    assert len(trees_) >= 10
    from tools.bbcheck import ALL_RULES
    from tools.bbcheck.report import load_allowlist
    violations = []
    for rule in ALL_RULES:
        violations.extend(rule.check(trees_))
    allow = load_allowlist(DEFAULT_ALLOWLIST)
    assert allow == [], "allowlist must only ever shrink — and it is empty"
    new, _allowed, stale = apply_allowlist(violations, allow)
    assert new == [], "\n".join(str(v) for v in new)
    assert stale == []
