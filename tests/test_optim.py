"""Optimizers vs analytic references; gradient utilities."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adafactor import Adafactor
from repro.optim.adamw import AdamW
from repro.optim.grad import (clip_by_global_norm, compress_error_feedback,
                              compress_int8, decompress_int8, global_norm)
from repro.optim.schedule import constant, warmup_cosine


def test_adamw_matches_reference_math():
    opt = AdamW(lr=constant(0.1), b1=0.9, b2=0.99, eps=1e-8,
                weight_decay=0.0)
    p = {"w": jnp.asarray([[1.0, -2.0]], jnp.float32)}
    g = {"w": jnp.asarray([[0.5, 0.5]], jnp.float32)}
    state = opt.init(p)
    p1, state = opt.update(g, state, p)
    # step 1: mhat = g, vhat = g^2 -> delta = g/|g| = sign(g)
    np.testing.assert_allclose(np.asarray(p1["w"]),
                               [[1.0 - 0.1 * (0.5 / (0.5 + 1e-8)),
                                 -2.0 - 0.1 * (0.5 / (0.5 + 1e-8))]],
                               rtol=1e-5)


def test_adamw_converges_quadratic():
    opt = AdamW(lr=constant(0.05), weight_decay=0.0)
    p = {"w": jnp.asarray(5.0)}
    state = opt.init(p)

    @jax.jit
    def step(p, state):
        g = {"w": 2 * p["w"]}
        return opt.update(g, state, p)

    for _ in range(300):
        p, state = step(p, state)
    assert abs(float(p["w"])) < 1e-2


def test_adamw_bf16_state_dtype():
    opt = AdamW(lr=constant(0.1), state_dtype="bfloat16")
    p = {"w": jnp.zeros((4, 4), jnp.bfloat16)}
    state = opt.init(p)
    assert state.m["w"].dtype == jnp.bfloat16
    p2, state2 = opt.update({"w": jnp.ones((4, 4), jnp.bfloat16)}, state, p)
    assert state2.v["w"].dtype == jnp.bfloat16


def test_adafactor_factored_shapes():
    opt = Adafactor(lr=constant(0.01), momentum=0.9)
    p = {"w": jnp.zeros((8, 16)), "b": jnp.zeros((16,))}
    st = opt.init(p)
    assert st.vr["w"].shape == (8,)
    assert st.vc["w"].shape == (16,)
    assert st.vr["b"].shape == (16,)       # unfactored fallback
    assert st.m["w"].dtype == jnp.bfloat16


def test_adafactor_converges_quadratic():
    opt = Adafactor(lr=constant(0.2), momentum=0.0, weight_decay=0.0)
    p = {"w": jnp.full((4, 4), 3.0)}
    state = opt.init(p)

    @jax.jit
    def step(p, state):
        return opt.update({"w": 2 * p["w"]}, state, p)

    for _ in range(200):
        p, state = step(p, state)
    assert float(jnp.max(jnp.abs(p["w"]))) < 0.05


def test_clip_by_global_norm():
    tree = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(5.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_int8_compression_error_feedback_converges():
    """Error feedback keeps the long-run average unbiased."""
    g = {"w": jnp.asarray(np.linspace(-1, 1, 64), jnp.float32)}
    residual = jax.tree.map(jnp.zeros_like, g)
    acc = jnp.zeros(64)
    n = 40
    for _ in range(n):
        q, s, residual = compress_error_feedback(g, residual)
        acc = acc + decompress_int8(q, s)["w"]
    np.testing.assert_allclose(np.asarray(acc / n), np.asarray(g["w"]),
                               atol=2e-3)


def test_warmup_cosine_schedule_shape():
    lr = warmup_cosine(1.0, 10, 100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert float(lr(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(lr(jnp.asarray(100))) == pytest.approx(0.1, rel=1e-3)
    assert float(lr(jnp.asarray(55))) < 1.0
