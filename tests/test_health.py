"""Health engine (ISSUE 10): SLO rules, stall watchdogs, critical-path
attribution, the health_query protocol surface, and the bbstat/bbtop CLI
exit codes.

Unit tests drive a private HealthEngine with hand-built snapshots and a
fake clock; the end-to-end test injects a stalled drain epoch and an
fsync slowdown into a live system's engine and reads the diagnosis back
through ``BurstBufferSystem.health()`` and ``bbtop --once --json``."""
import json
import os
import time

import pytest

from repro.core import health, telemetry
from repro.core.health import HealthConfig, HealthEngine
from repro.core.system import BBConfig, BurstBufferSystem
from tools import bbstat, bbtop


class FakeClock:
    def __init__(self, t=100.0):
        self.t = t

    def __call__(self):
        return self.t


class FakeTracer:
    """Duck-typed stand-in for telemetry.Tracer: a fixed list of finished
    spans, with ``events_total`` offset so the engine's lifetime watermark
    sees exactly these as fresh."""

    def __init__(self, events, base=0):
        self._events = list(events)
        self._base = base

    def events_total(self):
        return self._base + len(self._events)

    def events(self):
        return list(self._events)


def _engine(**cfg):
    return HealthEngine(HealthConfig(**cfg), clock=FakeClock())


def _slo(report, rule):
    return next(s for s in report["slos"] if s["rule"] == rule)


# --------------------------------------------------------------- SLO rules

def test_slo_burn_rate_window_flags_fresh_regression():
    """An hour of healthy history must not average away a fresh slowdown:
    the rule evaluates the p99 of *this window's* samples only."""
    reg = telemetry.Registry(clock=FakeClock())
    h = reg.histogram("ckpt.save_s")
    for _ in range(500):
        h.observe(1e-4)                     # long healthy history
    eng = _engine()
    r1 = eng.evaluate(reg.snapshot(), now=0.0)
    assert _slo(r1, "ckpt_save_p99")["verdict"] == "ok"
    for _ in range(10):
        h.observe(9.0)                      # fresh regression, tiny count
    r2 = eng.evaluate(reg.snapshot(), now=1.0)
    s = _slo(r2, "ckpt_save_p99")
    assert s["verdict"] == "critical"       # 10 samples vs 500 healthy
    assert s["value"] >= s["critical"]
    assert s["window_count"] == 10
    assert r2["status"] == "critical"
    # an idle window is not evidence either way
    r3 = eng.evaluate(reg.snapshot(), now=2.0)
    s = _slo(r3, "ckpt_save_p99")
    assert s["verdict"] == "ok" and s["value"] is None


def test_slo_worst_label_reported():
    reg = telemetry.Registry(clock=FakeClock())
    h = reg.histogram("store.fsync_s")
    for _ in range(10):
        h.observe(1e-3, label="sync")
        h.observe(3.0, label="spill")
    eng = _engine()
    s = _slo(eng.evaluate(reg.snapshot(), now=0.0), "fsync_p99")
    assert s["verdict"] == "critical" and s["label"] == "spill"


def test_slo_occupancy_ring_and_queue_depth_poll():
    snapshot = {
        "rings": {"server.occupancy": [
            [0.0, "server/0", 0.5], [1.0, "server/0", 0.99],
            [1.0, "server/1", 0.3]]},
        "polls": {"server.ops": {
            "server/0": {"queued_puts": 7},
            "server/1": {"queued_puts": 600}}},
    }
    r = _engine().evaluate(snapshot, now=0.0)
    occ = _slo(r, "occupancy")
    assert occ["verdict"] == "critical"     # last sample wins: 0.99
    assert occ["label"] == "server/0" and occ["value"] == 0.99
    qd = _slo(r, "queue_depth")
    assert qd["verdict"] == "warn" and qd["label"] == "server/1"
    assert qd["value"] == 600.0


# --------------------------------------------------------------- watchdogs

def test_epoch_stall_floor_and_adaptive_limit():
    eng = _engine()
    # young histogram: the floor is the limit
    r = eng.evaluate({}, inflight={"drain": {"epoch": 3, "started": 0.0}},
                     now=2.5)
    wd = [w for w in r["watchdogs"] if w["kind"] == "epoch_stall"]
    assert len(wd) == 1 and wd[0]["verdict"] == "critical"
    assert wd[0]["phase"] == "drain" and wd[0]["epoch"] == 3
    assert wd[0]["age_s"] == 2.5
    assert wd[0]["limit_s"] == pytest.approx(2.0)   # stall_floor_s
    # with drain history the limit adapts to stall_factor x p99
    reg = telemetry.Registry(clock=FakeClock())
    h = reg.histogram("manager.drain_epoch_s")
    for _ in range(20):
        h.observe(5.0)
    snap = reg.snapshot()
    r = eng.evaluate(snap, inflight={"drain": {"epoch": 4, "started": 0.0}},
                     now=20.0)
    assert not [w for w in r["watchdogs"] if w["kind"] == "epoch_stall"]
    r = eng.evaluate(snap, inflight={"drain": {"epoch": 4, "started": 0.0}},
                     now=60.0)
    wd = [w for w in r["watchdogs"] if w["kind"] == "epoch_stall"]
    assert len(wd) == 1
    assert wd[0]["limit_s"] > 30.0          # 4 x p99(~9.9s), not the floor
    # a closed epoch clears the anomaly
    r = eng.evaluate(snap, inflight={}, now=61.0)
    assert not [w for w in r["watchdogs"] if w["kind"] == "epoch_stall"]


def _src_msgs(**totals):
    return {"counters": {"transport.src_msgs": dict(totals)}}


def test_silent_server_fires_only_while_peers_advance():
    eng = _engine(silent_evals=2)
    seq = [
        _src_msgs(**{"server/0": 10, "server/1": 10, "client/0": 99}),
        _src_msgs(**{"server/0": 20, "server/1": 10}),   # s1 stalls (1)
        _src_msgs(**{"server/0": 30, "server/1": 10}),   # s1 stalls (2)
    ]
    for snap in seq[:-1]:
        r = eng.evaluate(snap, now=0.0)
        assert not [w for w in r["watchdogs"]
                    if w["kind"] == "silent_server"]
    r = eng.evaluate(seq[-1], now=0.0)
    wd = [w for w in r["watchdogs"] if w["kind"] == "silent_server"]
    assert len(wd) == 1 and wd[0]["server"] == "server/1"
    assert wd[0]["verdict"] == "critical"
    assert wd[0]["stalled_evals"] == 2
    # recovery: the counter advances again and the anomaly clears
    r = eng.evaluate(_src_msgs(**{"server/0": 40, "server/1": 11}), now=0.0)
    assert not [w for w in r["watchdogs"] if w["kind"] == "silent_server"]


def test_silent_server_idle_cluster_exempt():
    eng = _engine(silent_evals=1)
    snap = _src_msgs(**{"server/0": 10, "server/1": 10})
    for _ in range(5):                      # nobody advances: no asymmetry
        r = eng.evaluate(snap, now=0.0)
        assert not [w for w in r["watchdogs"]
                    if w["kind"] == "silent_server"]


def test_queue_growth_requires_strict_monotonic_run():
    eng = _engine(queue_growth_evals=3)

    def snap(depth):
        return {"polls": {"server.ops": {"server/0":
                                         {"queued_puts": depth}}}}
    for d in (1, 2, 3):                     # growing, but run too short
        r = eng.evaluate(snap(d), now=0.0)
        assert not [w for w in r["watchdogs"]
                    if w["kind"] == "queue_growth"]
    r = eng.evaluate(snap(4), now=0.0)      # 4th strictly-growing step
    wd = [w for w in r["watchdogs"] if w["kind"] == "queue_growth"]
    assert len(wd) == 1 and wd[0]["verdict"] == "warn"
    assert wd[0]["server"] == "server/0" and wd[0]["depth"] == 4
    r = eng.evaluate(snap(4), now=0.0)      # plateau resets the run
    assert not [w for w in r["watchdogs"] if w["kind"] == "queue_growth"]


def test_anomaly_transitions_counted_once():
    """A wedge held across many evaluations is one flight-recorder event
    and one counter increment, not a flood."""
    eng = _engine()
    before = telemetry.snapshot().get("counters", {}).get(
        "health.anomalies", {}).get("epoch_stall", 0)
    inflight = {"drain": {"epoch": 9, "started": 0.0}}
    for i in range(5):
        eng.evaluate({}, inflight=inflight, now=10.0 + i)
    after = telemetry.snapshot()["counters"]["health.anomalies"][
        "epoch_stall"]
    assert after == before + 1
    # clearing and re-firing is a second transition
    eng.evaluate({}, inflight={}, now=16.0)
    eng.evaluate({}, inflight=inflight, now=17.0)
    assert telemetry.snapshot()["counters"]["health.anomalies"][
        "epoch_stall"] == before + 2


# -------------------------------------------- critical-path attribution

def _ev(trace, span, parent, name, dur):
    return (trace, span, parent, name, "c", 0.0, dur, {})


def test_attribution_decomposes_and_names_dominant_segment():
    eng = _engine()
    tr = FakeTracer([
        _ev(1, 1, 0, "diag.save", 10.0),            # root
        _ev(1, 2, 1, "store.fsync", 6.1),           # fsync segment
        _ev(1, 3, 1, "client.lane_wait", 1.0),      # queue segment
    ])
    eng.evaluate({}, tracer=tr, now=0.0)            # ingest
    r = eng.evaluate({}, tracer=tr, now=1.0)        # settle + finalize
    op = r["bottlenecks"]["ops"]["diag.save"]
    assert op["count"] == 1 and op["dominant"] == "fsync"
    assert op["segments"]["fsync"]["share"] == pytest.approx(0.61)
    assert op["segments"]["queue"]["share"] == pytest.approx(0.10)
    # root self time is the gap no handler span covers: network
    assert op["segments"]["network"]["share"] == pytest.approx(0.29)
    assert op["segments"]["service"]["share"] == 0.0
    assert "fsync is 61% of diag.save" in op["summary"]
    top = r["bottlenecks"]["top"]
    assert top["op"] == "diag.save" and top["segment"] == "fsync"


def test_attribution_uncovered_root_time_is_network():
    eng = _engine()
    tr = FakeTracer([
        _ev(2, 1, 0, "diag.put", 10.0),
        _ev(2, 2, 1, "server.put", 4.0),    # only 4s instrumented
    ])
    eng.evaluate({}, tracer=tr, now=0.0)
    r = eng.evaluate({}, tracer=tr, now=1.0)
    op = r["bottlenecks"]["ops"]["diag.put"]
    assert op["dominant"] == "network"
    assert op["segments"]["network"]["share"] == pytest.approx(0.6)
    assert op["segments"]["service"]["share"] == pytest.approx(0.4)


def test_attribution_waits_for_straggler_spans():
    """A trace is attributed one evaluation after its last span lands, so
    spans finishing across threads between cadences still count."""
    eng = _engine()
    root = _ev(3, 1, 0, "diag.op", 10.0)
    late = _ev(3, 2, 1, "store.fsync", 9.0)
    tr = FakeTracer([root])
    eng.evaluate({}, tracer=tr, now=0.0)
    tr2 = FakeTracer([late], base=tr.events_total())
    r = eng.evaluate({}, tracer=tr2, now=1.0)       # straggler: re-touched
    assert "diag.op" not in r["bottlenecks"]["ops"]
    r = eng.evaluate({}, tracer=tr2, now=2.0)       # now settled
    assert r["bottlenecks"]["ops"]["diag.op"]["dominant"] == "fsync"


# ------------------------------------------------- end-to-end diagnosis

def test_end_to_end_diagnosis_and_bbtop(tmp_path, capsys):
    """Acceptance (ISSUE 10): with a fake clock, an injected stalled
    drain epoch and an injected fsync slowdown are both flagged within
    one evaluation, the critical path names fsync dominant for the
    affected op kind, and ``bbtop --once --json`` renders the same
    verdicts (exit code 4 on critical) from the health_query payload."""
    cfg = BBConfig(num_servers=1, num_clients=1, dram_capacity=4 << 20)
    cfg.health.interval_s = 3600.0          # park the run-loop evaluator
    sys_ = BurstBufferSystem(cfg).start()
    try:
        eng = sys_.manager._health
        assert eng is not None
        deadline = time.time() + 10.0       # run loop's baseline pass
        while eng._evals == 0 and time.time() < deadline:
            time.sleep(0.01)
        assert eng._evals >= 1

        # inject: an fsync slowdown into the live registry...
        h = telemetry.histogram("store.fsync_s")
        for _ in range(50):
            h.observe(3.0, label="sync")
        # ...a drain epoch that has been open for 60 fake seconds...
        now = 1000.0
        inflight = {"drain": {"epoch": 7, "started": now - 60.0}}
        # ...and a span tree whose wall time is mostly fsync
        tr = FakeTracer([
            _ev(91, 1, 0, "diag.ckpt.save", 10.0),
            _ev(91, 2, 1, "store.fsync", 6.1),
            _ev(91, 3, 1, "client.lane_wait", 1.0),
        ], base=eng._events_seen)
        first = eng.evaluate(telemetry.snapshot(), inflight=inflight,
                             tracer=tr, now=now)
        # both faults flagged within ONE evaluation of being injected
        assert _slo(first, "fsync_p99")["verdict"] == "critical"
        assert [w for w in first["watchdogs"]
                if w["kind"] == "epoch_stall"]
        for _ in range(50):                 # slowdown persists into the
            h.observe(3.0, label="sync")    # next burn-rate window
        report = eng.evaluate(telemetry.snapshot(), inflight=inflight,
                              tracer=tr, now=now + 1.0)

        # (1) both injected faults flagged, within one evaluation each
        assert report["status"] == "critical"
        assert _slo(report, "fsync_p99")["verdict"] == "critical"
        stalls = [w for w in report["watchdogs"]
                  if w["kind"] == "epoch_stall"]
        assert stalls and stalls[0]["phase"] == "drain"
        # (2) the critical path names fsync dominant for the op kind
        op = report["bottlenecks"]["ops"]["diag.ckpt.save"]
        assert op["dominant"] == "fsync"
        assert op["segments"]["fsync"]["share"] == pytest.approx(0.61)

        # the protocol surface carries the same report
        via_query = sys_.health()
        assert via_query["status"] == "critical"
        assert via_query["evals"] == report["evals"]
        assert telemetry.TRACE_KEY not in via_query
        assert [s["verdict"] for s in via_query["slos"]] == \
            [s["verdict"] for s in report["slos"]]
        # ...and rides pressure_report for the drain engine's consumers
        assert sys_.pressure()["health"]["status"] == "critical"

        # bbtop --once --json renders the same verdicts, exit code 4
        doc = tmp_path / "health.json"
        doc.write_text(json.dumps(via_query))
        capsys.readouterr()
        rc = bbtop.main([str(doc), "--once", "--json"])
        frame = json.loads(capsys.readouterr().out)
        assert rc == 4
        assert frame["health"]["status"] == "critical"
        assert frame["health"]["bottlenecks"]["ops"][
            "diag.ckpt.save"]["dominant"] == "fsync"
        # human rendering of the same frame survives too
        assert bbtop.main([str(doc), "--once"]) == 4
        out = capsys.readouterr().out
        assert "status=CRITICAL" in out
        assert "fsync is 61% of diag.ckpt.save" in out
    finally:
        sys_.stop()


def test_health_query_one_server_cluster():
    sys_ = BurstBufferSystem(BBConfig(num_servers=1, num_clients=1,
                                      dram_capacity=4 << 20)).start()
    try:
        r = sys_.transport.request(
            sys_.clients[0].ep, "manager", "health_query", {},
            timeout=sys_.cfg.control_timeout)
        assert r is not None and r.kind == "health"
        for key in ("status", "evals", "slos", "watchdogs", "bottlenecks"):
            assert key in r.payload
        h = sys_.health()
        assert h["status"] in ("ok", "warn", "critical")
        assert {s["rule"] for s in h["slos"]} == \
            {rule[0] for rule in health.SLO_RULES}
    finally:
        sys_.stop()


def test_health_disabled_zero_overhead(monkeypatch):
    """With telemetry off the manager holds no engine at all and the
    query surface answers a static stub — no evaluator on the run loop."""
    monkeypatch.setattr(telemetry, "_registry", None)
    sys_ = BurstBufferSystem(BBConfig(num_servers=1, num_clients=1,
                                      dram_capacity=4 << 20)).start()
    try:
        assert sys_.manager._health is None
        h = sys_.health()
        assert h["status"] == "disabled" and h["evals"] == 0
        assert sys_.pressure()["health"]["status"] == "disabled"
    finally:
        sys_.stop()


# ------------------------------------------------- scrape vs dead server

def test_scrape_reports_killed_server_and_bbstat_exits_3(tmp_path, capsys):
    sys_ = BurstBufferSystem(BBConfig(num_servers=3, num_clients=1,
                                      dram_capacity=4 << 20)).start()
    try:
        f = sys_.fs().open("hk/data", "w", policy="batched")
        chunk = os.urandom(64 << 10)
        for i in range(8):
            f.pwrite(chunk, i * len(chunk))
        f.close(30.0)
        sys_.kill_server("server/1")
        t0 = time.time()
        doc = sys_.scrape()
        elapsed = time.time() - t0
        # dead server skipped via alive(), never awaited: bounded well
        # under the per-survivor control_timeout budget
        assert elapsed < sys_.cfg.control_timeout * len(sys_.servers)
        assert doc["expected"] == ["server/0", "server/1", "server/2"]
        assert doc["missing"] == ["server/1"]
        assert set(doc["servers"]) == {"server/0", "server/2"}
        # the partial scrape fails loud in bbstat, in both entrypoints
        assert bbstat.check_missing(doc) == 3
        assert "server/1" in capsys.readouterr().out
        path = tmp_path / "scrape.json"
        path.write_text(json.dumps(doc, default=repr))
        assert bbstat.main([str(path)]) == 3
        assert "MISSING servers: server/1" in capsys.readouterr().out
    finally:
        sys_.stop()


def test_bbstat_missing_exit_code_paths(capsys):
    # healthy scrape: exit 0
    healthy = {"expected": ["server/0"], "servers": {"server/0": {}},
               "missing": []}
    assert bbstat.check_missing(healthy) == 0
    # pre-ISSUE-10 document without membership fields passes vacuously
    assert bbstat.check_missing({"registry": {}}) == 0
    # fallback: expected minus answering set when "missing" is absent
    legacy = {"expected": ["server/0", "server/1"],
              "servers": {"server/0": {}}}
    assert bbstat.check_missing(legacy) == 3
    assert "server/1" in capsys.readouterr().out


def test_bbtop_accepts_all_document_shapes():
    bare = {"status": "ok", "evals": 1, "t": 0.0, "slos": [],
            "watchdogs": [], "bottlenecks": {"ops": {}, "top": None}}
    assert bbtop.as_frame(bare)["health"] is bare
    pressure = {"health": bare, "servers": {"server/0": {"fraction": 0.5}}}
    frame = bbtop.as_frame(pressure)
    assert frame["health"] is bare
    assert frame["pressure"]["servers"]["server/0"]["fraction"] == 0.5
    wrapped = {"health": bare, "pressure": None}
    assert bbtop.as_frame(wrapped)["health"] is bare
    with pytest.raises(ValueError):
        bbtop.as_frame({"registry": {}})
