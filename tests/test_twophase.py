"""Property tests for two-phase I/O planning (paper §III-B)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.twophase import (Segment, domains, file_sizes, owner_of,
                                 plan_shuffle, split_segment)


@st.composite
def segment_layout(draw):
    """Random non-overlapping segment layout of one file, possibly spread
    over several source servers."""
    n_seg = draw(st.integers(1, 20))
    sizes = draw(st.lists(st.integers(1, 1 << 18), min_size=n_seg,
                          max_size=n_seg))
    n_src = draw(st.integers(1, 6))
    offsets = np.cumsum([0] + sizes[:-1]).tolist()
    segs = [Segment("f", o, s) for o, s in zip(offsets, sizes)]
    owner = [draw(st.integers(0, n_src - 1)) for _ in segs]
    return segs, owner, n_src


@given(segment_layout(), st.integers(1, 9))
@settings(max_examples=60, deadline=None)
def test_domains_partition_exactly(layout, n_servers):
    segs, _, _ = layout
    size = file_sizes(segs)["f"]
    servers = [f"s{i}" for i in range(n_servers)]
    doms = domains(size, servers)
    assert doms[0][1] == 0 and doms[-1][2] == size
    for (s1, a1, b1), (s2, a2, b2) in zip(doms, doms[1:]):
        assert b1 == a2                     # contiguous, no gaps/overlap
    for _, a, b in doms:
        assert a <= b


@given(segment_layout(), st.integers(1, 9))
@settings(max_examples=40, deadline=None)
def test_split_segment_covers_exactly(layout, n_servers):
    segs, _, _ = layout
    size = file_sizes(segs)["f"]
    doms = domains(size, [f"s{i}" for i in range(n_servers)])
    for seg in segs:
        pieces = split_segment(seg, doms)
        total = sum(l for _, _, _, l in pieces)
        assert total == seg.length
        # pieces are contiguous in file space and land in the right domain
        pos = seg.offset
        for owner, file_off, local_off, length in pieces:
            assert file_off == pos
            assert local_off == pos - seg.offset
            assert owner_of(file_off, doms) == owner
            pos += length


@given(segment_layout(), st.integers(1, 6), st.integers(0, 2**31))
@settings(max_examples=30, deadline=None)
def test_shuffle_reassembles_exact_bytes(layout, n_servers, seed):
    """End-to-end plan: scatter random bytes over sources, shuffle to domain
    owners, reassemble — must equal the original file content."""
    segs, owner, n_src = layout
    rng = np.random.default_rng(seed % 2**32)
    servers = [f"srv{i}" for i in range(n_servers)]
    payload = {s: rng.integers(0, 256, s.length, dtype=np.uint8).tobytes()
               for s in segs}
    all_meta = {f"src{i}": [s for s, o in zip(segs, owner) if o == i]
                for i in range(n_src)}
    size = file_sizes(segs)["f"]
    expect = bytearray(size)
    for s in segs:
        expect[s.offset:s.offset + s.length] = payload[s]

    got = bytearray(size)
    for i in range(n_src):
        mine = all_meta[f"src{i}"]
        sizes, doms, sends = plan_shuffle(mine, all_meta, servers)
        assert sizes["f"] == size
        for owner_srv, seg, file_off, local_off, length in sends:
            got[file_off:file_off + length] = \
                payload[seg][local_off:local_off + length]
    assert bytes(got) == bytes(expect)


def test_domains_stripe_aligned():
    doms = domains(10 << 20, ["a", "b", "c"])
    for _, a, _ in doms[1:]:
        assert a % (1 << 20) == 0           # 1 MiB (Lustre stripe) aligned
