"""Property tests for two-phase I/O planning (paper §III-B)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.twophase import (Segment, domains, file_sizes, owner_of,
                                 plan_shuffle, split_segment)


@st.composite
def segment_layout(draw):
    """Random non-overlapping segment layout of one file, possibly spread
    over several source servers."""
    n_seg = draw(st.integers(1, 20))
    sizes = draw(st.lists(st.integers(1, 1 << 18), min_size=n_seg,
                          max_size=n_seg))
    n_src = draw(st.integers(1, 6))
    offsets = np.cumsum([0] + sizes[:-1]).tolist()
    segs = [Segment("f", o, s) for o, s in zip(offsets, sizes)]
    owner = [draw(st.integers(0, n_src - 1)) for _ in segs]
    return segs, owner, n_src


@given(segment_layout(), st.integers(1, 9))
@settings(max_examples=60, deadline=None)
def test_domains_partition_exactly(layout, n_servers):
    segs, _, _ = layout
    size = file_sizes(segs)["f"]
    servers = [f"s{i}" for i in range(n_servers)]
    doms = domains(size, servers)
    assert doms[0][1] == 0 and doms[-1][2] == size
    for (s1, a1, b1), (s2, a2, b2) in zip(doms, doms[1:]):
        assert b1 == a2                     # contiguous, no gaps/overlap
    for _, a, b in doms:
        assert a <= b


@given(segment_layout(), st.integers(1, 9))
@settings(max_examples=40, deadline=None)
def test_split_segment_covers_exactly(layout, n_servers):
    segs, _, _ = layout
    size = file_sizes(segs)["f"]
    doms = domains(size, [f"s{i}" for i in range(n_servers)])
    for seg in segs:
        pieces = split_segment(seg, doms)
        total = sum(l for _, _, _, l in pieces)
        assert total == seg.length
        # pieces are contiguous in file space and land in the right domain
        pos = seg.offset
        for owner, file_off, local_off, length in pieces:
            assert file_off == pos
            assert local_off == pos - seg.offset
            assert owner_of(file_off, doms) == owner
            pos += length


@given(segment_layout(), st.integers(1, 6), st.integers(0, 2**31))
@settings(max_examples=30, deadline=None)
def test_shuffle_reassembles_exact_bytes(layout, n_servers, seed):
    """End-to-end plan: scatter random bytes over sources, shuffle to domain
    owners, reassemble — must equal the original file content."""
    segs, owner, n_src = layout
    rng = np.random.default_rng(seed % 2**32)
    servers = [f"srv{i}" for i in range(n_servers)]
    payload = {s: rng.integers(0, 256, s.length, dtype=np.uint8).tobytes()
               for s in segs}
    all_meta = {f"src{i}": [s for s, o in zip(segs, owner) if o == i]
                for i in range(n_src)}
    size = file_sizes(segs)["f"]
    expect = bytearray(size)
    for s in segs:
        expect[s.offset:s.offset + s.length] = payload[s]

    got = bytearray(size)
    for i in range(n_src):
        mine = all_meta[f"src{i}"]
        sizes, doms, sends = plan_shuffle(mine, all_meta, servers)
        assert sizes["f"] == size
        for owner_srv, seg, file_off, local_off, length in sends:
            got[file_off:file_off + length] = \
                payload[seg][local_off:local_off + length]
    assert bytes(got) == bytes(expect)


def test_domains_stripe_aligned():
    doms = domains(10 << 20, ["a", "b", "c"])
    for _, a, _ in doms[1:]:
        assert a % (1 << 20) == 0           # 1 MiB (Lustre stripe) aligned


# -------------------------- segment-subset planning (ISSUE 3 drain epochs)

@given(segment_layout(), st.integers(1, 9), st.integers(0, 2**31))
@settings(max_examples=40, deadline=None)
def test_domains_full_coverage_alignment_no_overlap(layout, n_servers, _seed):
    """Invariants for any layout: [0, size) covered exactly once, every
    interior boundary 1 MiB aligned, no negative-width domain."""
    segs, _, _ = layout
    size = file_sizes(segs)["f"]
    doms = domains(size, [f"s{i}" for i in range(n_servers)])
    assert doms[0][1] == 0 and doms[-1][2] == size
    pos = 0
    for s, a, b in doms:
        assert a == pos and a <= b          # contiguous, no overlap
        pos = b
    for _, a, _ in doms[1:]:
        assert a % (1 << 20) == 0


@given(segment_layout(), st.integers(1, 9))
@settings(max_examples=40, deadline=None)
def test_split_segment_pieces_disjoint_and_ordered(layout, n_servers):
    segs, _, _ = layout
    size = file_sizes(segs)["f"]
    doms = domains(size, [f"s{i}" for i in range(n_servers)])
    for seg in segs:
        pieces = split_segment(seg, doms)
        for (_, o1, _, l1), (_, o2, _, _) in zip(pieces, pieces[1:]):
            assert o1 + l1 == o2            # adjacent, never overlapping
        assert all(l > 0 for _, _, _, l in pieces)


@given(segment_layout(), st.integers(2, 6), st.integers(0, 2**31))
@settings(max_examples=40, deadline=None)
def test_subset_plan_agrees_with_full_size_domains(layout, n_servers, seed):
    """Drain micro-epochs plan over a cold SUBSET of a file's segments.
    With known_sizes pinning the file's true size, every piece's owner must
    agree with the owner computed from the FULL-size domain partition —
    otherwise a drain would write bytes to a different server than earlier
    full flushes did, corrupting the PFS layout."""
    segs, owner, n_src = layout
    rng = np.random.default_rng(seed % 2**32)
    servers = [f"srv{i}" for i in range(n_servers)]
    full_size = file_sizes(segs)["f"]
    subset = [s for s in segs if rng.random() < 0.5] or segs[:1]
    all_meta = {"src0": subset}
    sizes, doms, sends = plan_shuffle(subset, all_meta, servers,
                                      known_sizes={"f": full_size})
    assert sizes["f"] == full_size
    full_doms = domains(full_size, servers)
    covered = 0
    for owner_srv, seg, file_off, local_off, length in sends:
        assert owner_of(file_off, full_doms) == owner_srv
        assert 0 <= local_off and local_off + length <= seg.length
        covered += length
    assert covered == sum(s.length for s in subset)   # subset covered once


def test_subset_plan_deterministic_example():
    """Deterministic fallback for the subset invariant (runs without
    hypothesis): a 5 MiB file where only the middle segment drains."""
    servers = ["a", "b", "c"]
    full = [Segment("f", 0, 2 << 20), Segment("f", 2 << 20, 1 << 20),
            Segment("f", 3 << 20, 2 << 20)]
    full_size = file_sizes(full)["f"]
    subset = [full[1]]
    sizes, doms, sends = plan_shuffle(subset, {"src": subset}, servers,
                                      known_sizes={"f": full_size})
    assert sizes["f"] == full_size          # pinned, not the 3 MiB extent
    assert doms["f"] == domains(full_size, servers)
    assert sum(l for *_, l in sends) == 1 << 20
    full_doms = domains(full_size, servers)
    for owner_srv, seg, file_off, _local, length in sends:
        assert owner_of(file_off, full_doms) == owner_srv
    # without known_sizes the same subset would plan 3 MiB domains and
    # disagree with the durable layout
    sizes2, doms2, _ = plan_shuffle(subset, {"src": subset}, servers)
    assert sizes2["f"] == 3 << 20
    assert doms2["f"] != doms["f"]


def test_known_sizes_never_shrink_a_file():
    """A stale (smaller) known size must lose to the epoch's own extent."""
    seg = [Segment("f", 0, 4 << 20)]
    sizes, _, _ = plan_shuffle(seg, {"s": seg}, ["a", "b"],
                               known_sizes={"f": 1 << 20})
    assert sizes["f"] == 4 << 20
