"""Autonomous drain engine (ISSUE 3): watermark policy, burst deferral,
token-bucket bandwidth, tombstone eviction, transparent read-after-evict,
and the fault-injection surface — kill a server mid-drain (the epoch must
abort, nothing evicted, re-drain from replicas), crash after the epoch
completed (no data loss, no double-free), rewrite-during-drain (the write
generation guard must keep the fresh bytes)."""
import threading
import time

import numpy as np
import pytest

from repro.core import (BBConfig, BurstBufferSystem, DrainConfig,
                        DrainEngine, Transport)
from repro.core.server import BBServer
from repro.core.tiering import LogStore
from repro.core.transport import Message


# ------------------------------------------------------------ policy units

def _cfg(**kw):
    base = dict(high_watermark=0.6, low_watermark=0.3, panic_watermark=0.9,
                request_interval=0.0, burst_window_s=1.0,
                hot_bytes_per_s=1000, bw_bytes_per_s=1000)
    base.update(kw)
    return DrainConfig(**base)


def test_engine_watermark_hysteresis():
    eng = DrainEngine(_cfg(), now=0.0)
    assert not eng.update(0.5, now=1.0)          # below high: idle
    assert eng.update(0.7, now=2.0)              # crossed high: drain
    assert eng.draining
    assert eng.update(0.45, now=3.0)             # between watermarks: keep
    assert not eng.update(0.2, now=4.0)          # fell to low: stop
    assert not eng.draining
    assert not eng.update(0.45, now=5.0)         # between, from below: idle


def test_engine_burst_defers_until_panic():
    eng = DrainEngine(_cfg(), now=0.0)
    eng.note_ingest(5000, now=10.0)              # 5000 B/s >> hot threshold
    assert eng.hot(now=10.0)
    assert not eng.update(0.7, now=10.0)         # hot: absorption wins
    assert eng.stats["deferred_hot"] == 1
    assert eng.update(0.95, now=10.0)            # panic: space wins
    assert not eng.hot(now=12.0)                 # window slid past the burst
    assert eng.update(0.7, now=12.0)


def test_engine_request_rate_limit():
    eng = DrainEngine(_cfg(request_interval=0.5), now=0.0)
    assert eng.update(0.7, now=1.0)
    eng.note_requested(now=1.0)
    assert not eng.update(0.7, now=1.2)          # inside the interval
    assert eng.update(0.7, now=1.6)


def test_engine_token_bucket_caps_and_refunds():
    eng = DrainEngine(_cfg(bw_bytes_per_s=1000), now=0.0)
    assert eng.peek(now=0.0) == 1000             # starts full
    assert eng.take(700, now=0.0) == 700
    # overdraft: the full selection is debited (one segment may exceed the
    # remainder) and the refill must pay the debt back before peek() > 0
    assert eng.take(700, now=0.0) == 700
    assert eng.peek(now=0.0) == 0
    assert eng.peek(now=0.3) == 0                # 300 refilled, still in debt
    assert eng.peek(now=0.5) == 100              # debt (-400) + 500 refill
    # refund is symmetric with take: an aborted epoch gives back exactly
    # what was debited, clamped at one bucket
    eng.refund(700)
    assert eng.peek(now=0.5) == 800
    eng.refund(700)
    assert eng.peek(now=0.5) == 1000             # clamped at bucket size


# ----------------------------------------------------------- LogStore units

def test_logstore_evict_tombstone_idempotent(tmp_path):
    store = LogStore(1 << 20, str(tmp_path), name="ev0")
    store.put("k", b"x" * 1000)
    assert store.evict("k") == 1000
    assert store.get("k") is None
    assert store.tier_of("k") == "pfs" and store.was_evicted("k")
    assert "k" not in store and "k" not in store.keys()
    # double eviction frees 0 — accounting can never double-free
    assert store.evict("k") == 0
    assert store.evict("missing") == 0
    store.compact()
    assert store.dram_used >= 0 and store.ssd_used >= 0


def test_logstore_cold_keys_age_order(tmp_path):
    store = LogStore(256 << 10, str(tmp_path), name="ev1",
                     segment_bytes=64 << 10)
    for i in range(12):                          # 768 KB: oldest spill to SSD
        store.put(f"k{i}", b"a" * (64 << 10))
    cold = store.cold_keys()
    assert cold, "sealed segments must be drainable"
    tiers = [store.tier_of(k) for k, _ in cold]
    assert "ssd" in tiers
    first_dram = tiers.index("dram") if "dram" in tiers else len(tiers)
    assert all(t == "ssd" for t in tiers[:first_dram]), \
        "SSD-resident (oldest) keys must come first"
    open_seg_keys = [k for k, loc in store._index.items()
                     if loc.tier == "dram" and loc.segment == store._open_seg]
    assert not set(k for k, _ in cold) & set(open_seg_keys), \
        "the open segment never drains"
    # a tombstone is not a candidate
    victim = cold[0][0]
    store.evict(victim)
    assert victim not in [k for k, _ in store.cold_keys()]


# ---------------------------------------------- single-server protocol units

def _solo_server(tmp_path, **drain_kw):
    tr = Transport()
    drain = DrainConfig(**drain_kw) if drain_kw else DrainConfig()
    # tiny segments: a single put seals its segment, making it cold/drainable
    srv = BBServer("s0", tr, dram_capacity=1 << 20, segment_bytes=256,
                   ssd_dir=str(tmp_path), replication=1, drain=drain)
    srv.ring, srv.alive = ["s0"], {"s0": True}
    return tr, srv


def _msg(kind, payload, src="t"):
    return Message(kind, src, "s0", payload, msg_id=1)


def test_rewrite_during_drain_is_not_evicted(tmp_path):
    """The write-generation guard: a key rewritten between the drain epoch's
    snapshot and the evict broadcast holds FRESHER bytes than the PFS —
    evicting it would lose the rewrite."""
    tr, srv = _solo_server(tmp_path)
    srv.drainer.draining = True
    srv._on_put(_msg("put", {"key": "f:0", "value": b"old" * 100,
                             "file": "f", "offset": 0, "chain": []}))
    srv._on_flush_begin(_msg("flush_begin", {"epoch": 1 << 30,
                                             "drain": True}))
    assert "f:0" in srv._drain_epochs[1 << 30]["keys"]
    srv._on_put(_msg("put", {"key": "f:0", "value": b"new" * 100,
                             "file": "f", "offset": 0, "chain": []}))
    srv._on_drain_evict(_msg("drain_evict", {"epoch": 1 << 30,
                                             "keys": ["f:0"]}))
    assert srv.store.get("f:0") == b"new" * 100, \
        "rewritten key must survive the stale evict"
    assert srv.stats["evictions"] == 0


def test_unchanged_key_is_evicted_with_tombstone(tmp_path):
    tr, srv = _solo_server(tmp_path)
    srv.drainer.draining = True
    srv._on_put(_msg("put", {"key": "f:0", "value": b"cold" * 100,
                             "file": "f", "offset": 0, "chain": []}))
    srv._on_flush_begin(_msg("flush_begin", {"epoch": 1 << 30,
                                             "drain": True}))
    srv._on_drain_evict(_msg("drain_evict", {"epoch": 1 << 30,
                                             "keys": ["f:0"]}))
    assert srv.store.get("f:0") is None
    assert srv.store.was_evicted("f:0")
    assert srv._evicted["f:0"] == ("f", 0, 400)
    assert srv.stats["evictions"] == 1
    # replaying the evict is a no-op (no double-free of accounting)
    srv._on_drain_evict(_msg("drain_evict", {"epoch": 1 << 30,
                                             "keys": ["f:0"]}))
    assert srv.stats["evictions"] == 1


def test_flush_abort_refunds_budget_and_keeps_chunks(tmp_path):
    """An aborted micro-epoch (death/timeout mid-drain) must leave every
    chunk buffered and give the token-bucket budget back."""
    tr, srv = _solo_server(tmp_path, bw_bytes_per_s=1 << 20)
    srv.drainer.draining = True
    srv._on_put(_msg("put", {"key": "f:0", "value": b"z" * 1000,
                             "file": "f", "offset": 0, "chain": []}))
    before = srv.drainer.peek()
    srv._on_flush_begin(_msg("flush_begin", {"epoch": 1 << 30,
                                             "drain": True}))
    assert srv.drainer.peek() < before           # budget consumed
    srv._on_flush_abort(_msg("flush_abort", {"epoch": 1 << 30,
                                             "reason": "test"}))
    assert not srv._drain_epochs and (1 << 30) not in srv._flush
    assert srv.store.get("f:0") == b"z" * 1000   # nothing evicted
    assert srv.drainer.peek() == before          # budget refunded
    assert srv.drainer.stats["refunded_bytes"] == 1000
    # a straggler flush_meta/shuffle_done for the aborted epoch must not
    # resurrect the epoch state (a zombie entry would wedge self._flush)
    srv._on_flush_meta(_msg("flush_meta", {"epoch": 1 << 30, "from": "peer",
                                           "metas": [], "sizes": {}}))
    srv._on_shuffle_done(_msg("shuffle_done", {"epoch": 1 << 30,
                                               "from": "peer", "sizes": {}}))
    assert (1 << 30) not in srv._flush


# ------------------------------------------------------------- integration

def _drain_system(num=3, dram=1 << 20, **drain_kw):
    dk = dict(high_watermark=0.5, low_watermark=0.25,
              request_interval=0.02, pressure_interval=0.05,
              max_epoch_bytes=2 << 20, epoch_timeout_s=5.0)
    dk.update(drain_kw)
    return BurstBufferSystem(BBConfig(
        num_servers=num, num_clients=num, placement="iso",
        dram_capacity=dram, ssd_capacity=2 * dram,
        segment_bytes=128 << 10, chunk_bytes=64 << 10,
        stabilize_interval=0.15, drain=DrainConfig(**dk))).start()


def _write(sys_, path, nbytes, seed=0):
    data = np.random.default_rng(seed).integers(
        0, 256, nbytes, dtype=np.uint8).tobytes()
    f = sys_.fs().open(path, "w", policy="batched")
    f.pwrite(data, 0)
    f.close(60.0)
    return data


def _wait_drained(sys_, timeout=20.0, epochs=1):
    high = sys_.cfg.drain.high_watermark
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        pr = sys_.pressure()
        fracs = [s.get("fraction", 1.0) for s in pr["servers"].values()]
        if pr["drain"]["epochs"] >= epochs and fracs and max(fracs) < high:
            return pr
        time.sleep(0.1)
    return sys_.pressure()


def test_drain_bounds_occupancy_and_reads_stay_byte_exact():
    """The acceptance scenario: ingest past DRAM capacity, let the drainer
    work, and verify occupancy fell below the high watermark while a pread
    of the (mostly evicted) file returns exactly what was written."""
    sys_ = _drain_system()
    try:
        data = _write(sys_, "big", 6 << 20)      # 2x aggregate DRAM
        pr = _wait_drained(sys_)
        assert pr["drain"]["epochs"] >= 1, f"no drain ran: {pr}"
        assert max(s["fraction"] for s in pr["servers"].values()) \
            < sys_.cfg.drain.high_watermark
        got = sys_.fs().open("big", "r").pread(0, len(data))
        assert got == data
        assert sys_.manager.errors == []
    finally:
        sys_.stop()


def test_get_of_evicted_key_falls_through_transparently():
    """client.get of a drained-and-evicted key must return the original
    bytes via the tombstone's residency record — clients never observe
    eviction."""
    sys_ = _drain_system()
    try:
        chunk = sys_.cfg.chunk_bytes
        data = _write(sys_, "ev", 6 << 20, seed=1)
        _wait_drained(sys_)
        evicted = [(srv, k) for srv in sys_.servers.values()
                   for k in srv._evicted if k.startswith("ev:")]
        assert evicted, "expected at least one evicted chunk"
        _, key = evicted[0]
        off = int(key.split(":")[1])
        j = off // chunk                        # BBFile round-robins chunks
        c = sys_.clients[j % len(sys_.clients)]
        got = c.get(key)
        assert got == data[off:off + len(got or b"")] and got, \
            f"evicted get for {key} returned {type(got)}"
        assert c.stats["evicted_reads"] >= 1 or c.stats["bb_hits"] >= 1
    finally:
        sys_.stop()


def test_fs_stat_residency_tracks_the_drain():
    sys_ = _drain_system()
    try:
        data = _write(sys_, "res", 6 << 20, seed=2)
        _wait_drained(sys_)
        st = sys_.fs().stat("res")
        assert st["size"] == len(data)
        assert st["residency"]["pfs"] > 0, st
        buffered = st["residency"]["dram"] + st["residency"]["ssd"]
        assert buffered + st["residency"]["pfs"] >= len(data), \
            "every byte must be accounted to a tier (replicas included)"
        assert st["evicted_chunks"] > 0
    finally:
        sys_.stop()


def test_kill_server_mid_drain_aborts_then_redrains_from_replicas():
    """Fault injection: a server dies while a drain micro-epoch is in
    flight. The manager must abort (nothing evicted off the dead plan),
    survivors keep their replica copies, and later micro-epochs re-drain
    them — with every byte still readable."""
    sys_ = _drain_system(epoch_timeout_s=3.0)
    try:
        caught = threading.Event()

        def _assassin():
            deadline = time.monotonic() + 15.0
            while time.monotonic() < deadline and not caught.is_set():
                d = sys_.manager._drain
                if d is not None:
                    victim = sorted(d["expected"])[-1]
                    sys_.kill_server(victim)
                    caught.set()
                    return
        killer = threading.Thread(target=_assassin, daemon=True)
        killer.start()
        data = _write(sys_, "mid", 6 << 20, seed=3)
        killer.join(20.0)
        assert caught.is_set(), "no drain epoch was ever in flight"
        # the epoch must abort (timeout or failure report), then re-drain
        deadline = time.monotonic() + 20.0
        while time.monotonic() < deadline:
            st = sys_.manager.drain_stats
            if st["aborts"] >= 1 and st["epochs"] >= 1:
                break
            time.sleep(0.1)
        st = sys_.manager.drain_stats
        assert st["aborts"] >= 1, f"mid-drain death did not abort: {st}"
        assert st["epochs"] >= 1, f"survivors never re-drained: {st}"
        got = sys_.fs().open("mid", "r").pread(0, len(data))
        assert got == data, "data lost across mid-drain failover"
    finally:
        sys_.stop()


def test_crash_after_drain_completes_loses_nothing():
    """Fault injection: a server crashes AFTER a micro-epoch completed (its
    PFS writes are durable, eviction already broadcast). Everything must
    remain readable through replicas + the PFS, with sane accounting on the
    survivors."""
    sys_ = _drain_system()
    try:
        data = _write(sys_, "post", 6 << 20, seed=4)
        pr = _wait_drained(sys_)
        assert pr["drain"]["epochs"] >= 1
        sys_.kill_server("server/1")
        deadline = time.monotonic() + 8.0
        while time.monotonic() < deadline \
                and "server/1" not in sys_.manager.dead:
            time.sleep(0.05)
        got = sys_.fs().open("post", "r").pread(0, len(data))
        assert got == data
        for name, srv in sys_.servers.items():
            if name == "server/1":
                continue
            occ = srv.store.occupancy()
            assert occ["dram_used"] >= 0 and occ["ssd_used"] >= 0, \
                f"negative accounting on {name} (double-free)"
    finally:
        sys_.stop()


def test_manager_pressure_stats_populated():
    sys_ = _drain_system()
    try:
        _write(sys_, "pp", 1 << 20, seed=5)
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline \
                and len(sys_.pressure()["servers"]) < 3:
            time.sleep(0.05)
        pr = sys_.pressure()
        assert len(pr["servers"]) == 3
        for s, rep in pr["servers"].items():
            assert {"fraction", "dram_used", "ssd_used",
                    "draining"} <= set(rep)
        assert {"epochs", "aborts", "evicted_keys",
                "drained_bytes"} <= set(pr["drain"])
    finally:
        sys_.stop()


def test_concurrent_writers_read_your_writes_under_drain():
    """Stress (ISSUE 3 satellite): writers streaming through BBFile handles
    while the drainer evicts underneath them. Every synced prefix must read
    back byte-exact at all times — through DRAM, SSD, and PFS alike."""
    sys_ = _drain_system()
    try:
        fs = sys_.fs()
        chunk = 64 << 10
        n_chunks = 48                            # 3 MB per writer, 2 writers
        blobs = {}
        for w in range(2):
            blobs[w] = np.random.default_rng(10 + w).integers(
                0, 256, n_chunks * chunk, dtype=np.uint8).tobytes()
        synced = {0: 0, 1: 0}
        errors = []

        def _writer(w):
            try:
                f = fs.open(f"stream{w}", "w", policy="batched",
                            chunk_bytes=chunk)
                for j in range(n_chunks):
                    f.pwrite(blobs[w][j * chunk:(j + 1) * chunk], j * chunk)
                    if (j + 1) % 8 == 0:
                        f.sync(30.0)
                        synced[w] = (j + 1) * chunk
                f.close(30.0)
                synced[w] = n_chunks * chunk
            except Exception as e:               # surface in the main thread
                errors.append((w, repr(e)))

        threads = [threading.Thread(target=_writer, args=(w,))
                   for w in range(2)]
        for t in threads:
            t.start()
        deadline = time.monotonic() + 60.0
        while any(t.is_alive() for t in threads) \
                and time.monotonic() < deadline:
            for w in range(2):
                n = synced[w]
                if n:
                    # a fresh handle per check: its size snapshot must see
                    # at least the synced prefix
                    got = fs.open(f"stream{w}", "r").pread(0, n)
                    assert got == blobs[w][:n], \
                        f"read-your-writes violated on stream{w} at {n}"
            time.sleep(0.05)
        for t in threads:
            t.join(10.0)
        assert not errors, errors
        for w in range(2):
            got = fs.open(f"stream{w}", "r").pread(0, len(blobs[w]))
            assert got == blobs[w]
        evictions = sum(s.stats["evictions"] for s in sys_.servers.values())
        assert evictions > 0, "stress never exercised the evict path"
    finally:
        sys_.stop()


@pytest.mark.slow
def test_checkpoint_restore_spans_drained_data():
    """bbckpt integration: a checkpoint bigger than DRAM is saved while the
    drainer evicts its chunks; restore() must come back bit-exact through
    the three-tier fallthrough."""
    jnp = pytest.importorskip("jax.numpy")
    from repro.checkpoint.bbckpt import BBCheckpointManager
    sys_ = _drain_system()
    try:
        rng = np.random.default_rng(77)
        state = {"w": jnp.asarray(rng.normal(size=(1024, 1024)),
                                  jnp.float32),
                 "b": jnp.asarray(rng.normal(size=(4096,)), jnp.float32)}
        mgr = BBCheckpointManager(sys_, io_mode="batched",
                                  chunk_bytes=128 << 10)
        mgr.save(1, state, blocking_flush=True)
        _wait_drained(sys_, timeout=15.0)
        restored, step = mgr.restore(state)
        assert step == 1
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(state["w"]))
        np.testing.assert_array_equal(np.asarray(restored["b"]),
                                      np.asarray(state["b"]))
        assert "pressure" in mgr.metrics[1]
    finally:
        sys_.stop()
