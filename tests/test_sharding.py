"""RuleSet resolution unit tests + multi-device subprocess checks
(sharded MoE parity, small-mesh dry-run compile)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


class FakeMesh:
    axis_names = ("data", "model")

    class _Dev:
        shape = (4, 8)
    devices = _Dev()


def _rules(overrides=None):
    from repro.launch.sharding import RuleSet
    return RuleSet(FakeMesh(), overrides)


def test_spec_basic_tp_fsdp():
    r = _rules()
    spec = r.spec(("embed", "ffn"), (64, 128))
    assert tuple(spec) == ("data", "model")


def test_spec_divisibility_blocks_sharding():
    r = _rules()
    spec = r.spec(("embed", "ffn"), (6, 128))     # 6 % 4 != 0
    assert tuple(spec) == (None, "model")


def test_spec_conflict_one_axis_once():
    r = _rules()
    # both dims want "model": second gets None
    spec = r.spec(("ffn", "vocab"), (128, 256))
    assert tuple(spec) == ("model", None)


def test_spec_composite_experts():
    r = _rules()
    spec = r.spec(("experts", None, None), (32, 7, 5))   # 32 == 4*8
    assert tuple(spec)[0] == ("data", "model")


def test_spec_experts_fallback_row():
    r = _rules()
    spec = r.spec(("experts", "ffn"), (4, 64))   # 4 % 32 != 0 -> data
    assert tuple(spec) == ("data", "model")


def test_batch_composite_pod():
    class PodMesh:
        axis_names = ("pod", "data", "model")

        class _Dev:
            shape = (2, 4, 8)
        devices = _Dev()
    from repro.launch.sharding import RuleSet
    r = RuleSet(PodMesh())
    spec = r.spec(("batch", None), (16, 5))
    assert tuple(spec)[0] == ("pod", "data")
    # batch=1: unshardable
    spec = r.spec(("batch", None), (1, 5))
    assert tuple(spec) == (None, None)


def _run_subprocess(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_moe_sharded_matches_dense_subprocess():
    out = _run_subprocess("""
        import dataclasses, jax, jax.numpy as jnp
        from repro.configs.base import get_config, reduced
        from repro.launch.mesh import make_host_mesh
        from repro.launch.sharding import RuleSet, use_rules
        from repro.models import moe, moe_sharded
        from repro.models.common import init_tree

        mesh = make_host_mesh(data=4, model=2)
        rules = RuleSet(mesh)
        cfg = dataclasses.replace(
            reduced(get_config("deepseek-v3-671b")),
            num_experts=8, top_k=2, capacity_factor=8.0, d_ff_expert=32)
        p = init_tree(moe.moe_descs(cfg), jax.random.PRNGKey(0), jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model))
        dense = moe._apply_moe_dense(cfg, p, x)
        with jax.set_mesh(mesh), use_rules(rules):
            sh = jax.jit(lambda p, x:
                         moe_sharded.apply_moe_sharded(cfg, p, x, rules))(p, x)
        err = float(jnp.max(jnp.abs(dense - sh)))
        assert err < 1e-4, err
        print("OK", err)
    """)
    assert "OK" in out


@pytest.mark.slow
def test_small_mesh_dryrun_compile_subprocess():
    """Tiny-mesh analogue of the production dry-run: lower+compile a train
    step and a decode step with full sharding machinery on 8 host devices."""
    out = _run_subprocess("""
        import dataclasses, jax, jax.numpy as jnp
        from repro.configs.base import get_config, reduced
        from repro.launch.mesh import make_host_mesh
        from repro.launch.sharding import (RuleSet, batch_axes, cache_axes,
                                           use_rules)
        from repro.models.registry import build_model
        from repro.runtime.train_step import (TrainState, make_optimizer,
                                              make_train_step,
                                              state_logical_axes)
        from repro.analysis.hlo_stats import analyze

        cfg = dataclasses.replace(reduced(get_config("gemma3-4b"),
                                          d_model=64, vocab=512))
        mesh = make_host_mesh(data=4, model=2)
        rules = RuleSet(mesh)
        model = build_model(cfg)
        params_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        optimizer = make_optimizer(cfg)
        opt_struct = jax.eval_shape(optimizer.init, params_struct)
        state_struct = TrainState(params_struct, opt_struct)
        axes = state_logical_axes(cfg, model, optimizer)
        st_sh = rules.tree_shardings(axes, state_struct)
        batch = {"inputs": jax.ShapeDtypeStruct((8, 32), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
        b_sh = rules.tree_shardings(batch_axes(batch), batch)
        step = make_train_step(cfg, model, optimizer, accum_steps=2)
        with mesh, use_rules(rules):
            compiled = jax.jit(step, in_shardings=(st_sh, b_sh),
                               out_shardings=(st_sh, None),
                               donate_argnums=(0,)
                               ).lower(state_struct, batch).compile()
        stats = analyze(compiled.as_text())
        assert stats.flops > 0
        print("TRAIN-OK", int(stats.flops))

        cache_struct = jax.eval_shape(lambda: model.init_cache(8, 64))
        c_sh = rules.tree_shardings(cache_axes(cfg, cache_struct),
                                    cache_struct)
        p_sh = rules.tree_shardings(model.param_axes(), params_struct)
        toks = {"tokens": jax.ShapeDtypeStruct((8, 1), jnp.int32)}
        t_sh = rules.tree_shardings(batch_axes(toks), toks)
        def dec(params, cache, specs, pos):
            return model.decode_step(params, cache, specs["tokens"], pos)
        with mesh, use_rules(rules):
            compiled = jax.jit(dec, in_shardings=(p_sh, c_sh, t_sh, None),
                               out_shardings=(None, c_sh),
                               donate_argnums=(1,)).lower(
                params_struct, cache_struct, toks,
                jax.ShapeDtypeStruct((), jnp.int32)).compile()
        print("DECODE-OK")
    """)
    assert "TRAIN-OK" in out and "DECODE-OK" in out
