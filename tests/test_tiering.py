"""Log-structured DRAM/SSD store (paper §V hybrid storage)."""
import os

import numpy as np
import pytest

from repro.core.tiering import LogStore


def test_put_get_roundtrip(tmp_path):
    store = LogStore(1 << 20, str(tmp_path), name="t0")
    data = {f"k{i}": os.urandom(1000 + i) for i in range(50)}
    for k, v in data.items():
        store.put(k, v)
    for k, v in data.items():
        assert store.get(k) == v


def test_spill_to_ssd_preserves_data(tmp_path):
    store = LogStore(256 << 10, str(tmp_path), name="t1")
    rng = np.random.default_rng(0)
    data = {}
    for i in range(40):                       # ~2.6 MB >> 256 KB DRAM
        v = rng.integers(0, 256, 64 << 10, dtype=np.uint8).tobytes()
        data[f"k{i}"] = v
        store.put(f"k{i}", v)
    assert store.ssd_used > 0, "expected spill"
    assert store.dram_used <= store.dram_capacity + LogStore.SEGMENT_BYTES
    for k, v in data.items():
        assert store.get(k) == v, k
    # spilled log is append-only sequential (single file)
    assert os.path.getsize(store._ssd_path) == store.ssd_used


def test_overwrite_and_delete(tmp_path):
    store = LogStore(1 << 20, str(tmp_path), name="t2")
    store.put("k", b"one")
    store.put("k", b"two-two")
    assert store.get("k") == b"two-two"
    store.delete("k")
    assert store.get("k") is None
    assert "k" not in store


def test_compact_reclaims_dead_segments(tmp_path):
    store = LogStore(64 << 20, str(tmp_path), name="t3")
    for i in range(30):
        store.put(f"k{i}", b"x" * (LogStore.SEGMENT_BYTES // 4))
    used_before = store.dram_used
    for i in range(30):
        store.delete(f"k{i}")
    store.compact()
    assert store.dram_used < used_before / 4


def test_no_ssd_dir_is_memory_only():
    store = LogStore(16 << 10, None, name="t4")
    for i in range(10):                       # exceeds DRAM, nowhere to spill
        store.put(f"k{i}", b"y" * 8000)
    for i in range(10):
        assert store.get(f"k{i}") == b"y" * 8000
