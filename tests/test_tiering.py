"""Log-structured DRAM/SSD store (paper §V hybrid storage)."""
import os
import threading

import numpy as np
import pytest

from repro.core.tiering import LogStore


def test_put_get_roundtrip(tmp_path):
    store = LogStore(1 << 20, str(tmp_path), name="t0")
    data = {f"k{i}": os.urandom(1000 + i) for i in range(50)}
    for k, v in data.items():
        store.put(k, v)
    for k, v in data.items():
        assert store.get(k) == v


def test_spill_to_ssd_preserves_data(tmp_path):
    store = LogStore(256 << 10, str(tmp_path), name="t1")
    rng = np.random.default_rng(0)
    data = {}
    for i in range(40):                       # ~2.6 MB >> 256 KB DRAM
        v = rng.integers(0, 256, 64 << 10, dtype=np.uint8).tobytes()
        data[f"k{i}"] = v
        store.put(f"k{i}", v)
    assert store.ssd_used > 0, "expected spill"
    assert store.dram_used <= store.dram_capacity + LogStore.SEGMENT_BYTES
    for k, v in data.items():
        assert store.get(k) == v, k
    # spilled log is append-only sequential (single file): payload bytes
    # plus one self-describing record header per spilled key (ISSUE 8)
    overhead = sum(LogStore.record_overhead(k)
                   for k, loc in store._index.items() if loc.tier == "ssd")
    assert os.path.getsize(store._ssd_path) == store.ssd_used + overhead


def test_overwrite_and_delete(tmp_path):
    store = LogStore(1 << 20, str(tmp_path), name="t2")
    store.put("k", b"one")
    store.put("k", b"two-two")
    assert store.get("k") == b"two-two"
    store.delete("k")
    assert store.get("k") is None
    assert "k" not in store


def test_compact_reclaims_dead_segments(tmp_path):
    store = LogStore(64 << 20, str(tmp_path), name="t3")
    for i in range(30):
        store.put(f"k{i}", b"x" * (LogStore.SEGMENT_BYTES // 4))
    used_before = store.dram_used
    for i in range(30):
        store.delete(f"k{i}")
    store.compact()
    assert store.dram_used < used_before / 4


def test_no_ssd_dir_is_memory_only():
    store = LogStore(16 << 10, None, name="t4")
    for i in range(10):                       # exceeds DRAM, nowhere to spill
        store.put(f"k{i}", b"y" * 8000)
    for i in range(10):
        assert store.get(f"k{i}") == b"y" * 8000


def test_spill_hysteresis_batches_segments(tmp_path):
    """Once over DRAM capacity a spill keeps going down to the low
    watermark (capacity minus max(capacity/4, one segment)), so each
    trigger's single fsync covers several segments instead of paying a
    disk flush per sealed segment."""
    cap = 1 << 20
    store = LogStore(cap, str(tmp_path), name="hys",
                     segment_bytes=128 << 10)
    fsyncs = []
    orig_fsync = os.fsync

    def counting_fsync(fd):
        fsyncs.append(fd)
        orig_fsync(fd)

    os.fsync = counting_fsync
    try:
        for i in range(64):                  # 4 MB through a 1 MB DRAM tier
            store.put(f"k{i}", b"h" * (64 << 10))
    finally:
        os.fsync = orig_fsync
    # the trigger itself never lets DRAM exceed capacity...
    assert store.dram_used <= cap
    # ...and ~3 MB spilled in >= 256 KB hysteresis batches: far fewer
    # fsyncs than the ~24 sealed segments that moved (one flush each
    # without the low watermark)
    assert 0 < len(fsyncs) <= 14


def test_tombstone_fsyncs_coalesce_into_sync(tmp_path):
    """delete()/evict() of SSD-resident keys append tombstones without an
    immediate fsync; ``sync()`` hardens the batch in one flush, and a
    spill's batch fsync covers any tombstones appended before it."""
    store = LogStore(0, str(tmp_path), name="coal", ssd_capacity=1 << 30)
    for i in range(4):
        store.put(f"k{i}", b"c" * 4096)
    assert all(store.tier_of(f"k{i}") == "ssd" for i in range(4))
    store.delete("k0")
    store.evict("k1")
    assert store._unsynced
    store.sync()
    assert not store._unsynced
    store.sync()                             # idempotent no-op
    store.delete("k2")
    assert store._unsynced
    store.put("k4", b"c" * 4096)             # spill fsync covers the tombstone
    assert not store._unsynced
    # the tombstones replay: a fresh store over the same log drops the keys
    again = LogStore(0, str(tmp_path), name="coal", ssd_capacity=1 << 30)
    assert sorted(again.recovered_keys) == ["k3", "k4"]


# ----------------------------------------------- SSD spill path (ISSUE 2)

def test_spill_moves_whole_segments(tmp_path):
    """Log-structured spill is whole-segment: every key of a spilled segment
    moves to the ssd tier together, and no key is left pointing at a freed
    DRAM segment."""
    store = LogStore(LogStore.SEGMENT_BYTES, str(tmp_path), name="t5")
    val = b"s" * (LogStore.SEGMENT_BYTES // 4)
    for i in range(12):                       # ~3 segments worth
        store.put(f"k{i}", val)
    assert store.ssd_used > 0
    # keys from one original segment share a tier (never half-spilled):
    # segments hold exactly 4 values here, so spilled keys come in fours
    ssd_keys = [k for k, loc in store._index.items() if loc.tier == "ssd"]
    assert len(ssd_keys) > 0 and len(ssd_keys) % 4 == 0
    for k, loc in store._index.items():
        if loc.tier == "dram":
            assert loc.segment in store._segments, \
                f"{k} points at a freed DRAM segment"


def test_spilled_values_read_back_from_ssd_tier(tmp_path):
    rng = np.random.default_rng(7)
    store = LogStore(256 << 10, str(tmp_path), name="t6")
    data = {f"k{i}": rng.integers(0, 256, 96 << 10, dtype=np.uint8).tobytes()
            for i in range(24)}               # ~2.25 MB >> 256 KB DRAM
    for k, v in data.items():
        store.put(k, v)
    ssd_keys = [k for k, loc in store._index.items() if loc.tier == "ssd"]
    assert ssd_keys, "expected at least one spilled key"
    for k in ssd_keys:
        assert store.get(k) == data[k], f"ssd read-back mismatch for {k}"
    # the ssd log itself is a single sequential file of framed records
    overhead = sum(LogStore.record_overhead(k)
                   for k, loc in store._index.items() if loc.tier == "ssd")
    assert os.path.getsize(store._ssd_path) == store.ssd_used + overhead


def test_index_correct_after_eviction_of_spilled_keys(tmp_path):
    """Deleting spilled keys and compacting must leave every surviving key
    readable with its original bytes, on both tiers."""
    rng = np.random.default_rng(8)
    store = LogStore(256 << 10, str(tmp_path), name="t7")
    data = {f"k{i}": rng.integers(0, 256, 64 << 10, dtype=np.uint8).tobytes()
            for i in range(32)}
    for k, v in data.items():
        store.put(k, v)
    assert store.ssd_used > 0
    evicted = [k for i, k in enumerate(data) if i % 3 == 0]
    for k in evicted:
        store.delete(k)
    store.compact()
    for k in evicted:
        assert store.get(k) is None
        assert k not in store
    for k, v in data.items():
        if k not in evicted:
            assert store.get(k) == v, f"survivor {k} corrupted by eviction"
    tiers = {store._index[k].tier for k in data if k not in evicted}
    assert "ssd" in tiers                     # survivors span both tiers


# ------------------------------------- SSD compaction + eviction (ISSUE 3)

def test_compact_reclaims_ssd_space_from_deleted_entries(tmp_path):
    """compact() must rewrite the SSD log dropping dead entries: the
    accounting AND the file on disk both shrink, and every survivor reads
    back its original bytes."""
    rng = np.random.default_rng(11)
    store = LogStore(256 << 10, str(tmp_path), name="c0")
    data = {f"k{i}": rng.integers(0, 256, 64 << 10, dtype=np.uint8).tobytes()
            for i in range(32)}               # 2 MB >> 256 KB DRAM
    for k, v in data.items():
        store.put(k, v)
    assert store.ssd_used > 0
    before_ssd = store.ssd_used
    before_file = os.path.getsize(store._ssd_path)
    dead = [k for k, loc in store._index.items() if loc.tier == "ssd"][::2]
    assert dead
    for k in dead:
        store.delete(k)
    store.compact()
    assert store.ssd_used < before_ssd, "SSD accounting did not shrink"
    assert os.path.getsize(store._ssd_path) < before_file, \
        "SSD log file was not rewritten"
    overhead = sum(LogStore.record_overhead(k)
                   for k, loc in store._index.items() if loc.tier == "ssd")
    assert os.path.getsize(store._ssd_path) == store.ssd_used + overhead
    for k, v in data.items():
        if k not in dead:
            assert store.get(k) == v, f"survivor {k} corrupted by compaction"


def test_compact_reclaims_ssd_space_from_evicted_entries(tmp_path):
    store = LogStore(128 << 10, str(tmp_path), name="c1",
                     segment_bytes=64 << 10)
    val = b"e" * (64 << 10)
    for i in range(8):
        store.put(f"k{i}", val)
    assert store.ssd_used > 0
    victims = [k for k, loc in store._index.items() if loc.tier == "ssd"]
    freed = sum(store.evict(k) for k in victims)
    assert freed == len(victims) * len(val)
    store.compact()
    assert store.ssd_used == 0 or store.ssd_used < freed
    for k in victims:                         # tombstones survive compaction
        assert store.was_evicted(k)
        assert store.get(k) is None
    survivors = [k for k in store.keys() if k not in victims]
    for k in survivors:
        assert store.get(k) == val


def test_compact_noop_when_ssd_all_live(tmp_path):
    store = LogStore(64 << 10, str(tmp_path), name="c2",
                     segment_bytes=32 << 10)
    for i in range(8):
        store.put(f"k{i}", b"q" * (32 << 10))
    before = os.path.getsize(store._ssd_path)
    store.compact()                           # nothing dead: no rewrite
    assert os.path.getsize(store._ssd_path) == before
    for i in range(8):
        assert store.get(f"k{i}") == b"q" * (32 << 10)


def test_occupancy_fraction_tracks_both_tiers(tmp_path):
    store = LogStore(128 << 10, str(tmp_path), name="c3",
                     ssd_capacity=128 << 10, segment_bytes=32 << 10)
    occ = store.occupancy()
    assert occ["fraction"] == 0.0 and occ["capacity"] == 256 << 10
    store.put("a", b"x" * (64 << 10))
    assert abs(store.occupancy()["fraction"] - 0.25) < 1e-9
    for i in range(6):                        # spill: fraction keeps rising
        store.put(f"b{i}", b"x" * (32 << 10))
    occ = store.occupancy()
    assert occ["ssd_used"] > 0
    assert abs(occ["fraction"]
               - (occ["dram_used"] + occ["ssd_used"]) / occ["capacity"]) \
        < 1e-9


def test_concurrent_readers_race_evict_and_compact_byte_exact(tmp_path):
    """ISSUE 4 satellite: readers racing evict()+compact() must never see
    torn or relocated bytes — every get() returns either the original value
    or None (evicted), while the SSD log is being rewritten underneath."""
    rng = np.random.default_rng(21)
    store = LogStore(256 << 10, str(tmp_path), name="race",
                     segment_bytes=32 << 10)
    data = {f"k{i}": rng.integers(0, 256, 16 << 10, dtype=np.uint8).tobytes()
            for i in range(64)}                  # 1 MB: most spill to SSD
    for k, v in data.items():
        store.put(k, v)
    assert store.ssd_used > 0
    stop = threading.Event()
    errors = []

    def _reader():
        while not stop.is_set():
            for k, v in data.items():
                got = store.get(k)
                if got is not None and got != v:
                    errors.append(k)
                    return

    readers = [threading.Thread(target=_reader) for _ in range(3)]
    for t in readers:
        t.start()
    victims = list(data)[::3]
    for k in victims:                            # evict + compact in waves
        store.evict(k)
        store.compact()
    stop.set()
    for t in readers:
        t.join(10.0)
    assert not errors, f"raced read returned wrong bytes: {errors[:3]}"
    for k, v in data.items():
        if k in victims:
            assert store.get(k) is None and store.was_evicted(k)
        else:
            assert store.get(k) == v, f"survivor {k} corrupted"


def test_put_bumps_write_generation(tmp_path):
    store = LogStore(1 << 20, str(tmp_path), name="c4")
    store.put("k", b"one")
    g1 = store.gen_of("k")
    store.put("k", b"two")
    g2 = store.gen_of("k")
    assert g2 > g1
    store.evict("k")
    assert store.gen_of("k") == g2            # tombstone keeps the gen
