"""Data pipeline: determinism, shard disjointness, prefetch, resume."""
import numpy as np

from repro.data.pipeline import SyntheticLMPipeline


def _mk(**kw):
    args = dict(vocab_size=1000, seq_len=16, global_batch=8)
    args.update(kw)
    return SyntheticLMPipeline(**args)


def test_deterministic_same_seed():
    a, b = _mk(), _mk()
    for _ in range(3):
        ba, bb = next(a), next(b)
        np.testing.assert_array_equal(ba["inputs"], bb["inputs"])
        np.testing.assert_array_equal(ba["labels"], bb["labels"])


def test_labels_are_shifted_inputs():
    batch = next(_mk())
    np.testing.assert_array_equal(batch["inputs"][:, 1:],
                                  batch["labels"][:, :-1])


def test_shards_disjoint_and_cover():
    full_batches = [next(_mk(num_shards=1)) for _ in range(2)]
    shard0 = _mk(num_shards=2, shard_id=0)
    shard1 = _mk(num_shards=2, shard_id=1)
    b0, b1 = next(shard0), next(shard1)
    assert b0["inputs"].shape[0] == 4 and b1["inputs"].shape[0] == 4
    assert not np.array_equal(b0["inputs"], b1["inputs"])


def test_prefetch_matches_sync():
    sync = _mk(seed=7)
    pre = _mk(seed=7).start_prefetch()
    try:
        for _ in range(4):
            np.testing.assert_array_equal(next(sync)["inputs"],
                                          next(pre)["inputs"])
    finally:
        pre.stop_prefetch()


def test_resume_from_state_dict():
    a = _mk(seed=3)
    for _ in range(5):
        next(a)
    state = a.state_dict()
    b = _mk(seed=3)
    b.load_state_dict(state)
    np.testing.assert_array_equal(next(a)["inputs"], next(b)["inputs"])


def test_modality_stub_inputs():
    p = _mk(enc_seq=10, enc_dim=4)
    batch = next(p)
    assert batch["enc_input"].shape == (8, 10, 4)
    assert batch["enc_input"].dtype == np.float32
