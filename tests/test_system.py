"""End-to-end behaviour of the burst buffer system (paper §II-§V):
ingest, replication, two-phase flush byte-exactness, lookup-table reads,
failure detection/recovery, ring join, overload redirect."""
import os
import time

import numpy as np
import pytest

from repro.core import BBConfig, BurstBufferSystem


@pytest.fixture()
def bb4():
    sys_ = BurstBufferSystem(BBConfig(
        num_servers=4, num_clients=4, placement="iso",
        dram_capacity=8 << 20, stabilize_interval=0.15)).start()
    yield sys_
    sys_.stop()


def _write_shared_file(sys_, fname, per_client=4, seg=32 << 10, seed=0):
    rng = np.random.default_rng(seed)
    blobs = {}
    for ci, c in enumerate(sys_.clients):
        for j in range(per_client):
            off = (ci * per_client + j) * seg
            data = rng.integers(0, 256, seg, dtype=np.uint8).tobytes()
            blobs[off] = data
            assert c.put(f"{fname}:{off}", data, file=fname, offset=off)
    return blobs, seg


def test_put_get_replicated(bb4):
    blobs, _ = _write_shared_file(bb4, "f0")
    assert bb4.clients[0].get("f0:0") == blobs[0]
    c = bb4.clients[1]
    replicas = c.replica_set("f0:0")
    assert len(replicas) == 2


def test_two_phase_flush_byte_exact(bb4):
    blobs, seg = _write_shared_file(bb4, "ckpt1")
    assert bb4.flush(epoch=1, timeout=30)
    path = os.path.join(bb4.pfs_dir, "ckpt1")
    expect = b"".join(blobs[o] for o in sorted(blobs))
    assert open(path, "rb").read() == expect


def test_lookup_table_range_read_no_pfs(bb4):
    blobs, seg = _write_shared_file(bb4, "ckpt2")
    assert bb4.flush(epoch=2, timeout=30)
    expect = b"".join(blobs[o] for o in sorted(blobs))
    got = bb4.clients[2].read_file("ckpt2", seg + 7, 3 * seg)
    assert got == expect[seg + 7: seg + 7 + 3 * seg]


def test_failure_detection_and_replica_read(bb4):
    blobs, seg = _write_shared_file(bb4, "f3")
    victim = "server/1"
    bb4.kill_server(victim)
    deadline = time.monotonic() + 6
    while time.monotonic() < deadline and victim not in bb4.manager.dead:
        time.sleep(0.05)
    assert victim in bb4.manager.dead, "stabilization did not detect failure"
    off = 1 * 4 * (32 << 10)      # keys pinned to server/1 (iso, client 1)
    got = bb4.clients[1].get(f"f3:{off}")
    assert got == blobs[off], "replica read after failure failed"


def test_client_timeout_confirm_failover(bb4):
    _write_shared_file(bb4, "f4")
    victim = "server/2"
    bb4.kill_server(victim)
    c = bb4.clients[2]            # pinned to the dead server
    c.put_timeout = 0.8
    assert c.put("f4:new", b"hello-after-failure")
    assert c.stats["failovers"] >= 1
    assert c.get("f4:new") == b"hello-after-failure"


def test_server_join_ring_update(bb4):
    name = bb4.join_server(pred="server/1")
    time.sleep(0.6)
    assert name in bb4.manager.ring
    assert bb4.clients[0].put("f5:0", b"post-join", file="f5", offset=0)


def test_overload_redirect_or_spill():
    sys_ = BurstBufferSystem(BBConfig(
        num_servers=3, num_clients=3, placement="iso",
        dram_capacity=256 << 10, stabilize_interval=0.1)).start()
    try:
        time.sleep(0.5)           # let free-memory gossip propagate
        c = sys_.clients[0]
        for i in range(24):       # far beyond one server's DRAM
            assert c.put(f"big:{i}", b"z" * (64 << 10))
        stats = sys_.server_stats()
        redirects = sum(s["redirects"] for s in stats.values())
        spills = sum(s["spills"] for s in stats.values())
        assert redirects + spills > 0, \
            "expected overload handling (redirect or spill)"
        for i in range(24):
            assert c.get(f"big:{i}") == b"z" * (64 << 10)
    finally:
        sys_.stop()


def test_ketama_placement_end_to_end():
    sys_ = BurstBufferSystem(BBConfig(
        num_servers=4, num_clients=2, placement="ketama",
        dram_capacity=8 << 20)).start()
    try:
        rng = np.random.default_rng(1)
        blobs = {}
        for i in range(32):
            data = rng.integers(0, 256, 8 << 10, dtype=np.uint8).tobytes()
            blobs[i] = data
            assert sys_.clients[i % 2].put(f"kk:{i * 8192}", data,
                                           file="kk", offset=i * 8192)
        assert sys_.flush(epoch=9, timeout=30)
        expect = b"".join(blobs[i] for i in range(32))
        path = os.path.join(sys_.pfs_dir, "kk")
        assert open(path, "rb").read() == expect
        stats = sys_.server_stats()
        holders = [s for s, v in stats.items() if v["keys"] > 0]
        assert len(holders) >= 3      # ketama spreads one client's keys
    finally:
        sys_.stop()
