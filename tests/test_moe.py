"""MoE dispatch properties: capacity drops, weight normalization, MTP."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.models import moe
from repro.models.common import init_tree


def _cfg(**kw):
    base = reduced(get_config("deepseek-v3-671b"))
    return dataclasses.replace(base, **kw)


def test_moe_outputs_finite_and_shaped():
    cfg = _cfg(num_experts=8, top_k=2, d_ff_expert=32)
    p = init_tree(moe.moe_descs(cfg), jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y = moe._apply_moe_dense(cfg, p, x)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))


def test_moe_capacity_drops_are_graceful():
    """With capacity_factor near zero most assignments drop; output must
    shrink toward the shared-expert-only result, never NaN."""
    cfg = _cfg(num_experts=8, top_k=2, d_ff_expert=32, capacity_factor=1e-6)
    p = init_tree(moe.moe_descs(cfg), jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y = moe._apply_moe_dense(cfg, p, x)
    assert bool(jnp.all(jnp.isfinite(y)))
    # routed contribution mostly dropped -> ~= shared expert only
    cfg_big = dataclasses.replace(cfg, capacity_factor=8.0)
    y_full = moe._apply_moe_dense(cfg_big, p, x)
    assert float(jnp.mean(jnp.abs(y))) <= float(jnp.mean(jnp.abs(y_full)))


def test_moe_router_weights_normalized():
    cfg = _cfg(num_experts=4, top_k=4, d_ff_expert=16, num_shared_experts=0,
               capacity_factor=8.0)
    p = init_tree(moe.moe_descs(cfg), jax.random.PRNGKey(0), jnp.float32)
    # identical experts -> output independent of routing (weights sum to 1)
    w1 = jnp.broadcast_to(p["w_gate"][0], p["w_gate"].shape)
    p2 = {**p, "w_gate": w1,
          "w_up": jnp.broadcast_to(p["w_up"][0], p["w_up"].shape),
          "w_down": jnp.broadcast_to(p["w_down"][0], p["w_down"].shape)}
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    y = moe._apply_moe_dense(cfg, p2, x)
    # compare against a single dense expert MLP
    from repro.models.common import activation
    xt = x.reshape(-1, cfg.d_model)
    g = xt @ p["w_gate"][0]
    u = xt @ p["w_up"][0]
    ref = (activation(cfg, g) * u) @ p["w_down"][0]
    np.testing.assert_allclose(np.asarray(y).reshape(-1, cfg.d_model),
                               np.asarray(ref), atol=1e-4, rtol=1e-4)


def test_mtp_forward_and_loss():
    cfg = _cfg()
    assert cfg.mtp_depth == 1
    from repro.models import transformer
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    assert "mtp" in params
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                cfg.vocab_size)
    logits, mtp_logits = transformer.forward_with_mtp(cfg, params, tokens)
    assert logits.shape[:2] == (2, 12)
    assert mtp_logits.shape[:2] == (2, 11)         # predicts t+2
    assert bool(jnp.all(jnp.isfinite(mtp_logits)))
