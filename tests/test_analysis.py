"""Loop-aware HLO analyzer: exact on known programs; collective parsing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo_stats import Analyzer, analyze
from repro.analysis.roofline import (Roofline, collective_summary,
                                     model_flops_for, parse_collectives)


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_trip_count_exact():
    w = jnp.ones((64, 64), jnp.float32)
    x = jnp.ones((64, 64), jnp.float32)

    def body(c, _):
        return c @ w, None

    def scanned(x):
        return jax.lax.scan(body, x, None, length=12)[0]

    stats = analyze(_compile(scanned, x))
    expect = 2 * 64 ** 3 * 12
    assert stats.flops == pytest.approx(expect, rel=0.01)


def test_nested_scan_multiplies():
    w = jnp.ones((32, 32), jnp.float32)
    x = jnp.ones((32, 32), jnp.float32)

    def inner(c, _):
        return c @ w, None

    def outer(c, _):
        return jax.lax.scan(inner, c, None, length=5)[0], None

    def fn(x):
        return jax.lax.scan(outer, x, None, length=3)[0]

    stats = analyze(_compile(fn, x))
    assert stats.flops == pytest.approx(2 * 32 ** 3 * 15, rel=0.01)


def test_unrolled_matches_scanned():
    w = jnp.ones((48, 48), jnp.float32)
    x = jnp.ones((48, 48), jnp.float32)

    def unrolled(x):
        for _ in range(6):
            x = x @ w
        return x

    def scanned(x):
        return jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=6)[0]

    su = analyze(_compile(unrolled, x))
    ss = analyze(_compile(scanned, x))
    assert su.flops == pytest.approx(ss.flops, rel=0.01)


def test_roofline_terms_and_bottleneck():
    r = Roofline(arch="a", shape="train_4k", mesh="pod", chips=256,
                 flops_per_chip=197e12, hbm_bytes_per_chip=819e9 * 2,
                 link_bytes_per_chip=50e9 * 0.5,
                 model_flops=197e12 * 256 * 0.5)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(2.0)
    assert r.t_collective == pytest.approx(0.5)
    assert r.bottleneck == "memory"
    assert r.useful_flops_ratio == pytest.approx(0.5)
    # achieved useful flops/chip at t=2.0: 0.5*197e12/2 -> 1/4 of peak
    assert r.roofline_fraction == pytest.approx(0.25)


def test_parse_collectives_from_text():
    txt = """
  %ar = bf16[16,128]{1,0} all-reduce(bf16[16,128]{1,0} %x), replica_groups=[8,8]<=[64], to_apply=%sum
  %ag = f32[64,32]{1,0} all-gather(f32[8,32]{1,0} %y), replica_groups=[4,16]<=[64], dimensions={0}
"""
    colls = parse_collectives(txt)
    assert len(colls) == 2
    ar = [c for c in colls if c["op"] == "all-reduce"][0]
    assert ar["participants"] == 8
    assert ar["bytes"] == 16 * 128 * 2
    summary = collective_summary(colls)
    assert summary["all-gather"]["count"] == 1


def test_model_flops_train_vs_decode():
    from repro.configs.base import SHAPES_BY_NAME
    n = 1_000_000
    t = model_flops_for(None, SHAPES_BY_NAME["train_4k"], n)
    d = model_flops_for(None, SHAPES_BY_NAME["decode_32k"], n)
    assert t == 6.0 * n * 256 * 4096
    assert d == 2.0 * n * 128
