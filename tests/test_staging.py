"""Stage-in engine (ISSUE 4): domain-partitioned stage planning, sequential
read-ahead, parallel fan-out, the manager-coordinated stage epoch protocol
(serialized against drain micro-epochs), the clean-evict fast path (staged
bytes drop without a flush epoch), and the fault-injection surface — kill a
server mid-stage (the epoch must abort cleanly and reads stay byte-exact
via the fallback chain)."""
import os
import threading
import time

import numpy as np
import pytest

from repro.core import (BBConfig, BurstBufferSystem, DrainConfig, ReadAhead,
                        StageConfig, Transport)
from repro.core import staging
from repro.core.manager import DRAIN_EPOCH_BASE, STAGE_EPOCH_BASE
from repro.core.server import BBServer
from repro.core.tiering import LogStore
from repro.core.transport import Message


# ------------------------------------------------------------- plan units

def test_plan_stage_slices_only_uncovered_domain_bytes():
    plan = staging.plan_stage([(0, 100)], (0, 100),
                              [[20, 40], [60, 70]], slice_bytes=25)
    # gaps [0,20) [40,60) [70,100), the last sliced at 25 bytes
    assert plan == [(0, 20), (40, 20), (70, 25), (95, 5)]


def test_plan_stage_respects_requested_range_and_foreign_domains():
    # my domain is [50, 100); the request stops at 80; nothing is covered
    assert staging.plan_stage([(50, 100)], (0, 80), [], 1000) == [(50, 30)]
    # a fully covered domain needs no slices at all
    assert staging.plan_stage([(0, 50)], (0, 50), [[0, 50]], 16) == []
    # a domain wholly outside the request stages nothing
    assert staging.plan_stage([(90, 100)], (0, 50), [], 16) == []


def test_plan_stage_merges_overlapping_coverage():
    plan = staging.plan_stage([(0, 60)], (0, 60),
                              [[0, 20], [10, 30], [30, 40]], slice_bytes=100)
    assert plan == [(40, 20)]


# ------------------------------------------------------- read-ahead units

def _ra(**kw):
    base = dict(prefetch_min_run=2, prefetch_window=100)
    base.update(kw)
    return ReadAhead(StageConfig(**base))


def test_read_ahead_triggers_on_sequential_run():
    ra = _ra()
    assert ra.observe(0, 10, 1000) is None          # run of 1: no trigger
    assert ra.observe(10, 10, 1000) == (20, 120)    # sequential: window
    # plenty staged ahead — no re-trigger until the reader catches up
    assert ra.observe(20, 10, 1000) is None
    got = None
    for off in range(30, 70, 10):
        got = ra.observe(off, 10, 1000) or got
    assert got == (120, 220), "next window must start at the staged mark"


def test_read_ahead_seek_breaks_the_run():
    ra = _ra()
    assert ra.observe(0, 10, 1000) is None
    assert ra.observe(500, 10, 1000) is None        # seek: run restarts
    assert ra.observe(510, 10, 1000) == (520, 620)


def test_read_ahead_clamps_at_eof():
    ra = _ra(prefetch_window=1000)
    assert ra.observe(0, 10, 30) is None
    assert ra.observe(10, 10, 30) == (20, 30)
    assert ra.observe(20, 10, 30) is None           # nothing left to stage


# ------------------------------------------------------- fan-out helper

def test_parallel_map_preserves_order_and_propagates_errors():
    assert staging.parallel_map(lambda x: x * x, range(20), 4) \
        == [x * x for x in range(20)]
    assert staging.parallel_map(lambda x: x + 1, [5], 8) == [6]
    assert staging.parallel_map(lambda x: x, [], 8) == []

    def _boom(x):
        if x == 7:
            raise ValueError("seven")
        return x

    with pytest.raises(ValueError, match="seven"):
        staging.parallel_map(_boom, range(10), 3)


# ------------------------------------------------------- LogStore clean flag

def test_logstore_clean_flag_filters_and_survives_spill(tmp_path):
    store = LogStore(128 << 10, str(tmp_path), name="cl0",
                     segment_bytes=32 << 10)
    val = b"c" * (32 << 10)
    for i in range(4):
        store.put(f"d{i}", val)                  # dirty
    for i in range(4):
        store.put(f"c{i}", val, clean=True)      # staged
    assert store.ssd_used > 0, "expected spill to exercise tier moves"
    assert store.is_clean("c0") and not store.is_clean("d0")
    clean = {k for k, _ in store.cold_keys(clean=True)}
    dirty = {k for k, _ in store.cold_keys(clean=False)}
    assert clean <= {f"c{i}" for i in range(4)} and clean
    assert dirty <= {f"d{i}" for i in range(4)} and dirty
    store.compact()
    assert store.is_clean("c0"), "compact must preserve the clean flag"
    # a plain rewrite dirties the key again
    store.put("c0", val)
    assert not store.is_clean("c0")


# ------------------------------------------- single-server protocol units

def _stage_server(tmp_path):
    tr = Transport()
    srv = BBServer("s0", tr, dram_capacity=4 << 20,
                   ssd_dir=str(tmp_path / "ssd"),
                   pfs_dir=str(tmp_path / "pfs"), replication=1)
    srv.ring, srv.alive = ["s0"], {"s0": True}
    os.makedirs(srv.pfs_dir, exist_ok=True)
    return tr, srv


def _begin(srv, epoch, file="f"):
    srv._on_stage_begin(Message("stage_begin", "manager", "s0",
                                {"epoch": epoch, "file": file, "lo": 0,
                                 "hi": -1, "ring": ["s0"]}, msg_id=1))


def _meta(srv, epoch, covered, size):
    # the epoch's coverage snapshot, delivered by hand so a put can be
    # interleaved between snapshot and re-ingest — the race under test
    srv._on_stage_meta(Message("stage_meta", "s0", "s0",
                               {"epoch": epoch, "from": "s0",
                                "covered": covered, "size": size},
                               msg_id=2))


def test_write_landing_mid_stage_is_not_clobbered(tmp_path):
    """A put that lands AFTER the epoch's coverage snapshot but BEFORE the
    re-ingest holds fresher bytes than the PFS — staging over it would
    resurrect stale data and mark it clean (silently evictable). The slice
    must be skipped when its key is live."""
    tr, srv = _stage_server(tmp_path)
    with open(os.path.join(srv.pfs_dir, "f"), "wb") as fh:
        fh.write(b"stale" * 200)
    epoch = (2 << 30) + 1
    _begin(srv, epoch)                           # snapshot: nothing covered
    srv._on_put(Message("put", "client", "s0",   # fresh write races in
                        {"key": "f:0", "value": b"fresh" * 200, "file": "f",
                         "offset": 0, "chain": []}, msg_id=3))
    _meta(srv, epoch, covered=[], size=1000)
    srv._stage_tick(time.monotonic())
    assert srv.store.get("f:0") == b"fresh" * 200, \
        "mid-stage write clobbered by stale PFS bytes"
    assert not srv.store.is_clean("f:0"), \
        "fresh write must not become silently evictable"


def test_mid_stage_write_at_other_offset_blocks_overlapping_slice(tmp_path):
    tr, srv = _stage_server(tmp_path)
    with open(os.path.join(srv.pfs_dir, "f"), "wb") as fh:
        fh.write(b"s" * 1000)
    epoch = (2 << 30) + 2
    _begin(srv, epoch)
    srv._on_put(Message("put", "client", "s0",   # unaligned fresh write
                        {"key": "f:100", "value": b"F" * 50, "file": "f",
                         "offset": 100, "chain": []}, msg_id=3))
    _meta(srv, epoch, covered=[], size=1000)
    srv._stage_tick(time.monotonic())
    # the overlapping slice was skipped wholesale: the fresh chunk survives
    assert srv.store.get("f:100") == b"F" * 50
    assert "f:0" not in srv.store, "overlapping slice must not be staged"


# --------------------------------------------------------- integration

def _stage_system(num=3, dram=32 << 20, **kw):
    base = dict(num_servers=num, num_clients=num, placement="iso",
                dram_capacity=dram, chunk_bytes=128 << 10,
                segment_bytes=256 << 10, stabilize_interval=0.15,
                read_timeout=0.5)
    base.update(kw)
    return BurstBufferSystem(BBConfig(**base)).start()


def _write(sys_, path, nbytes, seed=0):
    data = np.random.default_rng(seed).integers(
        0, 256, nbytes, dtype=np.uint8).tobytes()
    f = sys_.fs().open(path, "w", policy="batched")
    f.pwrite(data, 0)
    f.close(60.0)
    return data


def _evict_fully(sys_, path, timeout=10.0):
    sys_.evict(path)
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        st = sys_.fs().stat(path)
        if st["residency"]["dram"] == 0 and st["residency"]["ssd"] == 0:
            return st
        time.sleep(0.05)
    raise AssertionError(f"{path} still buffered after evict")


def test_stage_in_of_evicted_file_restores_buffered_reads():
    """The acceptance scenario: a flushed-and-evicted file is bulk-loaded
    back by one stage epoch, each server re-ingesting its own domain; reads
    then come from buffered CLEAN chunks and stay byte-exact."""
    sys_ = _stage_system()
    try:
        data = _write(sys_, "ckpt", 4 << 20)
        assert sys_.flush(epoch=1, timeout=30)
        st = _evict_fully(sys_, "ckpt")
        assert st["evicted_chunks"] > 0
        assert sys_.fs().stage("ckpt"), "stage epoch did not complete"
        assert sys_.manager.stage_stats["epochs"] == 1
        assert sys_.manager.stage_stats["staged_bytes"] == len(data)
        st = sys_.fs().stat("ckpt")
        assert st["residency"]["dram"] + st["residency"]["ssd"] \
            >= len(data), f"staged bytes not resident: {st}"
        clean = [k for srv in sys_.servers.values()
                 for k in srv.store.keys() if srv.store.is_clean(k)]
        assert clean, "staged chunks must be marked clean"
        got = sys_.fs().open("ckpt", "r").pread(0, len(data))
        assert got == data
        assert sys_.manager.errors == []
    finally:
        sys_.stop()


def test_stage_never_overwrites_fresher_buffered_chunks():
    """Coverage exchange: bytes ANY server still buffers are fresher than
    the PFS copy and must survive a stage — staging over a buffered rewrite
    would resurrect stale durable bytes."""
    sys_ = _stage_system()
    try:
        data = _write(sys_, "mix", 2 << 20, seed=3)
        assert sys_.flush(epoch=1, timeout=30)
        _evict_fully(sys_, "mix")
        # rewrite one chunk AFTER the flush: buffered only, PFS is stale
        fresh = np.random.default_rng(9).integers(
            0, 256, 128 << 10, dtype=np.uint8).tobytes()
        f = sys_.fs().open("mix", "a", policy="sync")
        f.pwrite(fresh, 256 << 10)
        f.sync(30.0)
        want = data[:256 << 10] + fresh + data[(256 << 10) + len(fresh):]
        assert sys_.fs().stage("mix")
        got = sys_.fs().open("mix", "r").pread(0, len(want))
        assert got == want, "stage resurrected stale PFS bytes"
    finally:
        sys_.stop()


def test_clean_evict_drops_staged_data_without_flush_epoch():
    """Staged bytes have a durable copy by construction: pressure drops
    them locally (tombstone + compact), with NO drain micro-epoch, and
    reads fall through transparently."""
    sys_ = _stage_system()
    try:
        data = _write(sys_, "ce", 3 << 20, seed=1)
        assert sys_.flush(epoch=1, timeout=30)
        _evict_fully(sys_, "ce")
        assert sys_.fs().stage("ce")
        epochs_before = sys_.manager.drain_stats["epochs"]
        freed = {n: srv._clean_evict() for n, srv in sys_.servers.items()}
        assert sum(freed.values()) > 0, "no clean bytes were evicted"
        for n, srv in sys_.servers.items():
            if freed[n]:
                assert srv.stats["clean_evictions"] > 0
        # no coordination happened: drain epoch counter untouched
        assert sys_.manager.drain_stats["epochs"] == epochs_before
        st = sys_.fs().stat("ce")
        assert st["residency"]["dram"] + st["residency"]["ssd"] == 0, st
        got = sys_.fs().open("ce", "r").pread(0, len(data))
        assert got == data, "clean-evicted data unreadable via fallback"
    finally:
        sys_.stop()


def test_pressure_clean_evicts_before_requesting_drain_epochs():
    """Admission/storm guard, end to end: staging into a tight store pushes
    occupancy over the high watermark; the drain tick must relieve it via
    the free clean-evict path instead of burning drain micro-epochs on
    bytes that are already durable."""
    dram = 1 << 20
    sys_ = _stage_system(
        dram=dram, ssd_capacity=dram, segment_bytes=128 << 10,
        drain=DrainConfig(high_watermark=0.5, low_watermark=0.25,
                          request_interval=0.02, pressure_interval=0.05))
    try:
        data = _write(sys_, "big", 4 << 20, seed=2)
        deadline = time.monotonic() + 20.0       # let the drainer evict it
        while time.monotonic() < deadline:
            st = sys_.fs().stat("big")
            if st["residency"]["dram"] + st["residency"]["ssd"] == 0:
                break
            time.sleep(0.1)
        epochs_before = sys_.manager.drain_stats["epochs"]
        assert sys_.fs().stage("big"), "stage did not complete"
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:
            if sum(s.stats["clean_evictions"]
                   for s in sys_.servers.values()) > 0:
                break
            time.sleep(0.05)
        cleans = sum(s.stats["clean_evictions"]
                     for s in sys_.servers.values())
        assert cleans > 0, "pressure never took the clean-evict fast path"
        assert sys_.manager.drain_stats["epochs"] == epochs_before, \
            "staged (already durable) bytes triggered a drain storm"
        got = sys_.fs().open("big", "r").pread(0, len(data))
        assert got == data
    finally:
        sys_.stop()


def test_stage_and_drain_epochs_are_serialized():
    sys_ = _stage_system()
    try:
        data = _write(sys_, "ser", 1 << 20, seed=4)
        assert sys_.flush(epoch=1, timeout=30)
        _evict_fully(sys_, "ser")
        mgr = sys_.manager
        # a drain micro-epoch in flight: stage requests are refused
        mgr._drain = {"epoch": DRAIN_EPOCH_BASE, "started": time.monotonic(),
                      "expected": set(mgr.alive_ring()), "done": set(),
                      "drained": set(), "bytes": 0, "requested_by": None}
        assert sys_.fs().stage("ser", wait=False) is False
        mgr._drain = None
        # a stage epoch in flight: drain requests are dropped
        mgr._stage = {"epoch": STAGE_EPOCH_BASE + 99, "path": "ser",
                      "started": time.monotonic(),
                      "expected": set(mgr.alive_ring()), "done": set(),
                      "bytes": 0}
        c = sys_.clients[0]
        c.transport.send(c.tname, "manager", "drain_request",
                         {"server": "server/0", "occupancy": 0.99,
                          "drainable": 1 << 20})
        time.sleep(0.5)
        assert mgr._drain is None, "drain epoch started during a stage"
        mgr._stage = None
        # with both slots free, staging works again and reads stay exact
        assert sys_.fs().stage("ser")
        got = sys_.fs().open("ser", "r").pread(0, len(data))
        assert got == data
    finally:
        sys_.stop()


def test_sequential_read_ahead_stages_the_next_window():
    """A prefetching handle reading sequentially must trigger asynchronous
    stage-ins; later reads then HIT buffered clean chunks instead of
    falling back per miss — and the whole file reads byte-exact."""
    sys_ = _stage_system(
        stage=StageConfig(prefetch_window=1 << 20, prefetch_min_run=2,
                          slice_bytes=256 << 10))
    try:
        data = _write(sys_, "seq", 4 << 20, seed=5)
        assert sys_.flush(epoch=1, timeout=30)
        _evict_fully(sys_, "seq")
        r = sys_.fs().open("seq", "r", prefetch=True)
        step = 128 << 10
        got = bytearray()
        got += r.read(step)
        got += r.read(step)                      # sequential run: trigger
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline \
                and sys_.manager.stage_stats["epochs"] < 1:
            time.sleep(0.05)
        assert sys_.manager.stage_stats["epochs"] >= 1, \
            "sequential reads never triggered a stage"
        hits_before = sum(c.stats["bb_hits"] for c in sys_.clients)
        while len(got) < len(data):
            got += r.read(step)
        assert bytes(got) == data
        hits = sum(c.stats["bb_hits"] for c in sys_.clients) - hits_before
        assert hits > 0, "read-ahead staged nothing the reader then hit"
    finally:
        sys_.stop()


def test_mid_stage_server_death_aborts_cleanly_reads_correct():
    """Fault injection: a participant dies while a stage epoch is in
    flight. The manager must abort the epoch (nothing to undo — staged
    bytes are clean copies of durable data) and every byte must still read
    back via the fallback chain."""
    sys_ = _stage_system(drain=DrainConfig(epoch_timeout_s=3.0))
    try:
        # a PFS-only file (written straight to the PFS directory): the
        # stage is the only thing that could make it buffered
        data = np.random.default_rng(6).integers(
            0, 256, 8 << 20, dtype=np.uint8).tobytes()
        with open(os.path.join(sys_.pfs_dir, "pfsonly"), "wb") as f:
            f.write(data)
        caught = threading.Event()
        # deterministic fault injection: a whole stage epoch spans only a
        # few milliseconds on a fast PFS, so a polling assassin thread
        # routinely misses the window. Instead the victim dies on receipt
        # of its own stage_begin — by then the manager's epoch is in
        # flight, and the victim can never report stage_done.
        victim = sorted(sys_.servers)[-1]

        def _die_on_stage_begin(msg):
            sys_.kill_server(victim)
            caught.set()
        sys_.servers[victim]._on_stage_begin = _die_on_stage_begin
        completed = sys_.fs().stage("pfsonly", timeout=15.0)
        assert caught.is_set(), "no stage epoch was ever in flight"
        if not completed:
            # the abort path: bookkeeping must record it and clear the slot
            deadline = time.monotonic() + 10.0
            while time.monotonic() < deadline \
                    and sys_.manager.stage_stats["aborts"] < 1:
                time.sleep(0.05)
            assert sys_.manager.stage_stats["aborts"] >= 1
        assert sys_.manager._stage is None
        # wait for the clients to learn of the death so holders exclude it
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline \
                and len(sys_.manager.dead) < 1:
            time.sleep(0.05)
        got = sys_.fs().open("pfsonly", "r").pread(0, len(data))
        assert got == data, "data lost across mid-stage failover"
        # the system is not wedged: a fresh stage of another file works
        data2 = _write(sys_, "after", 1 << 20, seed=7)
        assert sys_.flush(epoch=2, timeout=30)
        _evict_fully(sys_, "after")
        assert sys_.fs().stage("after", timeout=15.0)
        assert sys_.fs().open("after", "r").pread(0, len(data2)) == data2
    finally:
        sys_.stop()


def test_stage_of_unknown_file_completes_empty():
    """Staging a path with no PFS copy and no buffered bytes is a clean
    no-op epoch, not a hang or an error."""
    sys_ = _stage_system()
    try:
        assert sys_.fs().stage("nope", timeout=10.0)
        assert sys_.manager.stage_stats["staged_bytes"] == 0
        assert sys_.manager.errors == []
    finally:
        sys_.stop()
