"""Async pipelined put path (paper Fig 4): ACK-ledger drain under redirect,
failover re-issue on a dropped primary, write coalescing; plus regression
tests for the read_range gap merge, replication-ledger keying, the
re-replication sentinel, and the flush ring snapshot."""
import os
import time

import numpy as np
import pytest

from repro.core import BBConfig, BurstBufferSystem
from repro.core.server import BBServer, _gaps, _merge_intervals
from repro.core.transport import Message, Transport


@pytest.fixture()
def bb4():
    sys_ = BurstBufferSystem(BBConfig(
        num_servers=4, num_clients=4, placement="iso",
        dram_capacity=8 << 20, stabilize_interval=0.15)).start()
    yield sys_
    sys_.stop()


def _blob(rng, n=32 << 10):
    return rng.integers(0, 256, n, dtype=np.uint8).tobytes()


# ------------------------------------------------------------ ledger basics

def test_put_async_wait_acks_roundtrip(bb4):
    rng = np.random.default_rng(0)
    c = bb4.clients[0]
    blobs = {f"a:{i}": _blob(rng) for i in range(12)}
    for i, (k, v) in enumerate(blobs.items()):
        c.put_async(k, v, file="fa", offset=i * (32 << 10), coalesce=False)
    # the ACK pump drains concurrently, so some ops may already be done
    assert c.outstanding() <= 12
    assert c.wait_acks(15.0)
    assert c.outstanding() == 0
    for k, v in blobs.items():
        assert c.get(k) == v


def test_ledger_drain_under_redirect(bb4):
    """A primary with no free DRAM redirects async puts; the ledger must
    re-issue to the announced target and still drain completely."""
    # client/0 is iso-pinned to server/0; make it always redirect
    bb4.servers["server/0"].store.dram_capacity = 0
    time.sleep(0.6)                        # let free-DRAM gossip propagate
    rng = np.random.default_rng(1)
    c = bb4.clients[0]
    blobs = {f"r:{i}": _blob(rng, 64 << 10) for i in range(8)}
    for k, v in blobs.items():
        c.put_async(k, v, coalesce=False)
    assert c.wait_acks(15.0)
    assert c.stats["redirects"] >= 1
    for k, v in blobs.items():
        assert c.get(k) == v


def test_failover_reissue_on_dropped_primary(bb4):
    """Puts outstanding against a dead server must confirm the failure via
    the predecessor and re-issue to the failover target (paper §IV-B2)."""
    bb4.kill_server("server/2")
    c = bb4.clients[2]                     # iso-pinned to the dead server
    c.put_timeout = 0.8
    c.put_async("fo:k", b"survives-failover", coalesce=False)
    assert c.wait_acks(20.0)
    assert c.stats["failovers"] >= 1
    assert c.get("fo:k") == b"survives-failover"


# -------------------------------------------------------------- coalescing

def test_batched_puts_individually_gettable(bb4):
    rng = np.random.default_rng(2)
    c = bb4.clients[1]
    blobs = {f"b:{i}": _blob(rng, 4 << 10) for i in range(40)}
    for i, (k, v) in enumerate(blobs.items()):
        c.put_async(k, v, file="fb", offset=i * (4 << 10))  # auto-coalesce
    assert c.wait_acks(15.0)
    assert c.stats["batches"] >= 1
    assert c.stats["batched_puts"] == 40
    for k, v in blobs.items():
        assert c.get(k) == v
    stats = bb4.server_stats()
    assert sum(s["batch_puts"] for s in stats.values()) >= 1


def test_batched_puts_flush_byte_exact(bb4):
    """Segments recorded through put_batch must two-phase-flush exactly."""
    rng = np.random.default_rng(3)
    seg = 8 << 10
    blobs = {}
    for ci, c in enumerate(bb4.clients):
        for j in range(4):
            off = (ci * 4 + j) * seg
            blobs[off] = _blob(rng, seg)
            c.put_async(f"fc:{off}", blobs[off], file="fc", offset=off)
    for c in bb4.clients:
        c.flush_batches()
    for c in bb4.clients:
        assert c.wait_acks(15.0)
    assert bb4.flush(epoch=21, timeout=30)
    expect = b"".join(blobs[o] for o in sorted(blobs))
    got = open(os.path.join(bb4.pfs_dir, "fc"), "rb").read()
    assert got == expect


def test_batch_replication_survives_primary_death(bb4):
    """Batched values are chain-replicated: after the storing primary dies,
    replicas must still serve every key."""
    rng = np.random.default_rng(4)
    c = bb4.clients[1]                     # iso-pinned to server/1
    blobs = {f"br:{i}": _blob(rng, 4 << 10) for i in range(10)}
    for k, v in blobs.items():
        c.put_async(k, v)
    c.flush_batches()
    assert c.wait_acks(15.0)
    bb4.kill_server("server/1")
    time.sleep(1.0)                        # stabilization + ring updates
    c.put_timeout = 0.8
    for k, v in blobs.items():
        assert c.get(k) == v


# --------------------------------------------------- regression: read_range

def test_interval_helpers():
    assert _merge_intervals([[5, 9], [0, 3], [2, 6]]) == [[0, 9]]
    assert _gaps([[2, 4], [6, 8]], 0, 10) == [[0, 2], [4, 6], [8, 10]]
    assert _gaps([], 3, 7) == [[3, 7]]
    assert _gaps([[0, 10]], 0, 10) == []


def test_read_range_merges_pfs_into_gaps(tmp_path):
    """Buffered chunks that only partially cover a range must be merged with
    the PFS bytes, not clobbered by them (the buffer is fresher)."""
    tr = Transport()
    srv = BBServer("s0", tr, pfs_dir=str(tmp_path))
    probe = tr.register("probe")
    # PFS has stale 'B's; the buffer holds fresh 'A's for the first 100
    with open(tmp_path / "f", "wb") as fh:
        fh.write(b"B" * 300)
    srv._domain_data["f"] = {0: b"A" * 100}
    srv._on_read_range(Message("read_range", "probe", "s0",
                               {"file": "f", "offset": 0, "length": 300},
                               msg_id=1))
    r = probe.recv(timeout=1.0)
    assert r is not None and r.kind == "range_ack"
    assert r.payload["complete"]
    assert r.payload["data"] == b"A" * 100 + b"B" * 200
    # a gap on both sides of a buffered chunk
    srv._domain_data["f"] = {100: b"C" * 50}
    srv._on_read_range(Message("read_range", "probe", "s0",
                               {"file": "f", "offset": 50, "length": 200},
                               msg_id=2))
    r = probe.recv(timeout=1.0)
    assert r.payload["data"] == b"B" * 50 + b"C" * 50 + b"B" * 100
    assert r.payload["complete"]


# ------------------------------------- regression: replication bookkeeping

def _bare_server(tr, name="s0", ring=("s0", "s1")):
    srv = BBServer(name, tr)
    srv.ring = list(ring)
    srv.alive = {s: True for s in ring}
    return srv


def test_replica_ack_requires_matching_client():
    """A replica_ack for a colliding msg_id but a different client must not
    prematurely ACK an unrelated put."""
    tr = Transport()
    srv = _bare_server(tr)
    client_a = tr.register("client/a")
    orig = Message("put", "client/a", "s0", {"key": "k", "value": b"v"},
                   msg_id=7)
    srv._pending_primary[("client/a", 7)] = ["client/a", 1, orig]
    # stray ack: same msg_id, wrong client
    srv._on_replica_ack(Message("replica_ack", "s1", "s0",
                                {"primary_msg": 7, "client": "client/b",
                                 "key": "k"}, msg_id=8))
    assert ("client/a", 7) in srv._pending_primary
    assert client_a.recv(timeout=0.05) is None
    # matching ack completes the put
    srv._on_replica_ack(Message("replica_ack", "s1", "s0",
                                {"primary_msg": 7, "client": "client/a",
                                 "key": "k"}, msg_id=9))
    assert ("client/a", 7) not in srv._pending_primary
    r = client_a.recv(timeout=1.0)
    assert r is not None and r.kind == "put_ack"


def test_re_replicate_sentinel_not_acked():
    """Re-replication copies carry the primary_msg=None sentinel: the
    receiving replica stores them but must not emit a replica_ack, and a
    stray sentinel ack must be ignored by the primary."""
    tr = Transport()
    srv = _bare_server(tr, name="s1", ring=("s0", "s1"))
    primary_inbox = tr.register("s0")
    srv._on_replica_put(Message("replica_put", "s0", "s1", {
        "key": "k", "value": b"v", "chain": [], "primary": "s0",
        "primary_msg": None, "client": None, "file": None, "offset": 0},
        msg_id=5))
    assert srv.store.get("k") == b"v"
    assert primary_inbox.recv(timeout=0.05) is None   # no ack sent
    # and the primary side ignores sentinel acks outright
    srv._pending_primary[("c", 1)] = ["c", 1, None]
    srv._on_replica_ack(Message("replica_ack", "s0", "s1",
                                {"primary_msg": None, "client": "c",
                                 "key": "k"}, msg_id=6))
    assert srv._pending_primary[("c", 1)][1] == 1     # untouched


def test_re_replicate_restores_copies():
    tr = Transport()
    srv_a = _bare_server(tr, name="a", ring=("a", "b"))
    srv_b = _bare_server(tr, name="b", ring=("a", "b"))
    srv_a.store.put("k", b"v")
    srv_a._re_replicate()
    msg = srv_b.ep.recv(timeout=1.0)
    assert msg is not None and msg.kind == "replica_put"
    srv_b._dispatch(msg)
    assert srv_b.store.get("k") == b"v"


# ------------------------------------------ regression: flush ring snapshot

def test_write_pfs_uses_flush_ring_snapshot(tmp_path):
    """Domain ownership during the PFS write must come from the ring
    snapshot taken at flush start, not the live membership view — otherwise
    a death observed mid-flush silently re-partitions the file."""
    tr = Transport()
    manager = tr.register("manager")
    srv = BBServer("a", tr, pfs_dir=str(tmp_path))
    srv.ring = ["a", "b"]
    srv.alive = {"a": True, "b": True}
    size = 2 << 20
    st = srv._flush_state(0)
    assert st["ring"] == ["a", "b"]
    srv.lookup_table["f"] = size
    st["epoch_sizes"] = {"f": size}       # the epoch's agreed size map
    srv._domain_data["f"] = {0: b"x" * (1 << 20)}     # a's snapshot domain
    # membership changes mid-flush: b is declared dead
    srv.alive["b"] = False
    srv._write_pfs(0, st)
    done = manager.recv(timeout=1.0)
    assert done is not None and done.kind == "flush_done"
    # a wrote ONLY its snapshot domain [0, 1MiB), not the whole file as the
    # live alive_ring() view would dictate
    assert done.payload["bytes"] == 1 << 20
    assert os.path.getsize(tmp_path / "f") == 1 << 20


def test_all_servers_dead_degrades_cleanly():
    """Total server loss: wait_acks reports failure instead of crashing in
    placement lookup, and sync put/get degrade to False/None."""
    sys_ = BurstBufferSystem(BBConfig(num_servers=2, num_clients=1,
                                      dram_capacity=8 << 20)).start()
    try:
        c = sys_.clients[0]
        sys_.kill_server("server/0")
        sys_.kill_server("server/1")
        c.put_timeout = 0.5
        c.put_async("dead", b"y" * 1000, coalesce=False)
        assert c.wait_acks(10.0) is False
        assert c.failed_keys() == ["dead"]
        assert c.put("dead2", b"z") is False
        assert c.get("dead") is None
    finally:
        sys_.stop()


# --------------------------------------------------- async checkpoint save

def test_async_and_batched_checkpoint_roundtrip():
    """restore() must be bit-identical through async- and batched-saved
    checkpoints (the paper Fig 4 path under the checkpoint manager)."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.checkpoint.bbckpt import BBCheckpointManager

    def tree(seed):
        k = jax.random.PRNGKey(seed)
        ks = jax.random.split(k, 2)
        return {"w": jax.random.normal(ks[0], (128, 64), jnp.float32),
                "b": jax.random.normal(ks[1], (64,), jnp.float32),
                "step": jnp.asarray(seed, jnp.int32)}

    with BurstBufferSystem(BBConfig(num_servers=4, num_clients=4,
                                    dram_capacity=64 << 20)) as bb:
        for step, mode in ((1, "async"), (2, "batched")):
            mgr = BBCheckpointManager(bb, io_mode=mode,
                                      chunk_bytes=16 << 10)
            t = tree(step)
            mgr.save(step, t, blocking_flush=True)
            restored, got_step = mgr.restore(tree(99), step=step)
            assert got_step == step
            for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(t)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
