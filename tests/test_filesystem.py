"""BBFileSystem / BBFile / BBFuture: the unified file-session API.

Covers the ISSUE-2 acceptance surface: open/write/sync/read roundtrips,
future error propagation (failures surface on the future / the sync()
barrier, not on a shared error list), failover mid-file, the wait_acks
timeout regression (a drain can never report success while ops are still
buffered), and namespace metadata (stat/exists/listdir/unlink)."""
import os
import time

import numpy as np
import pytest

from repro.core import (BBConfig, BBFuture, BBWriteError, BurstBufferSystem)


@pytest.fixture()
def bb4():
    sys_ = BurstBufferSystem(BBConfig(
        num_servers=4, num_clients=4, placement="iso",
        dram_capacity=8 << 20, stabilize_interval=0.15)).start()
    yield sys_
    sys_.stop()


def _blob(rng, n):
    return rng.integers(0, 256, n, dtype=np.uint8).tobytes()


# ------------------------------------------------------------ write + read

def test_open_write_sync_read_roundtrip(bb4):
    rng = np.random.default_rng(0)
    data = _blob(rng, 700_000)
    fs = bb4.fs()
    with fs.open("f1", "w", policy="async", chunk_bytes=64 << 10) as f:
        fut = f.pwrite(data, 0)
        f.sync()
        assert fut.done() and fut.result() is True
    r = fs.open("f1", "r")
    assert r.size == len(data)
    assert r.pread(0, len(data)) == data
    # unaligned interior range crossing chunk boundaries
    assert r.pread(100_001, 200_000) == data[100_001:300_001]


def test_write_advances_cursor_and_seek(bb4):
    fs = bb4.fs()
    with fs.open("f2", "w", policy="batched") as f:
        f.write(b"hello ")
        f.write(b"world")
        assert f.tell() == 11
    r = fs.open("f2", "r")
    assert r.read() == b"hello world"
    r.seek(6)
    assert r.read(5) == b"world"


def test_append_mode_continues_at_size(bb4):
    fs = bb4.fs()
    with fs.open("f3", "w") as f:
        f.write(b"part-one|")
    with fs.open("f3", "a") as f:
        assert f.tell() == 9
        f.write(b"part-two")
    assert fs.open("f3", "r").read() == b"part-one|part-two"


def test_flush_byte_exact_through_handles(bb4):
    """Chunks written through handles must two-phase-flush byte-exactly,
    and remain readable through the same handle API afterwards."""
    rng = np.random.default_rng(1)
    data = _blob(rng, 512 << 10)
    fs = bb4.fs()
    with fs.open("ckpt_fs", "w", policy="batched",
                 chunk_bytes=32 << 10) as f:
        f.pwrite(data, 0)
    assert bb4.flush(epoch=31, timeout=30)
    assert open(os.path.join(bb4.pfs_dir, "ckpt_fs"), "rb").read() == data
    assert fs.open("ckpt_fs", "r").pread(0, len(data)) == data


def test_read_falls_back_to_pfs_after_eviction(bb4):
    rng = np.random.default_rng(2)
    data = _blob(rng, 256 << 10)
    fs = bb4.fs()
    with fs.open("evicted_f", "w", chunk_bytes=32 << 10) as f:
        f.pwrite(data, 0)
    assert bb4.flush(epoch=32, timeout=30)
    bb4.evict("evicted_f")
    time.sleep(0.3)                      # evict_epoch is fire-and-forget
    assert fs.open("evicted_f", "r").pread(0, len(data)) == data


# --------------------------------------------------------- error propagation

def test_future_error_propagation_all_servers_dead():
    """Per-op failures surface as BBWriteError on the future and on the
    sync() barrier — not on a shared last_failed snapshot."""
    sys_ = BurstBufferSystem(BBConfig(num_servers=2, num_clients=1,
                                      dram_capacity=8 << 20)).start()
    try:
        for c in sys_.clients:
            c.put_timeout = 0.4
        fs = sys_.fs()
        f = fs.open("doomed", "w", policy="async")
        sys_.kill_server("server/0")
        sys_.kill_server("server/1")
        fut = f.pwrite(b"x" * 1000, 0)
        exc = fut.exception(timeout=15.0)
        assert isinstance(exc, BBWriteError)
        assert "doomed:0" in exc.keys
        with pytest.raises(BBWriteError):
            f.sync(timeout=15.0)
        with pytest.raises(BBWriteError):
            fut.result()
    finally:
        sys_.stop()


def test_sync_collects_all_failed_chunk_keys():
    sys_ = BurstBufferSystem(BBConfig(num_servers=2, num_clients=2,
                                      dram_capacity=8 << 20)).start()
    try:
        for c in sys_.clients:
            c.put_timeout = 0.4
        fs = sys_.fs()
        f = fs.open("doomed2", "w", policy="async", chunk_bytes=1 << 10)
        sys_.kill_server("server/0")
        sys_.kill_server("server/1")
        f.pwrite(b"y" * 4096, 0)         # 4 chunks, all doomed
        with pytest.raises(BBWriteError) as ei:
            f.sync(timeout=20.0)
        assert len(ei.value.keys) >= 1   # every failed chunk is named
    finally:
        sys_.stop()


def test_failover_mid_file_write(bb4):
    """Kill a server while a file is half-written: outstanding chunks must
    confirm the failure, re-issue to the failover owner, and the sync
    barrier must still succeed with all bytes readable."""
    rng = np.random.default_rng(3)
    data = _blob(rng, 256 << 10)
    for c in bb4.clients:
        c.put_timeout = 0.8
    fs = bb4.fs()
    f = fs.open("failover_f", "w", policy="async", chunk_bytes=16 << 10)
    f.pwrite(data[:128 << 10], 0)
    bb4.kill_server("server/2")
    f.pwrite(data[128 << 10:], 128 << 10)
    f.sync(timeout=30.0)
    assert sum(c.stats["failovers"] for c in bb4.clients) >= 1
    got = fs.open("failover_f", "r").pread(0, len(data))
    assert got == data


# ------------------------------------------------------- wait_acks regression

def test_wait_acks_timeout_cannot_report_true_with_unflushed_items(bb4):
    """Regression (ISSUE 2): if coalesced items cannot be shipped, a drain
    timeout must report failure — outstanding() is authoritative, so items
    parked in the coalesce buffer can never be mistaken for acked."""
    c = bb4.clients[0]
    c.put_async("stuck:0", b"z" * 100, coalesce=True)
    assert c.outstanding() == 1
    c.flush_coalesced = lambda: None          # flush path wedged
    assert c.wait_acks(0.3) is False
    assert "stuck:0" in c.failed_keys()
    assert c.outstanding() == 0               # abandoned, not leaked


def test_wait_acks_failure_also_on_future(bb4):
    c = bb4.clients[0]
    fut = c.put_async("stuck:1", b"z" * 100, coalesce=True)
    c.flush_coalesced = lambda: None
    assert c.wait_acks(0.3) is False
    assert isinstance(fut.exception(1.0), BBWriteError)


# ------------------------------------------------------------------ BBFuture

def test_future_gather_success_and_failure():
    f1, f2 = BBFuture("a"), BBFuture("b")
    g = BBFuture.gather([f1, f2])
    assert not g.done()
    f1._set_result(True)
    assert not g.done()
    f2._set_result(True)
    assert g.result(1.0) is True

    f3, f4 = BBFuture("c"), BBFuture("d")
    g2 = BBFuture.gather([f3, f4])
    f3._set_exception(BBWriteError("c", "boom"))
    assert isinstance(g2.exception(1.0), BBWriteError)

    assert BBFuture.gather([]).done()


def test_future_first_win_completion():
    f = BBFuture("k")
    f._set_exception(BBWriteError("k", "timeout"))
    f._set_result(True)                       # late ACK must be ignored
    assert isinstance(f.exception(1.0), BBWriteError)


def test_future_result_timeout():
    with pytest.raises(TimeoutError):
        BBFuture("k").result(0.05)


# ----------------------------------------------------------------- namespace

def test_stat_exists_listdir_unlink(bb4):
    fs = bb4.fs()
    assert not fs.exists("nsfile")
    with pytest.raises(FileNotFoundError):
        fs.open("nsfile", "r")
    with fs.open("nsfile", "w") as f:
        f.pwrite(b"q" * 5000, 0)
    st = fs.stat("nsfile")
    assert st["size"] == 5000 and st["buffered"] == 5000
    assert fs.exists("nsfile")
    assert "nsfile" in fs.listdir()
    assert fs.listdir("ns") == ["nsfile"]
    fs.unlink("nsfile")
    time.sleep(0.3)                      # eviction is fire-and-forget
    assert not fs.exists("nsfile")


def test_empty_file_visible_via_namespace(bb4):
    """A zero-byte synced file has no chunks and no PFS copy — only the
    manager namespace knows it. stat/exists/open('r') must still see it."""
    fs = bb4.fs()
    with fs.open("empty_f", "w"):
        pass
    assert fs.exists("empty_f")
    assert fs.stat("empty_f")["size"] == 0
    assert fs.open("empty_f", "r").read() == b""
    assert "empty_f" in fs.listdir()


def test_sync_failure_consumed_from_legacy_drain():
    """A failure observed on the sync() barrier must not ALSO fail a later
    legacy wait_acks() cycle of unrelated successful ops."""
    sys_ = BurstBufferSystem(BBConfig(num_servers=2, num_clients=1,
                                      dram_capacity=8 << 20)).start()
    try:
        c = sys_.clients[0]
        c.put_timeout = 0.4
        fs = sys_.fs()
        f = fs.open("observed", "w", policy="async")
        sys_.kill_server("server/0")
        sys_.kill_server("server/1")
        f.pwrite(b"x" * 1000, 0)
        with pytest.raises(BBWriteError):
            f.sync(timeout=15.0)
        assert c.wait_acks(1.0) is True          # nothing outstanding
        assert c.failed_keys() == []
    finally:
        sys_.stop()


def test_closed_handle_rejects_io(bb4):
    fs = bb4.fs()
    f = fs.open("closed_f", "w")
    f.write(b"x")
    f.close()
    with pytest.raises(ValueError):
        f.write(b"y")
    r = fs.open("closed_f", "r")
    with pytest.raises(ValueError):
        r.pwrite(b"y", 0)


def test_reopen_w_truncates_previous_incarnation(bb4):
    """A shorter rewrite must never read back stale tail bytes — buffered
    chunks, lookup-table entries, and the PFS copy are all dropped."""
    rng = np.random.default_rng(9)
    long_data = _blob(rng, 200 << 10)
    short_data = _blob(rng, 50 << 10)
    fs = bb4.fs()
    with fs.open("trunc_f", "w", chunk_bytes=16 << 10) as f:
        f.pwrite(long_data, 0)
    assert bb4.flush(epoch=41, timeout=30)       # durable long incarnation
    with fs.open("trunc_f", "w", chunk_bytes=16 << 10) as f:
        f.pwrite(short_data, 0)
    assert fs.stat("trunc_f")["size"] == len(short_data)
    r = fs.open("trunc_f", "r")
    assert r.read() == short_data
    # reading past the new EOF short-reads instead of resurrecting old bytes
    assert r.pread(len(short_data), 1000) == b""


def test_reopen_w_truncates_unsynced_incarnation(bb4):
    """A crashed writer that never reached sync() still landed chunks;
    the next open-for-write must truncate them too."""
    fs = bb4.fs()
    f = fs.open("crash_f", "w", policy="sync", chunk_bytes=4 << 10)
    f.pwrite(b"OLD!" * 4096, 0)              # 16 KB landed, no sync/close
    with fs.open("crash_f", "w") as g:
        g.pwrite(b"new" * 100, 0)
    assert fs.stat("crash_f")["size"] == 300
    assert fs.open("crash_f", "r").read() == b"new" * 100


def test_unlink_is_exact_not_prefix(bb4):
    """unlink("run") must not destroy "run_info.txt" (chunk eviction goes
    through exact-match file_truncate, not prefix eviction)."""
    fs = bb4.fs()
    with fs.open("run", "w") as f:
        f.write(b"R" * 2000)
    with fs.open("run_info.txt", "w") as f:
        f.write(b"I" * 2000)
    fs.unlink("run")
    time.sleep(0.3)
    assert not fs.exists("run")
    assert fs.open("run_info.txt", "r").read() == b"I" * 2000


def test_read_after_write_same_handle(bb4):
    """pwrite must invalidate the cached chunk manifest so later reads on
    the same handle see the new chunks."""
    fs = bb4.fs()
    f = fs.open("raw_f", "w", chunk_bytes=4 << 10)
    f.pwrite(b"A" * 4096, 0)
    f.sync()
    assert f.pread(0, 10) == b"A" * 10           # caches the manifest
    f.pwrite(b"B" * 4096, 4096)
    f.sync()
    assert f.pread(4096, 10) == b"B" * 10


def test_open_w_truncates_legacy_shim_incarnation(bb4):
    """Chunks written through the legacy put(file=...) shims share the key
    namespace; open('w') must find and truncate them via the servers'
    manifests even though the manager never saw the file."""
    c = bb4.clients[0]
    assert c.put("legacy_f:0", b"Y" * 1000, file="legacy_f", offset=0)
    assert c.put("legacy_f:1000", b"Y" * 1000, file="legacy_f", offset=1000)
    fs = bb4.fs()
    with fs.open("legacy_f", "w") as f:
        f.pwrite(b"z" * 500, 0)
    assert fs.stat("legacy_f")["size"] == 500
    assert fs.open("legacy_f", "r").read() == b"z" * 500


def test_pread_short_reads_at_eof_instead_of_zero_fill(bb4):
    fs = bb4.fs()
    with fs.open("short_f", "w") as f:
        f.pwrite(b"Q" * 100, 0)
    r = fs.open("short_f", "r")
    assert r.pread(50, 500) == b"Q" * 50         # clamped, not zero-padded
    assert r.pread(100, 10) == b""


def test_blocking_put_failure_does_not_poison_wait_acks():
    """Regression: a put() the caller already saw fail must not make a
    later wait_acks() of unrelated, successful async ops report False."""
    from repro.core import BBClient, BBServer, Transport
    tr = Transport()
    srv = BBServer("s0", tr, replication=1)
    srv.ring, srv.alive = ["s0"], {"s0": True}
    srv.start()
    c = BBClient("c0", tr, replication=1, put_timeout=0.3)
    try:
        assert c.put("doomed", b"v") is False    # no ring yet -> fails
        c._set_ring(["s0"])
        fut = c.put_async("fine", b"x" * 100, coalesce=False)
        assert c.wait_acks(5.0) is True
        assert c.failed_keys() == []
        assert fut.result(1.0) is True
    finally:
        c.close()
        srv.stop()


def test_client_close_fails_inflight_futures():
    """Teardown must complete every outstanding future so no thread can
    block forever on a write the pump will never finish."""
    sys_ = BurstBufferSystem(BBConfig(num_servers=2, num_clients=1,
                                      dram_capacity=8 << 20)).start()
    try:
        c = sys_.clients[0]
        sys_.kill_server("server/0")
        sys_.kill_server("server/1")
        fut = c.put_async("never", b"n" * 100, coalesce=False)
        c.close()
        assert isinstance(fut.exception(2.0), BBWriteError)
    finally:
        sys_.stop()


def test_compat_shims_share_the_pipeline(bb4):
    """put/put_async are shims over submit(): bytes written through them are
    visible to file handles and vice versa (same key namespace)."""
    c = bb4.clients[0]
    assert c.put("shim_f:0", b"A" * 100, file="shim_f", offset=0)
    fut = c.put_async("shim_f:100", b"B" * 100, file="shim_f", offset=100,
                      coalesce=False)
    assert c.wait_acks(10.0)
    assert fut.result(1.0) is True
    fs = bb4.fs()
    assert fs.open("shim_f", "r").pread(0, 200) == b"A" * 100 + b"B" * 100
