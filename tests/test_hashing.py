"""Property tests for data placement (paper §II/§V)."""
import string

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.hashing import IsoPlacement, KetamaRing, RendezvousHash

servers_st = st.lists(
    st.text(string.ascii_lowercase + string.digits, min_size=3, max_size=8),
    min_size=2, max_size=12, unique=True)
keys_st = st.lists(st.text(string.printable, min_size=1, max_size=24),
                   min_size=1, max_size=60, unique=True)


@given(servers_st, keys_st)
@settings(max_examples=50, deadline=None)
def test_ketama_lookup_stable_and_valid(servers, keys):
    ring = KetamaRing(servers)
    for k in keys:
        owner = ring.lookup(k)
        assert owner in servers
        assert ring.lookup(k) == owner          # deterministic


@given(servers_st, keys_st)
@settings(max_examples=50, deadline=None)
def test_ketama_minimal_remap_on_removal(servers, keys):
    """Removing one server only remaps keys it owned (consistent hashing)."""
    ring = KetamaRing(servers)
    before = {k: ring.lookup(k) for k in keys}
    victim = servers[0]
    ring.remove_server(victim)
    for k, owner in before.items():
        if owner != victim:
            assert ring.lookup(k) == owner
        else:
            assert ring.lookup(k) != victim


@given(servers_st, keys_st)
@settings(max_examples=30, deadline=None)
def test_ketama_remap_on_join_only_to_new(servers, keys):
    ring = KetamaRing(servers)
    before = {k: ring.lookup(k) for k in keys}
    ring.add_server("zz-new-server")
    for k, owner in before.items():
        after = ring.lookup(k)
        assert after == owner or after == "zz-new-server"


@given(servers_st, st.integers(min_value=2, max_value=3), keys_st)
@settings(max_examples=30, deadline=None)
def test_ketama_successors_distinct(servers, n, keys):
    ring = KetamaRing(servers)
    n = min(n, len(servers))
    for k in keys:
        succ = ring.successors(k, n)
        assert len(succ) == n
        assert len(set(succ)) == n
        assert succ[0] == ring.lookup(k)


@given(servers_st, st.integers(min_value=0, max_value=1000))
@settings(max_examples=50, deadline=None)
def test_iso_pins_client_to_one_server(servers, client_idx):
    iso = IsoPlacement(servers)
    assert iso.lookup_for_client(client_idx) == \
        servers[client_idx % len(servers)]


@given(servers_st, keys_st)
@settings(max_examples=50, deadline=None)
def test_rendezvous_minimal_remap(servers, keys):
    h = RendezvousHash(servers)
    before = {k: h.lookup(k) for k in keys}
    victim = servers[-1]
    h.remove_server(victim)
    for k, owner in before.items():
        if owner != victim:
            assert h.lookup(k) == owner


def test_ketama_balance_rough():
    """With vnodes, 8 servers should each own a non-trivial key share."""
    servers = [f"server/{i}" for i in range(8)]
    ring = KetamaRing(servers)
    counts = {s: 0 for s in servers}
    for i in range(4000):
        counts[ring.lookup(f"key-{i}")] += 1
    assert min(counts.values()) > 4000 / 8 / 4     # within 4x of fair share
