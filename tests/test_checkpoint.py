"""Checkpoint serializer + BBCheckpointManager: round-trips, quantization
error bounds, restore fast paths, replica failover restore."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import serializer as ser
from repro.checkpoint.bbckpt import BBCheckpointManager
from repro.core import BBConfig, BurstBufferSystem


def _tree(seed=0, dtype=jnp.float32):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 4)
    return {
        "params": {"w": jax.random.normal(ks[0], (64, 32), dtype),
                   "b": jax.random.normal(ks[1], (32,), dtype)},
        "opt_state": {"m": jax.random.normal(ks[2], (64, 32), dtype),
                      "step": jnp.asarray(7, jnp.int32)},
        "data": {"step": jnp.asarray(13, jnp.int32)},
    }


def test_serialize_roundtrip_bit_exact_f32():
    tree = _tree()
    payloads, manifest = ser.serialize_tree(tree)
    out = ser.deserialize_tree(tree, payloads, manifest)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serialize_roundtrip_bf16():
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (33, 17),
                                   jnp.bfloat16)}
    payloads, manifest = ser.serialize_tree(tree)
    out = ser.deserialize_tree(tree, payloads, manifest)
    assert out["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(out["w"], np.float32), np.asarray(tree["w"], np.float32))


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_quantized_moments_error_bound(seed):
    rng = np.random.default_rng(seed)
    leaf = jnp.asarray(rng.normal(0, 0.02, (64, 64)), jnp.float32)
    tree = {"opt_state": {"m": leaf}}
    payloads, manifest = ser.serialize_tree(tree, ser.default_quant_policy)
    assert manifest["leaves"][0]["quant"]
    out = ser.deserialize_tree(tree, payloads, manifest)
    err = np.abs(np.asarray(out["opt_state"]["m"]) - np.asarray(leaf))
    # blockwise int8: |err| <= max|block| / 254 + eps
    assert err.max() <= np.abs(np.asarray(leaf)).max() / 127 + 1e-6


def test_manager_save_restore_roundtrip():
    with BurstBufferSystem(BBConfig(num_servers=4, num_clients=4,
                                    dram_capacity=64 << 20)) as bb:
        mgr = BBCheckpointManager(bb, quantize=False)
        tree = _tree(1)
        mgr.save(5, tree, blocking_flush=True)
        restored, step = mgr.restore(_tree(99))
        assert step == 5
        for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(tree)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_latest_of_many_and_retention():
    with BurstBufferSystem(BBConfig(num_servers=4, num_clients=4,
                                    dram_capacity=64 << 20)) as bb:
        mgr = BBCheckpointManager(bb, quantize=False, retention=2)
        for step in (1, 2, 3):
            mgr.save(step, _tree(step), blocking_flush=True)
        assert sorted(mgr.saved_steps) == [2, 3]     # retention evicted 1
        restored, step = mgr.restore(_tree(0))
        assert step == 3
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["w"]),
            np.asarray(_tree(3)["params"]["w"]))


def test_restore_from_pfs_after_eviction():
    """Evicted epochs are durably on the PFS; restore falls back there."""
    with BurstBufferSystem(BBConfig(num_servers=4, num_clients=4,
                                    dram_capacity=64 << 20)) as bb:
        mgr = BBCheckpointManager(bb, quantize=False, retention=1)
        mgr.save(1, _tree(1), blocking_flush=True)
        mgr.save(2, _tree(2), blocking_flush=True)
        restored, step = mgr.restore(_tree(0), step=1)   # evicted from BB
        assert step == 1
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["w"]),
            np.asarray(_tree(1)["params"]["w"]))


def test_restore_survives_server_failure():
    """Kill a server after save: replicas must still reconstruct the full
    checkpoint (paper §IV-B data recovery)."""
    with BurstBufferSystem(BBConfig(num_servers=4, num_clients=4,
                                    dram_capacity=64 << 20,
                                    stabilize_interval=0.1)) as bb:
        mgr = BBCheckpointManager(bb, quantize=False)
        tree = _tree(2)
        mgr.save(9, tree, blocking_flush=True)
        bb.kill_server("server/1")
        time.sleep(1.0)               # stabilization + client updates
        for c in bb.clients:
            c.put_timeout = 0.8
        restored, step = mgr.restore(_tree(0))
        assert step == 9
        for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(tree)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
