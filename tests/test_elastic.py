"""Elastic scaling: restore a BB checkpoint onto a different (smaller) mesh
via logical-key resharding, plus flush-domain work stealing."""
import subprocess
import sys
import os
import textwrap

import numpy as np
import pytest

from repro.launch.elastic import rebalance_domains

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_rebalance_domains_penalizes_stragglers():
    servers = ["s0", "s1", "s2", "s3"]
    tp = {"s0": 100.0, "s1": 100.0, "s2": 100.0, "s3": 10.0}   # s3 straggles
    weighted = rebalance_domains(tp, servers)
    assert weighted.count("s3") == 0          # below slack -> no domains
    assert weighted.count("s0") >= 1


def test_rebalance_domains_balanced_noop():
    servers = ["a", "b"]
    assert sorted(rebalance_domains({"a": 5.0, "b": 5.0}, servers)) == \
        ["a", "b"]


@pytest.mark.slow
def test_elastic_restore_smaller_mesh_subprocess():
    """Save on a (2,2) mesh, restore onto a degraded (1,2) mesh: values must
    be identical (shards are keyed by logical path, not device)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    code = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_config, reduced
        from repro.core import BBConfig, BurstBufferSystem
        from repro.checkpoint.bbckpt import BBCheckpointManager
        from repro.launch.elastic import degraded_mesh, elastic_restore
        from repro.launch.mesh import make_host_mesh
        from repro.launch.sharding import RuleSet, use_rules
        from repro.models.registry import build_model
        from repro.runtime.train_step import (init_train_state,
                                              make_optimizer)

        cfg = reduced(get_config("h2o-danube-1.8b"))
        model = build_model(cfg)
        opt = make_optimizer(cfg)

        mesh = make_host_mesh(data=2, model=2)
        rules = RuleSet(mesh)
        with mesh, use_rules(rules):
            state = init_train_state(cfg, model, opt, jax.random.PRNGKey(0))

        with BurstBufferSystem(BBConfig(num_servers=2, num_clients=2,
                                        dram_capacity=64 << 20)) as bb:
            mgr = BBCheckpointManager(bb, quantize=False)
            ck = {"params": state.params, "opt_state": state.opt_state}
            mgr.save(3, ck, blocking_flush=True)

            small = degraded_mesh(total_hosts=4, lost_hosts=2, model_axis=2)
            placed, step = elastic_restore(mgr, cfg, model, opt, small, ck)
            assert step == 3
            for a, b in zip(jax.tree.leaves(placed["params"]),
                            jax.tree.leaves(state.params)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            # restored arrays live on the degraded mesh
            leaf = jax.tree.leaves(placed["params"])[0]
            assert len(leaf.sharding.mesh.devices.ravel()) == 2
        print("ELASTIC-OK")
    """
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ELASTIC-OK" in out.stdout
