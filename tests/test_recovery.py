"""ISSUE 8: whole-cluster crash recovery from the SSD logs.

LogStore-level: the self-describing record log replays last-gen-wins with
torn tails truncated, tombstones converge deletes/evicts, the clean flag
survives, and the cached read handle survives compaction races.
Manager-level: flush_complete is no longer vacuously True on an empty ring,
and the append-only journal replays namespace/lookup/epoch counters.
System-level: a killed server restarts over its surviving log and rejoins
the ring byte-exact; a whole-cluster restart recovers acked SSD-resident
data end to end.
"""
import json
import os
import threading
import time

import numpy as np
import pytest

from repro.core import BBConfig, BBManager, BurstBufferSystem, Transport
from repro.core.manager import DRAIN_EPOCH_BASE, STAGE_EPOCH_BASE
from repro.core.tiering import LogStore


def _ssd_store(tmp_path, name="r0", **kw):
    kw.setdefault("ssd_capacity", 1 << 30)
    return LogStore(0, str(tmp_path), name=name, **kw)


# --------------------------------------------------- LogStore log replay

def test_recover_rebuilds_index_byte_exact(tmp_path):
    store = _ssd_store(tmp_path)
    data = {f"f:{i * 100}": os.urandom(3000 + i) for i in range(30)}
    for k, v in data.items():
        store.put(k, v)                     # dram_capacity=0: all spill
    assert all(store.tier_of(k) == "ssd" for k in data)
    restarted = _ssd_store(tmp_path)        # same dir: recover, not wipe
    assert sorted(restarted.recovered_keys) == sorted(data)
    assert restarted.ssd_used == store.ssd_used
    for k, v in data.items():
        assert restarted.get(k) == v, k
    # generation counter resumes past every replayed record: the next put
    # must outrank anything already in the log
    restarted.put("f:0", b"newer")
    assert restarted.gen_of("f:0") > store.gen_of("f:2900")


def test_recover_truncates_torn_tail(tmp_path):
    store = _ssd_store(tmp_path)
    for i in range(10):
        store.put(f"k:{i}", bytes([i]) * 2000)
    good_size = os.path.getsize(store._ssd_path)
    with open(store._ssd_path, "ab") as fh:
        fh.write(b"BBR1" + os.urandom(40))  # torn record: magic, no CRC
    restarted = _ssd_store(tmp_path)
    assert len(restarted.recovered_keys) == 10
    assert os.path.getsize(restarted._ssd_path) == good_size, \
        "torn tail must be truncated away"
    for i in range(10):
        assert restarted.get(f"k:{i}") == bytes([i]) * 2000
    # the truncated log appends cleanly (the invariant the truncation buys)
    restarted.put("k:10", b"after-torn-tail" * 100)
    again = _ssd_store(tmp_path)
    assert again.get("k:10") == b"after-torn-tail" * 100


def test_recover_mid_record_crash_truncates(tmp_path):
    """A crash mid-append leaves a half-written record: CRC catches it."""
    store = _ssd_store(tmp_path)
    for i in range(8):
        store.put(f"k:{i}", b"v" * 4096)
    size = os.path.getsize(store._ssd_path)
    with open(store._ssd_path, "r+b") as fh:
        fh.truncate(size - 1000)            # tear the LAST record
    restarted = _ssd_store(tmp_path)
    assert len(restarted.recovered_keys) == 7
    for i in range(7):
        assert restarted.get(f"k:{i}") == b"v" * 4096
    assert restarted.get("k:7") is None


def test_recover_last_gen_wins_over_rewrites(tmp_path):
    """Rewrites leave multiple records per key; compact() may then reorder
    them (it rewrites in offset order, not gen order) — replay must compare
    generations, never trust file order."""
    store = _ssd_store(tmp_path)
    for ver in range(3):
        for i in range(6):
            store.put(f"k:{i}", f"v{ver}-{i}".encode() * 50)
    restarted = _ssd_store(tmp_path)
    for i in range(6):
        assert restarted.get(f"k:{i}") == f"v2-{i}".encode() * 50, \
            "replay resurrected a stale generation"
    # now compact (drops dead records, reorders survivors) and re-recover
    restarted.delete("k:0")
    restarted.compact()
    again = _ssd_store(tmp_path)
    assert again.get("k:0") is None
    for i in range(1, 6):
        assert again.get(f"k:{i}") == f"v2-{i}".encode() * 50


def test_tombstone_replay_of_evicted_and_deleted_keys(tmp_path):
    store = _ssd_store(tmp_path)
    for i in range(10):
        store.put(f"k:{i}", b"e" * 1024)
    store.evict("k:3")                      # drained: PFS copy is truth
    store.delete("k:4")                     # unlinked outright
    restarted = _ssd_store(tmp_path)
    assert restarted.get("k:3") is None
    assert restarted.get("k:4") is None
    assert "k:3" not in restarted.recovered_keys
    assert "k:4" not in restarted.recovered_keys
    assert len(restarted.recovered_keys) == 8


def test_clean_flag_survives_restart(tmp_path):
    store = _ssd_store(tmp_path)
    store.put("c:0", b"staged" * 100, clean=True)
    store.put("d:0", b"dirty" * 100)
    assert store.is_clean("c:0") and not store.is_clean("d:0")
    restarted = _ssd_store(tmp_path)
    assert restarted.is_clean("c:0"), \
        "clean flag lost: recovered staged bytes would need a flush epoch"
    assert not restarted.is_clean("d:0")


def test_spill_is_fsynced_before_index_publishes(tmp_path):
    """The index may only say tier 'ssd' once the bytes are recoverable:
    a restart immediately after a spill must read every spilled key."""
    store = _ssd_store(tmp_path)
    store.put("k:0", b"z" * 8192)           # spill happens inside put()
    assert store.tier_of("k:0") == "ssd"
    restarted = _ssd_store(tmp_path)        # no close(), no extra flush
    assert restarted.get("k:0") == b"z" * 8192


# ------------------------------------- cached read handle (ISSUE 8 sat. 3)

def test_ssd_reads_reuse_cached_handle_and_survive_compact(tmp_path):
    store = LogStore(32 << 10, str(tmp_path), name="h0",
                     segment_bytes=8 << 10)
    data = {f"k:{i}": os.urandom(4 << 10) for i in range(32)}
    for k, v in data.items():
        store.put(k, v)
    ssd_keys = [k for k in data if store.tier_of(k) == "ssd"]
    assert ssd_keys
    assert store.get(ssd_keys[0]) == data[ssd_keys[0]]
    fh = store._read_fh
    assert fh is not None, "SSD read must cache its handle"
    assert store.get(ssd_keys[-1]) == data[ssd_keys[-1]]
    assert store._read_fh is fh, "handle must be reused across reads"

    stop = threading.Event()
    errors = []

    def _reader():
        while not stop.is_set():
            for k, v in data.items():
                got = store.get(k)
                if got is not None and got != v:
                    errors.append(k)
                    return

    threads = [threading.Thread(target=_reader) for _ in range(3)]
    for t in threads:
        t.start()
    for k in ssd_keys[::2]:                 # force repeated log rewrites
        store.delete(k)
        store.compact()
    stop.set()
    for t in threads:
        t.join(10.0)
    assert not errors, f"stale handle served wrong bytes: {errors[:3]}"
    assert store._read_fh is not fh, "compact must invalidate the handle"
    for k in ssd_keys[1::2]:
        assert store.get(k) == data[k]


# ------------------------------------------- manager: flush completion fix

def test_flush_complete_not_vacuous_on_empty_ring():
    m = BBManager(Transport(), expected_servers=2)
    # seed PR 8 regression: set() >= set() made this True before any
    # server ever registered
    assert not m.flush_complete(5)
    m.ring = ["s0", "s1"]
    assert not m.flush_complete(5)
    m.flush_done[5] = {"s0"}
    assert not m.flush_complete(5)
    m.flush_done[5] = {"s0", "s1"}
    assert m.flush_complete(5)


def test_flush_complete_against_participant_snapshot():
    m = BBManager(Transport(), expected_servers=2)
    m.ring = ["s0", "s1"]
    m._flush_expected[7] = {"s0", "s1"}
    m.flush_done[7] = {"s0"}
    assert not m.flush_complete(7)
    m.dead.add("s1")                        # mid-epoch death is excused
    assert m.flush_complete(7)
    m.dead.add("s0")                        # whole snapshot dead: never
    assert not m.flush_complete(7), \
        "an all-dead snapshot must not report success"


# --------------------------------------------- manager: journal replay

def test_manager_journal_replay(tmp_path):
    jpath = str(tmp_path / "manager.journal")
    records = [
        {"op": "ns", "path": "a", "size": 100, "synced": True},
        {"op": "ns", "path": "b", "size": 7, "synced": True},
        {"op": "lookup", "sizes": {"a": 100}},
        {"op": "epoch", "drain": DRAIN_EPOCH_BASE + 6},
        {"op": "epoch", "stage": STAGE_EPOCH_BASE + 12},
        {"op": "ns_del", "path": "b"},
        {"op": "lookup_del", "path": "zzz"},
    ]
    with open(jpath, "wb") as fh:
        for rec in records:
            fh.write(json.dumps(rec).encode() + b"\n")
        good = fh.tell()
        fh.write(b'{"op":"ns","pa')        # torn tail from a mid-append crash
    m = BBManager(Transport(), expected_servers=1, journal_path=jpath)
    m._replay_journal()
    assert m.namespace == {"a": {"size": 100, "synced": True,
                                 "opened_by": set()}}
    assert m.lookup == {"a": 100}
    # re-allocated epoch ids can never collide with pre-crash ones
    assert m._next_drain_epoch == DRAIN_EPOCH_BASE + 7
    assert m._next_stage_epoch == STAGE_EPOCH_BASE + 13
    assert os.path.getsize(jpath) == good, "torn tail must be truncated"


def test_manager_journal_round_trip(tmp_path):
    """What one manager journals, its successor replays — driven through
    the real handlers, not hand-written records."""
    from repro.core.transport import Message
    jpath = str(tmp_path / "manager.journal")
    tr = Transport()
    probe = tr.register("probe")
    m1 = BBManager(tr, expected_servers=1, journal_path=jpath)
    m1.ring = ["s0"]
    m1._on_fs_open(Message("fs_open", "probe", "manager",
                           {"path": "ckpt", "mode": "w"}, msg_id=1))
    m1._on_fs_sync(Message("fs_sync", "probe", "manager",
                           {"path": "ckpt", "size": 4096}, msg_id=2))
    m1._on_flush_done(Message("flush_done", "s0", "manager",
                              {"epoch": 1, "server": "s0", "bytes": 4096,
                               "sizes": {"ckpt": 4096}}, msg_id=3))
    while probe.recv(timeout=0) is not None:
        pass                                # drain the acks
    m2 = BBManager(tr, expected_servers=1, name="manager2",
                   journal_path=jpath)
    m2._replay_journal()
    assert m2.namespace["ckpt"]["size"] == 4096
    assert m2.namespace["ckpt"]["synced"] is True
    assert m2.lookup == {"ckpt": 4096}


# ---------------------------------------------------- system-level restart

def _recovery_cfg(ssd_dir, pfs_dir, replication=1):
    cfg = BBConfig(num_servers=2, num_clients=2, replication=replication,
                   dram_capacity=0,         # every acked byte is SSD-resident
                   ssd_capacity=1 << 30,
                   ssd_dir=str(ssd_dir), pfs_dir=str(pfs_dir),
                   chunk_bytes=32 << 10)
    cfg.drain.enabled = False               # the logs stay the only copy
    return cfg


def test_single_server_kill_and_restart_rejoins_byte_exact(tmp_path):
    """replication=1: the killed server's chunks exist nowhere else, so a
    byte-exact read after restart proves log recovery, not replica reads."""
    cfg = _recovery_cfg(tmp_path / "ssd", tmp_path / "pfs")
    rng = np.random.default_rng(3)
    data = rng.integers(0, 256, 512 << 10, dtype=np.uint8).tobytes()
    with BurstBufferSystem(cfg) as sys_:
        fs = sys_.fs()
        with fs.open("ckpt", "w", policy="batched",
                     chunk_bytes=32 << 10) as f:
            f.pwrite(data, 0)
        victim = "server/0"
        sys_.kill_server(victim)
        deadline = time.monotonic() + 6
        while time.monotonic() < deadline \
                and victim not in sys_.manager.dead:
            time.sleep(0.05)
        assert victim in sys_.manager.dead, "failure detection missed"

        srv = sys_.restart_server(victim)
        deadline = time.monotonic() + 6
        while time.monotonic() < deadline and victim in sys_.manager.dead:
            time.sleep(0.05)
        assert victim not in sys_.manager.dead, "rejoin not processed"
        assert srv.stats["recovered_keys"] > 0, \
            "restart did not replay the SSD log"
        # reads need the clients to have digested the rejoin: poll briefly
        r = sys_.fs().open("ckpt", "r")
        deadline = time.monotonic() + 6
        got = None
        while time.monotonic() < deadline:
            got = r.pread(0, len(data))
            if got == data:
                break
            time.sleep(0.1)
        assert got == data, "restarted server did not serve its bytes back"


def test_whole_cluster_restart_recovers_acked_bytes(tmp_path):
    """The tentpole end to end: nothing was flushed to the PFS, the whole
    cluster dies, and a cold start over the surviving SSD directory serves
    every acked byte byte-exact with the namespace rebuilt."""
    cfg = _recovery_cfg(tmp_path / "ssd", tmp_path / "pfs", replication=2)
    rng = np.random.default_rng(4)
    data = rng.integers(0, 256, 768 << 10, dtype=np.uint8).tobytes()
    with BurstBufferSystem(cfg) as sys_:
        fs = sys_.fs()
        with fs.open("ckpt", "w", policy="batched",
                     chunk_bytes=32 << 10) as f:
            f.pwrite(data, 0)
        st = fs.stat("ckpt")
        assert st["residency"]["dram"] == 0
        assert st["residency"]["ssd"] >= len(data)
        assert not os.path.exists(str(tmp_path / "pfs" / "ckpt")), \
            "test premise broken: bytes reached the PFS"

    with BurstBufferSystem(cfg) as sys2:
        fs2 = sys2.fs()
        st = fs2.stat("ckpt")               # manager journal: ns rebuilt
        assert st["size"] == len(data)
        assert "ckpt" in fs2.listdir()
        got = fs2.open("ckpt", "r").pread(0, len(data))
        assert got == data, "cold-cluster restart lost acked bytes"
        stats = sys2.server_stats()
        assert sum(s.get("recovered_keys", 0) for s in stats.values()) > 0


def test_restart_lookup_table_reseeded_from_journal(tmp_path):
    """A FLUSHED file's lookup size must survive a whole-cluster restart:
    the manager journals it and re-seeds servers via the ring broadcast,
    so post-restart range reads still find the PFS-resident bytes."""
    cfg = _recovery_cfg(tmp_path / "ssd", tmp_path / "pfs", replication=2)
    rng = np.random.default_rng(5)
    data = rng.integers(0, 256, 256 << 10, dtype=np.uint8).tobytes()
    with BurstBufferSystem(cfg) as sys_:
        fs = sys_.fs()
        with fs.open("flushed", "w", policy="batched",
                     chunk_bytes=32 << 10) as f:
            f.pwrite(data, 0)
        assert sys_.flush(epoch=1, timeout=30)
        assert sys_.manager.lookup.get("flushed") == len(data)

    with BurstBufferSystem(cfg) as sys2:
        assert sys2.manager.lookup.get("flushed") == len(data)
        # ring bootstrap re-seeded every server's lookup table
        deadline = time.monotonic() + 6
        seeded = False
        while time.monotonic() < deadline and not seeded:
            seeded = all(
                srv.lookup_table.get("flushed") == len(data)
                for srv in sys2.servers.values())
            if not seeded:
                time.sleep(0.05)
        assert seeded, "servers did not relearn the lookup table"
        got = sys2.fs().open("flushed", "r").pread(0, len(data))
        assert got == data
