"""Compare a bench's --json output against its committed baseline.

First step of ROADMAP Open item 4 (perf trajectory tracking): each
``BENCH_*.json`` under ``benchmarks/baselines/`` pins the headline
metrics of one bench; ``ci.sh --bench-smoke`` re-runs the bench and
fails if a headline metric regresses below ``--min-ratio`` times the
baseline value (default 0.5 — lenient on purpose: smoke runs on shared
CI machines see large variance, and the floor is meant to catch
collapses, not noise).

    python -m benchmarks.compare CURRENT.json BASELINE.json [--min-ratio R]
"""
from __future__ import annotations

import argparse
import json
import sys

# headline higher-is-better metrics per bench (keys into doc["results"])
METRICS = {
    "bench_drain": ["sustained_mbps", "readback_mbps"],
    "bench_restart": ["speedup"],
    "bench_qos": ["p99_speedup"],
    "bench_recovery": ["recovered_mbps"],
}


def _load(path: str) -> dict:
    with open(path) as fh:
        return json.load(fh)


def compare(current: dict, baseline: dict, min_ratio: float):
    """Return (failures, checked) comparing two jsonout documents."""
    bench = current.get("bench")
    if bench != baseline.get("bench"):
        return [f"bench mismatch: {bench!r} vs {baseline.get('bench')!r}"], []
    failures, checked = [], []
    cur, base = current.get("results", {}), baseline.get("results", {})
    for key in METRICS.get(bench, []):
        b = base.get(key)
        c = cur.get(key)
        if not isinstance(b, (int, float)) or b <= 0:
            continue                    # baseline doesn't pin this metric
        if not isinstance(c, (int, float)):
            failures.append(f"{key}: missing from current results")
            continue
        floor = min_ratio * b
        ok = c >= floor
        checked.append((key, c, b, floor, ok))
        if not ok:
            failures.append(
                f"{key}: {c:.3f} < floor {floor:.3f} "
                f"({min_ratio:.2f} x baseline {b:.3f})")
    if not checked and not failures:
        failures.append(f"no comparable metrics for bench {bench!r}")
    return failures, checked


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.compare")
    ap.add_argument("current")
    ap.add_argument("baseline")
    ap.add_argument("--min-ratio", type=float, default=0.5)
    args = ap.parse_args(argv)

    current, baseline = _load(args.current), _load(args.baseline)
    failures, checked = compare(current, baseline, args.min_ratio)
    for key, c, b, floor, ok in checked:
        print(f"[compare] {key}: current {c:.3f} vs baseline {b:.3f} "
              f"(floor {floor:.3f}) {'ok' if ok else 'FAIL'}")
    for f in failures:
        print(f"[compare] FAIL {f}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
