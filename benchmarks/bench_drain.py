"""Sustained over-capacity ingest under the autonomous drain engine.

The scenario the pre-drain code could not run at all: ingest 3-5x the
cluster's aggregate DRAM capacity through one BBFile handle while the
background drainer continuously flushes cold segments to the PFS and evicts
them. Reports sustained ingest MB/s, drain micro-epoch counters, final
occupancy (proof the staging area was actually reclaimed, not just spilled
into an ever-growing SSD log), and verifies a pread over the whole file —
most of it evicted by then — returns byte-identical data.

CLI:
  python -m benchmarks.bench_drain            # full run (4 srv, ~4x DRAM)
  python -m benchmarks.bench_drain --smoke    # capped CI run; exits non-zero
                                              #   if sustained ingest under
                                              #   drain falls below
                                              #   --floor-frac of the async
                                              #   put baseline, if occupancy
                                              #   was not reclaimed, or if
                                              #   any read-back byte differs
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.core import BBConfig, BurstBufferSystem, DrainConfig


def _config(n_servers: int, n_clients: int, dram_mb: int) -> BBConfig:
    dram = dram_mb << 20
    return BBConfig(
        num_servers=n_servers, num_clients=n_clients, placement="iso",
        dram_capacity=dram, ssd_capacity=2 * dram,
        segment_bytes=max(dram // 8, 64 << 10),
        chunk_bytes=max(dram // 16, 64 << 10),
        stabilize_interval=0.5,
        drain=DrainConfig(high_watermark=0.60, low_watermark=0.30,
                          request_interval=0.05, pressure_interval=0.1,
                          max_epoch_bytes=dram,
                          epoch_timeout_s=10.0))


def _ingest(sys_: BurstBufferSystem, fname: str, total: int,
            chunk: int, rng) -> tuple:
    """Stream ``total`` random bytes through one handle; returns (B/s, data).
    The sync barrier raises on any client-visible error."""
    data = rng.integers(0, 256, total, dtype=np.uint8).tobytes()
    fs = sys_.fs()
    t0 = time.perf_counter()
    f = fs.open(fname, "w", policy="batched", chunk_bytes=chunk)
    for off in range(0, total, chunk):
        f.pwrite(data[off:off + chunk], off)
    f.close(120.0)
    return total / (time.perf_counter() - t0), data


def run(n_servers: int = 4, n_clients: int = 4, dram_mb: int = 4,
        capacity_multiple: float = 4.0, floor_frac: float = 0.25,
        settle_s: float = 20.0) -> dict:
    cfg = _config(n_servers, n_clients, dram_mb)
    aggregate_dram = n_servers * cfg.dram_capacity
    total = int(capacity_multiple * aggregate_dram)
    chunk = cfg.chunk_bytes
    rng = np.random.default_rng(42)

    # async-put baseline: same topology, ingest comfortably inside DRAM so
    # the drainer never fires — the reference the drained run is held to
    with BurstBufferSystem(_config(n_servers, n_clients, dram_mb)) as ref:
        base_bps, _ = _ingest(ref, "baseline", aggregate_dram // 4,
                              chunk, rng)

    out = {"aggregate_dram_mb": aggregate_dram / 1e6,
           "ingest_mb": total / 1e6,
           "capacity_multiple": capacity_multiple,
           "baseline_async_mbps": base_bps / 1e6}
    with BurstBufferSystem(cfg) as sys_:
        bps, data = _ingest(sys_, "over_capacity", total, chunk, rng)
        out["sustained_mbps"] = bps / 1e6
        # let the drainer work the backlog down below the high watermark
        deadline = time.monotonic() + settle_s
        while time.monotonic() < deadline:
            pr = sys_.pressure()
            fracs = [s.get("fraction", 0.0)
                     for s in pr["servers"].values()]
            if pr["drain"]["epochs"] >= 1 and fracs \
                    and max(fracs) < cfg.drain.high_watermark:
                break
            time.sleep(0.2)
        pr = sys_.pressure()
        out["drain"] = pr["drain"]
        out["final_occupancy"] = max(
            (s.get("fraction", 0.0) for s in pr["servers"].values()),
            default=0.0)
        st = sys_.fs().stat("over_capacity")
        out["residency"] = st["residency"]
        # read the whole file back — most of it is evicted by now, so this
        # exercises the transparent DRAM -> SSD -> PFS fallthrough
        t0 = time.perf_counter()
        got = sys_.fs().open("over_capacity", "r").pread(0, total)
        out["readback_mbps"] = total / (time.perf_counter() - t0) / 1e6
        out["byte_exact"] = got == data
        out["server_errors"] = len(sys_.manager.errors)
    out["ok"] = (out["byte_exact"]
                 and out["server_errors"] == 0
                 and out["drain"]["epochs"] >= 1
                 and out["final_occupancy"] < 1.0
                 and out["sustained_mbps"]
                 >= floor_frac * out["baseline_async_mbps"])
    return out


def main(argv=None) -> int:
    from benchmarks import jsonout
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="capped CI run (2 servers, ~3x DRAM)")
    ap.add_argument("--floor-frac", type=float, default=0.25,
                    help="fail if sustained ingest under drain drops below "
                         "this fraction of the async put baseline")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write machine-readable results to PATH")
    args = ap.parse_args(argv)
    if args.smoke:
        res = run(n_servers=2, n_clients=2, dram_mb=1,
                  capacity_multiple=3.0, floor_frac=args.floor_frac,
                  settle_s=15.0)
    else:
        res = run(floor_frac=args.floor_frac)
    for k, v in res.items():
        if isinstance(v, float):
            print(f"{k:>24}: {v:.2f}")
        else:
            print(f"{k:>24}: {v}")
    jsonout.dump(args.json, "bench_drain", res)
    if not res["ok"]:
        print("bench_drain: FAILED (see fields above)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
