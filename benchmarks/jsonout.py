"""Machine-readable benchmark output (ISSUE 5 satellite).

Every bench CLI accepts ``--json <path>`` and funnels its results through
``dump`` so the perf trajectory can be tracked as ``BENCH_*.json`` files
across PRs — MB/s, p50/p99 latencies, occupancy, whatever the bench
measures — instead of scraping the human-readable CSV."""
from __future__ import annotations

import datetime
import json
import sys
from typing import Optional, Sequence, Tuple


def rows_to_records(rows: Sequence[Tuple[str, float, str]]):
    """The harness row format (name, us_per_call, derived) as dicts."""
    return [{"name": n, "us_per_call": us, "derived": d}
            for n, us, d in rows]


def cli_main(main_fn, bench: str) -> None:
    """Shared __main__ body for row-producing benches: parse ``--json``,
    print the CSV table, and dump the rows."""
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write machine-readable results to PATH")
    args = ap.parse_args()
    rows = main_fn()
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    dump(args.json, bench, rows_to_records(rows))


def dump(path: Optional[str], bench: str, payload) -> None:
    """Write one bench's results as JSON; a None path is a no-op so every
    caller can pass its ``--json`` argument through unconditionally."""
    if not path:
        return
    doc = {"bench": bench,
           "generated": datetime.datetime.now(
               datetime.timezone.utc).isoformat(timespec="seconds"),
           "results": payload}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True, default=str)
        f.write("\n")
    print(f"[{bench}] json results -> {path}", file=sys.stderr)
