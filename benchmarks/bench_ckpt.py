"""Framework-level checkpoint-stall benchmark (the paper's value prop
applied to training): per-checkpoint stall on the training critical path.

  direct_pfs  — serialize + synchronous write to a rate-limited "PFS"
                (200 MB/s shared-filesystem model)
  bb_async    — burst-buffer ingest only (flush overlaps compute)
  bb_int8     — ingest with device-side int8 quantization of optimizer
                moments (kernels/quantize): ~half the ingested bytes

Plus an ingest-mode comparison on the same state (paper Fig 4), expressed
as BBFile write policies on one fs handle:
  sync        — one replicated round-trip per chunk (blocking)
  async       — chunks pipelined through the ACK ledger, one sync() barrier
  batched     — async + client-side write coalescing into put_batch

Derived columns: stall relative to direct PFS; ingest bandwidth per mode.
"""
from __future__ import annotations

import os
import time

import jax

from repro.checkpoint import serializer as ser
from repro.checkpoint.bbckpt import BBCheckpointManager
from repro.configs.base import get_config, reduced
from repro.core import BBConfig, BurstBufferSystem
from repro.models.registry import build_model
from repro.runtime.train_step import init_train_state, make_optimizer

PFS_BW = 200e6      # rate-limited shared PFS model (B/s)


def _state(scale=320):
    cfg = reduced(get_config("starcoder2-3b"), d_model=scale, vocab=8192)
    model = build_model(cfg)
    opt = make_optimizer(cfg)
    st = init_train_state(cfg, model, opt, jax.random.PRNGKey(0))
    return {"params": st.params, "opt_state": st.opt_state}


def _direct_pfs(state, pfs_dir) -> float:
    t0 = time.perf_counter()
    payloads, manifest = ser.serialize_tree(state)
    path = os.path.join(pfs_dir, "direct_ckpt")
    nbytes = 0
    with open(path, "wb") as f:
        for name, data in payloads.items():
            f.write(data)
            nbytes += len(data)
    os.fsync(os.open(path, os.O_RDONLY))
    # model the shared-PFS rate limit as additional stall
    t_write = nbytes / PFS_BW
    return (time.perf_counter() - t0) + t_write


def run():
    state = _state()
    with BurstBufferSystem(BBConfig(num_servers=4, num_clients=4,
                                    dram_capacity=512 << 20)) as bb:
        t_direct = _direct_pfs(state, bb.pfs_dir)

        mgr = BBCheckpointManager(bb, quantize=False)
        mgr.save(0, state)                      # warm the serialize path
        mgr.wait_flushes()
        t0 = time.perf_counter()
        mgr.save(1, state)
        t_bb = time.perf_counter() - t0
        mgr.wait_flushes()

        mgr_q = BBCheckpointManager(bb, quantize=True)
        mgr_q.save(2, state)
        mgr_q.wait_flushes()
        t0 = time.perf_counter()
        mgr_q.save(3, state)
        t_q = time.perf_counter() - t0
        mgr_q.wait_flushes()
        bytes_full = mgr.metrics[1]["bytes"]
        bytes_q = mgr_q.metrics[3]["bytes"]

        # ingest-mode comparison (paper Fig 4): the SAME serialized chunks
        # through the three write policies of one BBFile handle.
        # Serialization happens once, outside the timed region — this
        # measures pure BB absorption. 64 KB chunks model the
        # many-small-tensors checkpoint shape the write-coalescing policy
        # targets (per-message overhead dominates). Best of 3 reps per mode
        # to damp scheduler noise.
        payloads, manifest = ser.serialize_tree(state)
        offset_of = {m["name"]: m["offset"] for m in manifest["leaves"]}
        chunk = 64 << 10
        chunks = []
        for name, data in payloads.items():
            base = offset_of[name]
            for off in range(0, max(len(data), 1), chunk):
                chunks.append((base + off, data[off:off + chunk]))
        total = sum(len(p) for _, p in chunks)
        fs = bb.fs()
        modes = {}
        for mode in ("sync", "async", "batched"):
            best = 0.0
            for rep in range(3):
                fname = f"ing_{mode}_{rep}"
                t0 = time.perf_counter()
                f = fs.open(fname, "w", policy=mode, chunk_bytes=chunk)
                for off, piece in chunks:
                    f.pwrite(piece, off)
                f.close(60.0)       # sync barrier; raises on failed chunks
                dt = time.perf_counter() - t0
                best = max(best, total / dt)
                bb.evict(fname)
                # barrier: inboxes are FIFO, so a stats reply means the
                # eviction (and its log compaction) finished — keeps the
                # previous rep's compaction out of the next timed region
                bb.server_stats()
            modes[mode] = best

    rows = [
        ("ckpt_stall_direct_pfs", t_direct * 1e6,
         f"1.00x baseline ({bytes_full/1e6:.0f} MB at 200 MB/s PFS)"),
        ("ckpt_stall_bb_async", t_bb * 1e6,
         f"{t_direct / t_bb:.1f}x less stall (flush overlaps compute)"),
        ("ckpt_stall_bb_int8", t_q * 1e6,
         f"{t_direct / t_q:.1f}x less stall; BB ingress bytes "
         f"{bytes_full / bytes_q:.2f}x smaller (quantize is a TPU kernel; "
         "its CPU cost here is not representative)"),
    ]
    bw_sync = modes["sync"]
    for mode in ("sync", "async", "batched"):
        bw = modes[mode]
        rows.append((f"ckpt_ingest_{mode}", total / bw * 1e6,
                     f"{bw / 1e6:.0f} MB/s ingest "
                     f"({bw / bw_sync:.2f}x sync)"))
    return rows


def main():
    return run()


if __name__ == "__main__":
    from benchmarks import jsonout
    jsonout.cli_main(main, "bench_ckpt")
