"""Framework-level checkpoint-stall benchmark (the paper's value prop
applied to training): per-checkpoint stall on the training critical path.

  direct_pfs  — serialize + synchronous write to a rate-limited "PFS"
                (200 MB/s shared-filesystem model)
  bb_async    — burst-buffer ingest only (flush overlaps compute)
  bb_int8     — ingest with device-side int8 quantization of optimizer
                moments (kernels/quantize): ~half the ingested bytes

Derived column: stall relative to direct PFS.
"""
from __future__ import annotations

import os
import time

import jax

from repro.checkpoint import serializer as ser
from repro.checkpoint.bbckpt import BBCheckpointManager
from repro.configs.base import get_config, reduced
from repro.core import BBConfig, BurstBufferSystem
from repro.models.registry import build_model
from repro.runtime.train_step import init_train_state, make_optimizer

PFS_BW = 200e6      # rate-limited shared PFS model (B/s)


def _state(scale=320):
    cfg = reduced(get_config("starcoder2-3b"), d_model=scale, vocab=8192)
    model = build_model(cfg)
    opt = make_optimizer(cfg)
    st = init_train_state(cfg, model, opt, jax.random.PRNGKey(0))
    return {"params": st.params, "opt_state": st.opt_state}


def _direct_pfs(state, pfs_dir) -> float:
    t0 = time.perf_counter()
    payloads, manifest = ser.serialize_tree(state)
    path = os.path.join(pfs_dir, "direct_ckpt")
    nbytes = 0
    with open(path, "wb") as f:
        for name, data in payloads.items():
            f.write(data)
            nbytes += len(data)
    os.fsync(os.open(path, os.O_RDONLY))
    # model the shared-PFS rate limit as additional stall
    t_write = nbytes / PFS_BW
    return (time.perf_counter() - t0) + t_write


def run():
    state = _state()
    with BurstBufferSystem(BBConfig(num_servers=4, num_clients=4,
                                    dram_capacity=512 << 20)) as bb:
        t_direct = _direct_pfs(state, bb.pfs_dir)

        mgr = BBCheckpointManager(bb, quantize=False)
        mgr.save(0, state)                      # warm the serialize path
        mgr.wait_flushes()
        t0 = time.perf_counter()
        mgr.save(1, state)
        t_bb = time.perf_counter() - t0
        mgr.wait_flushes()

        mgr_q = BBCheckpointManager(bb, quantize=True)
        mgr_q.save(2, state)
        mgr_q.wait_flushes()
        t0 = time.perf_counter()
        mgr_q.save(3, state)
        t_q = time.perf_counter() - t0
        mgr_q.wait_flushes()
        bytes_full = mgr.metrics[1]["bytes"]
        bytes_q = mgr_q.metrics[3]["bytes"]

    return [
        ("ckpt_stall_direct_pfs", t_direct * 1e6,
         f"1.00x baseline ({bytes_full/1e6:.0f} MB at 200 MB/s PFS)"),
        ("ckpt_stall_bb_async", t_bb * 1e6,
         f"{t_direct / t_bb:.1f}x less stall (flush overlaps compute)"),
        ("ckpt_stall_bb_int8", t_q * 1e6,
         f"{t_direct / t_q:.1f}x less stall; BB ingress bytes "
         f"{bytes_full / bytes_q:.2f}x smaller (quantize is a TPU kernel; "
         "its CPU cost here is not representative)"),
    ]


def main():
    return run()
