"""Ablations beyond the paper's figures.

1. replication factor K: ingest cost of resilience (paper fixes K=2; we
   sweep K=1..3 through the real system — each +1 adds one store-and-forward
   hop to the ACK chain).
2. placement ablation at equal load: iso vs ketama vs rendezvous keys/server
   balance (stddev of per-server key counts).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import BBConfig, BurstBufferSystem


def replication_sweep(total_mb=8, seg_kb=128):
    out = []
    base = None
    # throwaway warmup run: thread spin-up dominates the first system on a
    # single core and otherwise masks the K ordering
    _warm = BurstBufferSystem(BBConfig(num_servers=4, num_clients=4,
                                       dram_capacity=64 << 20)).start()
    for i in range(64):
        _warm.clients[i % 4].put(f"w:{i}", b"x" * 65536)
    _warm.stop()
    for k in (1, 2, 3):
        sys_ = BurstBufferSystem(BBConfig(
            num_servers=4, num_clients=4, replication=k,
            dram_capacity=256 << 20, stabilize_interval=1.0)).start()
        try:
            seg = seg_kb << 10
            n = (total_mb << 20) // seg
            payload = b"\x7a" * seg
            t0 = time.perf_counter()
            for i in range(n):
                assert sys_.clients[i % 4].put(f"r{k}:{i}", payload)
            dt = time.perf_counter() - t0
            bw = (total_mb << 20) / dt
            base = base or bw
            out.append((f"ablation_replication_k{k}", dt * 1e6,
                        f"{bw/1e6:.0f} MB/s ({bw/base:.2f}x of K=1)"))
        finally:
            sys_.stop()
    return out


def placement_balance(n_keys=2000):
    from repro.core.hashing import IsoPlacement, KetamaRing, RendezvousHash
    servers = [f"s{i}" for i in range(8)]
    out = []
    ket, rv = KetamaRing(servers), RendezvousHash(servers)
    iso = IsoPlacement(servers)
    for name, lookup in (
            ("ketama", lambda i: ket.lookup(f"key-{i}")),
            ("rendezvous", lambda i: rv.lookup(f"key-{i}")),
            ("iso", lambda i: iso.lookup_for_client(i % 64))):
        counts = {}
        for i in range(n_keys):
            s = lookup(i)
            counts[s] = counts.get(s, 0) + 1
        arr = np.array([counts.get(s, 0) for s in servers], float)
        cv = float(arr.std() / arr.mean())
        out.append((f"ablation_balance_{name}", 0.0,
                    f"cv={cv:.3f} over 8 servers"))
    return out


def main():
    return replication_sweep() + placement_balance()


if __name__ == "__main__":
    from benchmarks import jsonout
    jsonout.cli_main(main, "bench_ablation")
