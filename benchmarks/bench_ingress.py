"""Fig 5 reproduction: ingress bandwidth, 1..128 burst-buffer servers.

Two parts:
  run_sim():  full-scale curves from the calibrated Titan model (simkit) —
              reproduces the paper's scaling shapes and its reported mean
              ratios (BB-ISO = 2.78x IOR-SF, 1.75x IOR-SFP).
  run_real(): the actual threaded implementation at container scale
              (1..8 servers, real bytes through transport + LogStore),
              checking the ORDERING (iso >= ketama) on real code.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.simkit import Testbed, fig5_table, ingress_bandwidth
from repro.core import BBConfig, BurstBufferSystem


def run_sim():
    rows = fig5_table()
    iso_sf = float(np.mean([r["bb_iso"] / r["ior_sf"] for r in rows]))
    iso_sfp = float(np.mean([r["bb_iso"] / r["ior_sfp"] for r in rows]))
    return rows, iso_sf, iso_sfp


def _measure(placement: str, n_servers: int, n_clients: int,
             per_client_mb: int = 8, seg_kb: int = 256,
             mode: str = "sync") -> float:
    """Aggregate real ingress bandwidth (B/s) through the implementation.

    mode "sync" blocks on every replicated put; "async" pipelines puts
    through the ACK ledger (paper Fig 4) and barriers once on wait_acks;
    "batched" additionally coalesces puts into put_batch messages."""
    sys_ = BurstBufferSystem(BBConfig(
        num_servers=n_servers, num_clients=n_clients, placement=placement,
        dram_capacity=per_client_mb * n_clients * (1 << 20) + (16 << 20),
        stabilize_interval=1.0)).start()
    try:
        seg = seg_kb << 10
        nseg = (per_client_mb << 20) // seg
        payload = b"\xab" * seg
        t0 = time.perf_counter()
        for j in range(nseg):
            for ci, c in enumerate(sys_.clients):
                key = f"ing:{ci}:{j}"
                if mode == "sync":
                    if not c.put(key, payload):
                        raise RuntimeError(f"sync put failed: {key}")
                else:
                    c.put_async(key, payload, coalesce=(mode == "batched"))
        if mode != "sync":
            for c in sys_.clients:
                c.flush_batches()
            for c in sys_.clients:
                if not c.wait_acks(60.0):
                    raise RuntimeError(f"{mode} ingest incomplete: {c.tname}")
        dt = time.perf_counter() - t0
        total = n_clients * nseg * seg
        return total / dt
    finally:
        sys_.stop()


def run_real(ns=(1, 2, 4, 8)):
    rows = []
    for n in ns:
        iso = _measure("iso", n, n)
        ket = _measure("ketama", n, n)
        rows.append({"servers": n, "bb_iso": iso, "bb_ketama": ket})
    return rows


def run_modes(n: int = 4):
    """Sync vs async vs batched ingest on the same topology (paper Fig 4)."""
    return {mode: _measure("iso", n, n, mode=mode)
            for mode in ("sync", "async", "batched")}


def main(full: bool = True):
    out = []
    rows, iso_sf, iso_sfp = run_sim()
    for r in rows:
        out.append((f"fig5_sim_n{r['servers']}",
                    0.0,
                    "iso=%.1f ket=%.1f sfp=%.1f sf=%.1f GB/s" % (
                        r["bb_iso"] / 1e9, r["bb_ketama"] / 1e9,
                        r["ior_sfp"] / 1e9, r["ior_sf"] / 1e9)))
    out.append(("fig5_mean_iso_over_sf", 0.0, f"{iso_sf:.3f}x (paper 2.78x)"))
    out.append(("fig5_mean_iso_over_sfp", 0.0,
                f"{iso_sfp:.3f}x (paper 1.75x)"))
    if full:
        for r in run_real():
            out.append((f"fig5_real_n{r['servers']}", 0.0,
                        "iso=%.0f ket=%.0f MB/s" % (
                            r["bb_iso"] / 1e6, r["bb_ketama"] / 1e6)))
        modes = run_modes()
        for mode, bw in modes.items():
            out.append((f"fig4_ingress_{mode}", 0.0,
                        "%.0f MB/s (%.2fx sync)" % (
                            bw / 1e6, bw / modes["sync"])))
    return out
