"""Fig 5 reproduction: ingress bandwidth, 1..128 burst-buffer servers.

Two parts:
  run_sim():  full-scale curves from the calibrated Titan model (simkit) —
              reproduces the paper's scaling shapes and its reported mean
              ratios (BB-ISO = 2.78x IOR-SF, 1.75x IOR-SFP).
  run_real(): the actual threaded implementation at container scale
              (1..8 servers, real bytes through transport + LogStore),
              checking the ORDERING (iso >= ketama) on real code.

Ingest goes through the BBFileSystem file-session API (one handle, chunks
striped over clients; mode selects the sync/async/batched write policy).
``--legacy-kv`` keeps the raw put/put_async KV path alive for A/B
comparison against the handle-based path.

CLI:
  python -m benchmarks.bench_ingress               # full table (fs API)
  python -m benchmarks.bench_ingress --legacy-kv   # A/B: raw KV shims
  python -m benchmarks.bench_ingress --smoke       # tiny CI smoke run
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.simkit import Testbed, fig5_table, ingress_bandwidth
from repro.core import BBConfig, BurstBufferSystem


def run_sim():
    rows = fig5_table()
    iso_sf = float(np.mean([r["bb_iso"] / r["ior_sf"] for r in rows]))
    iso_sfp = float(np.mean([r["bb_iso"] / r["ior_sfp"] for r in rows]))
    return rows, iso_sf, iso_sfp


def _measure(placement: str, n_servers: int, n_clients: int,
             per_client_mb: int = 8, seg_kb: int = 256,
             mode: str = "sync", legacy_kv: bool = False) -> float:
    """Aggregate real ingress bandwidth (B/s) through the implementation.

    mode is the BBFile write policy: "sync" blocks on every replicated
    chunk; "async" pipelines chunks through the ACK ledger and barriers
    once at sync(); "batched" additionally coalesces chunks into put_batch
    messages. With legacy_kv=True the same bytes go through the raw
    put/put_async compat shims instead of a file handle."""
    sys_ = BurstBufferSystem(BBConfig(
        num_servers=n_servers, num_clients=n_clients, placement=placement,
        dram_capacity=per_client_mb * n_clients * (1 << 20) + (16 << 20),
        stabilize_interval=1.0)).start()
    try:
        seg = seg_kb << 10
        nseg = (per_client_mb << 20) // seg
        payload = b"\xab" * seg
        total = n_clients * nseg * seg
        if legacy_kv:
            t0 = time.perf_counter()
            for j in range(nseg):
                for ci, c in enumerate(sys_.clients):
                    key = f"ing:{ci}:{j}"
                    if mode == "sync":
                        if not c.put(key, payload):
                            raise RuntimeError(f"sync put failed: {key}")
                    else:
                        c.put_async(key, payload,
                                    coalesce=(mode == "batched"))
            if mode != "sync":
                for c in sys_.clients:
                    c.flush_batches()
                for c in sys_.clients:
                    if not c.wait_acks(60.0):
                        raise RuntimeError(
                            f"{mode} ingest incomplete: {c.tname}")
            return total / (time.perf_counter() - t0)
        fs = sys_.fs()
        t0 = time.perf_counter()
        f = fs.open("ing", "w", policy=mode, chunk_bytes=seg)
        for j in range(nseg * n_clients):
            f.pwrite(payload, j * seg)
        f.close(60.0)           # sync barrier; raises on failed chunks
        return total / (time.perf_counter() - t0)
    finally:
        sys_.stop()


def run_real(ns=(1, 2, 4, 8), legacy_kv: bool = False):
    rows = []
    for n in ns:
        iso = _measure("iso", n, n, legacy_kv=legacy_kv)
        ket = _measure("ketama", n, n, legacy_kv=legacy_kv)
        rows.append({"servers": n, "bb_iso": iso, "bb_ketama": ket})
    return rows


def run_modes(n: int = 4, legacy_kv: bool = False):
    """Sync vs async vs batched ingest on the same topology (paper Fig 4)."""
    return {mode: _measure("iso", n, n, mode=mode, legacy_kv=legacy_kv)
            for mode in ("sync", "async", "batched")}


def run_smoke() -> float:
    """CI smoke: tiny batched ingest through the fs API; returns B/s and
    raises if the pipeline reports failures (f.close() is the barrier)."""
    return _measure("iso", 2, 2, per_client_mb=1, seg_kb=64, mode="batched")


def main(full: bool = True, legacy_kv: bool = False):
    out = []
    rows, iso_sf, iso_sfp = run_sim()
    for r in rows:
        out.append((f"fig5_sim_n{r['servers']}",
                    0.0,
                    "iso=%.1f ket=%.1f sfp=%.1f sf=%.1f GB/s" % (
                        r["bb_iso"] / 1e9, r["bb_ketama"] / 1e9,
                        r["ior_sfp"] / 1e9, r["ior_sf"] / 1e9)))
    out.append(("fig5_mean_iso_over_sf", 0.0, f"{iso_sf:.3f}x (paper 2.78x)"))
    out.append(("fig5_mean_iso_over_sfp", 0.0,
                f"{iso_sfp:.3f}x (paper 1.75x)"))
    if full:
        api = "kv" if legacy_kv else "fs"
        for r in run_real(legacy_kv=legacy_kv):
            out.append((f"fig5_real_n{r['servers']}", 0.0,
                        "iso=%.0f ket=%.0f MB/s (%s)" % (
                            r["bb_iso"] / 1e6, r["bb_ketama"] / 1e6, api)))
        modes = run_modes(legacy_kv=legacy_kv)
        for mode, bw in modes.items():
            out.append((f"fig4_ingress_{mode}", 0.0,
                        "%.0f MB/s (%.2fx sync, %s)" % (
                            bw / 1e6, bw / modes["sync"], api)))
    return out


if __name__ == "__main__":
    from benchmarks import jsonout
    ap = argparse.ArgumentParser()
    ap.add_argument("--legacy-kv", action="store_true",
                    help="drive ingest through the raw put/put_async shims "
                         "instead of BBFileSystem handles (A/B comparison)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI smoke run: assert non-zero bandwidth")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write machine-readable results to PATH")
    args = ap.parse_args()
    if args.smoke:
        bw = run_smoke()
        assert bw > 0, "smoke ingest produced zero bandwidth"
        print(f"bench_smoke_ingress,0.0,{bw / 1e6:.1f} MB/s OK")
        jsonout.dump(args.json, "bench_ingress", {"smoke_mbps": bw / 1e6})
    else:
        rows = main(legacy_kv=args.legacy_kv)
        print("name,us_per_call,derived")
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        jsonout.dump(args.json, "bench_ingress",
                     jsonout.rows_to_records(rows))
