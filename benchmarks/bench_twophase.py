"""§III-B reproduction: two-phase I/O vs direct flush.

Real measurement through the full system: N clients write interleaved
segments of a shared checkpoint file; we compare
  two-phase  — the system's domain-shuffled flush (one sequential write
               per server domain)
  direct     — each server writes its own non-contiguous segments straight
               into the shared file (seek/write per segment)
and report wall time plus the *write-op count* per server — the quantity
that turns into Lustre extent-lock acquisitions at scale (the paper's
motivation; a local FS hides the lock cost, the op count does not).
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import BBConfig, BurstBufferSystem
from repro.core.twophase import Segment, domains, file_sizes


def _fill(sys_, fname, n_seg_per_client=16, seg=64 << 10):
    rng = np.random.default_rng(3)
    n = len(sys_.clients)
    with sys_.fs().open(fname, "w", policy="sync", chunk_bytes=seg) as f:
        for j in range(n_seg_per_client * n):
            # the handle round-robins clients, so ownership interleaves
            f.write(rng.integers(0, 256, seg, dtype=np.uint8).tobytes())
    return n_seg_per_client * n * seg


def run():
    out = []
    # --- two-phase through the real system ---
    sys_ = BurstBufferSystem(BBConfig(num_servers=4, num_clients=4,
                                      dram_capacity=128 << 20)).start()
    try:
        total = _fill(sys_, "tp")
        t0 = time.perf_counter()
        assert sys_.flush(epoch=0, timeout=60)
        t_twophase = time.perf_counter() - t0
        # one contiguous write per (server, file domain)
        writes_twophase = len(sys_.servers)
    finally:
        sys_.stop()

    # --- direct: seek/write per buffered segment (no shuffle) ---
    sys_ = BurstBufferSystem(BBConfig(num_servers=4, num_clients=4,
                                      dram_capacity=128 << 20)).start()
    try:
        total = _fill(sys_, "direct")
        segs = []
        for srv in sys_.servers.values():
            segs.append([(s.offset, srv.store.get(k))
                         for k, s in srv._segments.items()])
        path = os.path.join(sys_.pfs_dir, "direct")
        t0 = time.perf_counter()
        with open(path, "w+b") as f:
            for server_segs in segs:
                for off, data in server_segs:   # non-contiguous writes
                    f.seek(off)
                    f.write(data)
            os.fsync(f.fileno())
        t_direct = time.perf_counter() - t0
        writes_direct = sum(len(s) for s in segs)
    finally:
        sys_.stop()

    out.append(("twophase_flush", t_twophase * 1e6,
                f"{total/1e6:.0f}MB, {writes_twophase} seq writes"))
    out.append(("direct_flush", t_direct * 1e6,
                f"{total/1e6:.0f}MB, {writes_direct} seek+writes"))
    out.append(("twophase_lock_ops_reduction", 0.0,
                f"{writes_direct / writes_twophase:.0f}x fewer PFS write ops"))
    return out


def main():
    return run()


if __name__ == "__main__":
    from benchmarks import jsonout
    jsonout.cli_main(main, "bench_twophase")
