"""Append bench ``--json`` records to a cumulative JSONL history.

Usage:
  python -m benchmarks.history OUT/*.json         append records
  python -m benchmarks.history trend [--window N] newest vs trailing median

Each input is one ``benchmarks.jsonout`` document (``{"bench",
"generated", "results"}``). The current commit hash is attached and the
document appended as one line to ``benchmarks/history/BENCH_history.jsonl``
— ``scripts/ci.sh --bench-smoke`` calls this after every smoke run, so the
headline numbers accrete into a greppable per-commit time series instead
of evaporating with the run's tempdir.

``trend`` (ISSUE 10) compares each bench's newest record against the
median of its trailing window and prints per-headline-metric deltas. It
is a warn-only report — always exit 0 — because a noisy shared machine
swings these numbers run to run; regression *gating* stays with
``benchmarks.compare`` and its committed baseline floors.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from benchmarks.compare import METRICS

HISTORY = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "history", "BENCH_history.jsonl")

# headline metrics per bench: the compare.py gating set, plus benches that
# have no committed baseline but still deserve a trend line
TREND_METRICS = dict(METRICS, bench_ingress=["smoke_mbps"])


def commit_hash() -> str:
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"],
                             capture_output=True, text=True,
                             cwd=os.path.dirname(os.path.abspath(__file__)))
        return out.stdout.strip() or "unknown" if out.returncode == 0 \
            else "unknown"
    except OSError:
        return "unknown"


def _median(xs):
    xs = sorted(xs)
    mid = len(xs) // 2
    return xs[mid] if len(xs) % 2 else (xs[mid - 1] + xs[mid]) / 2.0


def trend(argv=None) -> int:
    """Newest record per bench vs the trailing-window median, per headline
    metric. Warn-only: informative output, always exit 0."""
    ap = argparse.ArgumentParser(prog="history trend")
    ap.add_argument("--history", default=HISTORY, metavar="PATH")
    ap.add_argument("--window", type=int, default=8, metavar="N",
                    help="trailing records per bench for the median")
    args = ap.parse_args(argv)
    if not os.path.exists(args.history):
        print(f"trend: no history at {args.history}")
        return 0
    by_bench = {}
    with open(args.history) as fh:
        for line in fh:
            try:
                doc = json.loads(line)
            except ValueError:
                continue                    # torn tail: skip, warn-only
            if isinstance(doc.get("results"), dict) and doc.get("bench"):
                by_bench.setdefault(doc["bench"], []).append(doc)
    for bench in sorted(by_bench):
        recs = by_bench[bench]
        newest, prior = recs[-1], recs[-1 - args.window:-1]
        for metric in TREND_METRICS.get(bench, []):
            cur = newest["results"].get(metric)
            vals = [r["results"][metric] for r in prior
                    if isinstance(r["results"].get(metric), (int, float))]
            if not isinstance(cur, (int, float)):
                continue
            if not vals:
                print(f"trend: {bench:<16} {metric:<16} {cur:.4g} "
                      f"(no trailing history)")
                continue
            med = _median(vals)
            delta = (cur - med) / med * 100.0 if med else 0.0
            flag = "  <-- check" if delta <= -20.0 else ""
            print(f"trend: {bench:<16} {metric:<16} {cur:.4g} vs "
                  f"median[{len(vals)}] {med:.4g} ({delta:+.1f}%){flag}")
    return 0


def main(argv=None) -> int:
    paths = list(argv) if argv is not None else sys.argv[1:]
    if paths and paths[0] == "trend":
        return trend(paths[1:])
    if not paths:
        print("usage: python -m benchmarks.history BENCH.json "
              "[BENCH.json ...] | trend [--window N]")
        return 2
    commit = commit_hash()
    os.makedirs(os.path.dirname(HISTORY), exist_ok=True)
    n = 0
    with open(HISTORY, "a") as fh:
        for p in sorted(paths):
            with open(p) as src:
                doc = json.load(src)
            doc["commit"] = commit
            fh.write(json.dumps(doc, sort_keys=True) + "\n")
            n += 1
    print(f"history: appended {n} record(s) to {HISTORY}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
