"""Append bench ``--json`` records to a cumulative JSONL history.

Usage: ``python -m benchmarks.history OUT/*.json``

Each input is one ``benchmarks.jsonout`` document (``{"bench",
"generated", "results"}``). The current commit hash is attached and the
document appended as one line to ``benchmarks/history/BENCH_history.jsonl``
— ``scripts/ci.sh --bench-smoke`` calls this after every smoke run, so the
headline numbers accrete into a greppable per-commit time series instead
of evaporating with the run's tempdir.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

HISTORY = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "history", "BENCH_history.jsonl")


def commit_hash() -> str:
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"],
                             capture_output=True, text=True,
                             cwd=os.path.dirname(os.path.abspath(__file__)))
        return out.stdout.strip() or "unknown" if out.returncode == 0 \
            else "unknown"
    except OSError:
        return "unknown"


def main(argv=None) -> int:
    paths = list(argv) if argv is not None else sys.argv[1:]
    if not paths:
        print("usage: python -m benchmarks.history BENCH.json "
              "[BENCH.json ...]")
        return 2
    commit = commit_hash()
    os.makedirs(os.path.dirname(HISTORY), exist_ok=True)
    n = 0
    with open(HISTORY, "a") as fh:
        for p in sorted(paths):
            with open(p) as src:
                doc = json.load(src)
            doc["commit"] = commit
            fh.write(json.dumps(doc, sort_keys=True) + "\n")
            n += 1
    print(f"history: appended {n} record(s) to {HISTORY}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
