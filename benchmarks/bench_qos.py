"""QoS engine benchmark (ISSUE 5): checkpoint latency under contention.

The scenario the QoS engine exists for: a background flood has the cluster
saturated when a checkpoint burst lands on the same servers. Without QoS
every checkpoint chunk waits behind whatever background traffic arrived
first (FIFO inboxes, no congestion windows); with QoS the burst rides the
checkpoint lane — weighted-deficit priority on both the client dispatch
queue and the server put path — while per-lane congestion windows park the
background flood client-side. A third, steady sequential stream writes
through the PFS bypass and must never raise BB occupancy above the drain
low-watermark.

Reported: checkpoint-chunk p50/p99 completion latency for the FIFO
baseline (QoS disabled) vs the QoS run, background throughput, max
occupancy, byte-exact readback of every stream.

CLI:
  python -m benchmarks.bench_qos                 # full run (4 servers)
  python -m benchmarks.bench_qos --smoke         # capped CI run; exits
        non-zero unless checkpoint p99 improves >= --min-speedup over the
        FIFO baseline, the bypassed stream stayed under the drain
        low-watermark, and every stream read back byte-exact
  python -m benchmarks.bench_qos --json out.json # machine-readable results
"""
from __future__ import annotations

import argparse
import gc
import sys
import threading
import time
from typing import List

import numpy as np

from benchmarks import jsonout
from repro.core import BBConfig, BurstBufferSystem, DrainConfig, QoSConfig


def _config(qos_enabled: bool, n_servers: int, n_clients: int,
            dram_mb: int, drain_enabled: bool = True) -> BBConfig:
    dram = dram_mb << 20
    return BBConfig(
        num_servers=n_servers, num_clients=n_clients, placement="iso",
        dram_capacity=dram, ssd_capacity=4 * dram,
        # small segments: frequent, short SSD spills instead of rare long
        # ones — a spill stalls the store, and a multi-MB spill mid-burst
        # is indistinguishable from queueing in the latency tail
        segment_bytes=max(dram // 32, 64 << 10),
        chunk_bytes=64 << 10, coalesce_threshold=32 << 10,
        stabilize_interval=0.5,
        drain=DrainConfig(enabled=drain_enabled, pressure_interval=0.1),
        qos=QoSConfig(enabled=qos_enabled))


def _pattern(offset: int, length: int) -> bytes:
    """Deterministic bytes from the offset alone, so background rewrites of
    a region are idempotent and the final readback has one right answer
    regardless of which in-flight rewrite won. Vectorized: the generators
    must be able to saturate the servers, not the interpreter."""
    return ((np.arange(offset, offset + length, dtype=np.int64) >> 6)
            & 0xFF).astype(np.uint8).tobytes()


def _stuff_background(fs, names, total: int, chunk: int):
    """Queue ``total`` bytes of background-lane batched writes per stream
    WITHOUT waiting, then flush every coalesce buffer onto the wire. The
    payloads are pre-generated so the submit loop outruns the servers —
    the backlog the checkpoint burst faces is structural, not a race
    against thread scheduling: with FIFO servers its chunks wait behind
    the queued flood; with QoS they jump it (and the client windows park
    most of the flood before it ever reaches a server inbox). Returns the
    open handles."""
    offsets = list(range(0, total, chunk))
    payloads = [_pattern(off, chunk) for off in offsets]
    handles = [fs.open(name, "w", policy="batched", chunk_bytes=chunk,
                       lane="background") for name in names]
    for f in handles:
        for off, data in zip(offsets, payloads):
            f.pwrite(data, off)
    for c in fs.clients:
        c.flush_coalesced()
    return handles


def _through_writer(f, total: int, chunk: int, stop: threading.Event,
                    out: dict):
    """Steady sequential stream on the write-through bypass."""
    off = 0
    while off < total and not stop.is_set():
        f.pwrite(_pattern(off, chunk), off)
        off += chunk
        if (off // chunk) % 4 == 0:
            time.sleep(0.002)   # steady, not bursty
    out["bytes"] = off


def _ckpt_burst(fs, fname: str, total: int, chunk: int) -> List[float]:
    """The measured workload: a checkpoint-lane burst; returns per-chunk
    completion latencies (pwrite call -> replicated-ACK callback). The
    payloads are pre-generated so the burst hits while the background
    backlog is still deep."""
    offsets = list(range(0, total, chunk))
    payloads = [_pattern(off, chunk) for off in offsets]
    lat: List[float] = []
    lock = threading.Lock()
    f = fs.open(fname, "w", policy="async", chunk_bytes=chunk,
                lane="checkpoint")
    for off, data in zip(offsets, payloads):
        t0 = time.perf_counter()
        fut = f.pwrite(data, off)

        def _done(_fut, t0=t0):
            dt = time.perf_counter() - t0
            with lock:
                lat.append(dt)
        fut.add_done_callback(_done)
    f.close(120.0)
    return lat


def _phase(qos_enabled: bool, *, n_servers: int, n_clients: int,
           dram_mb: int, ckpt_mb: int, bg_mb: int,
           through_mb: int) -> dict:
    """One contention run: a pre-queued background flood + a steady
    write-through stream + the measured checkpoint burst. The drainer is
    off here — the flood churns the log-structured store, and
    drain/compaction stalls would add identical noise spikes to both
    phases' p99, drowning the queueing signal this phase isolates (the
    bypass phase runs with the drainer on)."""
    cfg = _config(qos_enabled, n_servers, n_clients, dram_mb,
                  drain_enabled=False)
    chunk = cfg.chunk_bytes
    out = {"qos_enabled": qos_enabled}
    with BurstBufferSystem(cfg) as sys_:
        fs = sys_.fs()
        stop = threading.Event()
        thr_out: dict = {}

        thr_f = fs.open("seq_through", "w", policy="through")
        thr_t = threading.Thread(
            target=_through_writer, daemon=True, name="through-writer",
            args=(thr_f, through_mb << 20, chunk, stop, thr_out))
        thr_t.start()

        bg_files = ["bg_stream_0", "bg_stream_1"]
        gc.collect()
        gc.disable()    # a gen-2 pause mid-burst would land random
        try:            # 10-100 ms spikes on either phase's p99
            bg_fs = _stuff_background(fs, bg_files, bg_mb << 20, chunk)

            t0 = time.perf_counter()
            lat = _ckpt_burst(fs, "ckpt_burst", ckpt_mb << 20, chunk)
            burst_s = time.perf_counter() - t0

            for f in bg_fs:     # drain the flood (barrier raises on loss)
                f.close(180.0)
            bg_s = time.perf_counter() - t0
        finally:
            gc.enable()
        stop.set()
        thr_t.join(60.0)
        thr_f.close(120.0)

        out["ckpt_p50_ms"] = float(np.percentile(lat, 50)) * 1e3
        out["ckpt_p99_ms"] = float(np.percentile(lat, 99)) * 1e3
        out["ckpt_burst_mbps"] = (ckpt_mb << 20) / burst_s / 1e6
        out["bg_mbps"] = 2 * (bg_mb << 20) / bg_s / 1e6

        # byte-exact readback of every stream
        got = fs.open("ckpt_burst", "r").pread(0, ckpt_mb << 20)
        out["ckpt_exact"] = got == b"".join(
            _pattern(o, chunk) for o in range(0, ckpt_mb << 20, chunk))
        out["bg_exact"] = True
        for name in bg_files:
            bg_st = fs.stat(name)
            got = fs.open(name, "r").pread(0, bg_st["size"])
            out["bg_exact"] &= got == b"".join(
                _pattern(o, chunk) for o in range(0, bg_st["size"], chunk))
        n = thr_out.get("bytes", 0)
        got = fs.open("seq_through", "r").pread(0, n)
        out["through_mb"] = n / 1e6
        out["through_exact"] = got == b"".join(
            _pattern(o, chunk) for o in range(0, n, chunk))
        st = fs.stat("seq_through")
        out["through_buffered_bytes"] = (st["residency"]["dram"]
                                         + st["residency"]["ssd"])
        out["fs_bypass"] = dict(fs.bypass_stats)
        stats = sys_.server_stats()
        out["puts_by_lane"] = [s.get("puts_by_lane")
                               for s in stats.values()]
        out["final_occupancy"] = max(
            (s.get("occupancy", 0.0) for s in stats.values()), default=0.0)
        out["server_errors"] = len(sys_.manager.errors)
    return out


def _bypass_phase(n_servers: int, n_clients: int, dram_mb: int,
                  through_mb: int) -> dict:
    """The ISSUE acceptance criterion in isolation: a sequential stream on
    the write-through bypass, sized so that BUFFERING it would blow far
    past the drain low-watermark, must never raise BB occupancy above it
    (the bytes go straight to the PFS) while reading back byte-exact."""
    cfg = _config(True, n_servers, n_clients, dram_mb)
    chunk = cfg.chunk_bytes
    cap = n_servers * (cfg.dram_capacity + cfg.ssd_capacity)
    # size the stream so that BUFFERING it would land well past the low
    # watermark — otherwise "occupancy stayed low" proves nothing
    total = max(through_mb << 20,
                int(1.5 * cfg.drain.low_watermark * cap / cfg.replication))
    total -= total % chunk
    out = {"through_mb": total / 1e6,
           "buffered_would_be_frac": total * cfg.replication / cap,
           "low_watermark": cfg.drain.low_watermark}
    with BurstBufferSystem(cfg) as sys_:
        fs = sys_.fs()
        occ: List[float] = []
        f = fs.open("seq_through", "w", policy="through")
        for off in range(0, total, chunk):
            f.pwrite(_pattern(off, chunk), off)
            if (off // chunk) % 32 == 0:
                pr = sys_.pressure()
                occ.extend(s.get("fraction", 0.0)
                           for s in pr["servers"].values())
        f.close(60.0)
        got = fs.open("seq_through", "r").pread(0, total)
        out["exact"] = got == b"".join(
            _pattern(o, chunk) for o in range(0, total, chunk))
        st = fs.stat("seq_through")
        out["buffered_bytes"] = (st["residency"]["dram"]
                                 + st["residency"]["ssd"])
        out["pfs_bytes"] = st["pfs_size"]
        pr = sys_.pressure()
        occ.extend(s.get("fraction", 0.0) for s in pr["servers"].values())
        out["max_occupancy"] = max(occ, default=0.0)
        out["server_errors"] = len(sys_.manager.errors)
    return out


def run(n_servers: int = 4, n_clients: int = 4, dram_mb: int = 16,
        ckpt_mb: int = 16, bg_mb: int = 64, through_mb: int = 32,
        min_speedup: float = 2.0) -> dict:
    kw = dict(n_servers=n_servers, n_clients=n_clients, dram_mb=dram_mb,
              ckpt_mb=ckpt_mb, bg_mb=bg_mb, through_mb=through_mb)
    fifo = _phase(False, **kw)
    qos = _phase(True, **kw)
    bypass = _bypass_phase(n_servers, n_clients, dram_mb, through_mb)
    speedup = fifo["ckpt_p99_ms"] / max(qos["ckpt_p99_ms"], 1e-9)
    res = {"fifo": fifo, "qos": qos, "bypass": bypass,
           "p99_speedup": speedup, "min_speedup": min_speedup,
           "ok": (speedup >= min_speedup
                  and all(p[k] for p in (fifo, qos)
                          for k in ("ckpt_exact", "bg_exact",
                                    "through_exact"))
                  and qos["through_buffered_bytes"] == 0
                  and bypass["exact"]
                  and bypass["buffered_bytes"] == 0
                  and bypass["max_occupancy"] < bypass["low_watermark"]
                  and fifo["server_errors"] == 0
                  and qos["server_errors"] == 0
                  and bypass["server_errors"] == 0)}
    return res


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="capped CI run (2 servers)")
    ap.add_argument("--min-speedup", type=float, default=2.0,
                    help="fail unless checkpoint-lane p99 under contention "
                         "beats the FIFO baseline by this factor")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write machine-readable results to PATH")
    args = ap.parse_args(argv)
    if args.smoke:
        res = run(n_servers=2, n_clients=2, dram_mb=16, ckpt_mb=4,
                  bg_mb=64, through_mb=16, min_speedup=args.min_speedup)
    else:
        res = run(min_speedup=args.min_speedup)
    for phase in ("fifo", "qos", "bypass"):
        print(f"--- {phase} ---")
        for k, v in res[phase].items():
            if isinstance(v, float):
                print(f"{k:>24}: {v:.3f}")
            else:
                print(f"{k:>24}: {v}")
    print(f"{'p99_speedup':>24}: {res['p99_speedup']:.2f}x "
          f"(floor {res['min_speedup']:.1f}x)")
    jsonout.dump(args.json, "bench_qos", res)
    if not res["ok"]:
        print("bench_qos: FAILED (see fields above)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
