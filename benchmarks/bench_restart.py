"""§III-C reproduction: restart latency — burst buffer vs PFS.

Warm scenario (run): writes a checkpoint through a BBFileSystem handle,
flushes, then measures
  bb_dram    — BBFile.pread of buffered chunks (server DRAM, manifest-
               directed fetches)
  bb_range   — lookup-table range reads (post-shuffle domains, no PFS)
  pfs        — cold-ish file read from the PFS directory
The paper's claim: recent checkpoints are retrievable without touching the
PFS; the derived column reports the speedup.

Cold scenario (run_cold, ISSUE 4): the checkpoint is FULLY EVICTED to the
PFS — the state every restart after PR 3's drain engine actually finds.
  cold_serial — the pre-staging read path: every chunk-sized read misses
                the buffer and falls back one at a time through a single
                client (read fan-out forced to 1)
  cold_staged — fs.stage() bulk-loads the file back (each server re-ingests
                its own lookup-table domain in parallel), then the same
                chunk loop reads with prefetch + parallel fan-out
Both paths are verified byte-exact; the derived column is the speedup the
stage-in engine buys. ``--smoke`` runs a capped version in CI and exits
non-zero if the speedup falls under ``--min-speedup`` (default 3x) or any
byte differs.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

from repro.core import BBConfig, BurstBufferSystem


def run(total_mb=32, seg_kb=256):
    sys_ = BurstBufferSystem(BBConfig(num_servers=4, num_clients=4,
                                      dram_capacity=256 << 20)).start()
    try:
        seg = seg_kb << 10
        n = (total_mb << 20) // seg
        rng = np.random.default_rng(0)
        fs = sys_.fs()
        with fs.open("rst", "w", policy="sync", chunk_bytes=seg) as f:
            for i in range(n):
                f.write(rng.integers(0, 256, seg, dtype=np.uint8).tobytes())
        assert sys_.flush(epoch=0, timeout=60)

        c = sys_.clients[0]
        r = fs.open("rst", "r")
        t0 = time.perf_counter()
        for i in range(n):
            assert len(r.pread(i * seg, seg)) == seg
        t_dram = time.perf_counter() - t0

        t0 = time.perf_counter()
        data = c.read_file("rst", 0, total_mb << 20)
        t_range = time.perf_counter() - t0
        assert data is not None and len(data) == total_mb << 20

        path = os.path.join(sys_.pfs_dir, "rst")
        t0 = time.perf_counter()
        with open(path, "rb") as f:
            pfs_data = f.read()
        t_pfs = time.perf_counter() - t0
        assert pfs_data == data
    finally:
        sys_.stop()

    bw = lambda t: (total_mb << 20) / t / 1e6
    return [
        ("restart_bb_dram", t_dram * 1e6, f"{bw(t_dram):.0f} MB/s"),
        ("restart_bb_range", t_range * 1e6, f"{bw(t_range):.0f} MB/s"),
        ("restart_pfs", t_pfs * 1e6, f"{bw(t_pfs):.0f} MB/s"),
    ]


def _evict_fully(sys_, fname: str, timeout: float = 10.0):
    """Retention-evict the file and wait until no server buffers a byte."""
    sys_.evict(fname)
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        st = sys_.fs().stat(fname)
        if st["residency"]["dram"] == 0 and st["residency"]["ssd"] == 0:
            return st
        time.sleep(0.05)
    raise RuntimeError(f"{fname} still buffered after evict")


def _read_per_miss(fs, fname: str, total: int, seg: int) -> tuple:
    """The pre-staging restart read: chunk-sized preads, every one missing
    the buffer and falling back serially (caller pins fan-out to 1).
    Returns (seconds, bytes)."""
    r = fs.open(fname, "r", prefetch=False)
    out = bytearray(total)
    t0 = time.perf_counter()
    for off in range(0, total, seg):
        out[off:off + seg] = r.pread(off, min(seg, total - off))
    return time.perf_counter() - t0, bytes(out)


def run_cold(total_mb=16, seg_kb=32, n_servers=4, min_speedup=3.0) -> dict:
    """Cold restart off a fully-evicted checkpoint: serial per-miss
    fallback vs stage-in + parallel fan-out, both byte-exact."""
    cfg = BBConfig(num_servers=n_servers, num_clients=n_servers,
                   dram_capacity=256 << 20, chunk_bytes=seg_kb << 10)
    cfg.stage.slice_bytes = 1 << 20
    total, seg = total_mb << 20, seg_kb << 10
    rng = np.random.default_rng(1)
    data = rng.integers(0, 256, total, dtype=np.uint8).tobytes()
    out = {"total_mb": total_mb, "seg_kb": seg_kb}
    sys_ = BurstBufferSystem(cfg).start()
    try:
        fs = sys_.fs()
        with fs.open("coldrst", "w", policy="batched", chunk_bytes=seg) as f:
            f.pwrite(data, 0)
        assert sys_.flush(epoch=0, timeout=60)
        _evict_fully(sys_, "coldrst")

        # baseline: the pre-staging read path — no stage, no prefetch, and
        # fan-out forced to 1 so every miss is one serial client round-trip
        fanouts = [(fs, fs.read_fanout)] + \
            [(c, c.read_fanout) for c in sys_.clients]
        for obj, _ in fanouts:
            obj.read_fanout = 1
        t_serial, got = _read_per_miss(fs, "coldrst", total, seg)
        out["serial_exact"] = got == data
        for obj, fo in fanouts:
            obj.read_fanout = fo

        # re-evict what the serial read's fallbacks may have left warm
        _evict_fully(sys_, "coldrst")

        # staged restart: one bulk stage-in (timed — it is part of the
        # restart) pulls every domain back in parallel, then the read
        # assembles from buffered chunks with the parallel fan-out
        t0 = time.perf_counter()
        staged = fs.stage("coldrst")
        got = fs.open("coldrst", "r").pread(0, total)
        t_staged = time.perf_counter() - t0
        out["staged_exact"] = got == data
        out["stage_completed"] = bool(staged)
        out["stage_stats"] = dict(sys_.manager.stage_stats)
        out["server_errors"] = len(sys_.manager.errors)
        out["serial_s"] = t_serial
        out["staged_s"] = t_staged
        out["serial_mbps"] = total / t_serial / 1e6
        out["staged_mbps"] = total / t_staged / 1e6
        out["speedup"] = t_serial / t_staged
        out["ok"] = (out["serial_exact"] and out["staged_exact"]
                     and out["stage_completed"]
                     and out["server_errors"] == 0
                     and out["speedup"] >= min_speedup)
    finally:
        sys_.stop()
    return out


def main():
    rows = run()
    cold = run_cold()
    rows += [
        ("restart_cold_serial", cold["serial_s"] * 1e6,
         f"{cold['serial_mbps']:.0f} MB/s"),
        ("restart_cold_staged", cold["staged_s"] * 1e6,
         f"{cold['staged_mbps']:.0f} MB/s "
         f"({cold['speedup']:.1f}x serial)"),
    ]
    return rows


if __name__ == "__main__":
    from benchmarks import jsonout
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="capped CI run of the cold-restart scenario")
    ap.add_argument("--min-speedup", type=float, default=3.0,
                    help="fail if stage-in + fan-out restart is not at "
                         "least this much faster than the serial per-miss "
                         "fallback baseline")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write machine-readable results to PATH")
    args = ap.parse_args()
    if args.smoke:
        res = run_cold(total_mb=8, seg_kb=32, n_servers=2,
                       min_speedup=args.min_speedup)
        for k, v in res.items():
            print(f"{k:>16}: {v:.2f}" if isinstance(v, float)
                  else f"{k:>16}: {v}")
        jsonout.dump(args.json, "bench_restart", res)
        if not res["ok"]:
            print("bench_restart: FAILED (see fields above)",
                  file=sys.stderr)
            raise SystemExit(1)
        print(f"bench_smoke_restart,0.0,{res['speedup']:.1f}x OK")
    else:
        rows = main()
        print("name,us_per_call,derived")
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        jsonout.dump(args.json, "bench_restart",
                     jsonout.rows_to_records(rows))
