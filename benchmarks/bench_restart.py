"""§III-C reproduction: restart latency — burst buffer vs PFS.

Writes a checkpoint through a BBFileSystem handle, flushes, then measures
  bb_dram    — BBFile.pread of buffered chunks (server DRAM, manifest-
               directed fetches)
  bb_range   — lookup-table range reads (post-shuffle domains, no PFS)
  pfs        — cold-ish file read from the PFS directory
The paper's claim: recent checkpoints are retrievable without touching the
PFS; the derived column reports the speedup.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import BBConfig, BurstBufferSystem


def run(total_mb=32, seg_kb=256):
    sys_ = BurstBufferSystem(BBConfig(num_servers=4, num_clients=4,
                                      dram_capacity=256 << 20)).start()
    try:
        seg = seg_kb << 10
        n = (total_mb << 20) // seg
        rng = np.random.default_rng(0)
        fs = sys_.fs()
        with fs.open("rst", "w", policy="sync", chunk_bytes=seg) as f:
            for i in range(n):
                f.write(rng.integers(0, 256, seg, dtype=np.uint8).tobytes())
        assert sys_.flush(epoch=0, timeout=60)

        c = sys_.clients[0]
        r = fs.open("rst", "r")
        t0 = time.perf_counter()
        for i in range(n):
            assert len(r.pread(i * seg, seg)) == seg
        t_dram = time.perf_counter() - t0

        t0 = time.perf_counter()
        data = c.read_file("rst", 0, total_mb << 20)
        t_range = time.perf_counter() - t0
        assert data is not None and len(data) == total_mb << 20

        path = os.path.join(sys_.pfs_dir, "rst")
        t0 = time.perf_counter()
        with open(path, "rb") as f:
            pfs_data = f.read()
        t_pfs = time.perf_counter() - t0
        assert pfs_data == data
    finally:
        sys_.stop()

    bw = lambda t: (total_mb << 20) / t / 1e6
    return [
        ("restart_bb_dram", t_dram * 1e6, f"{bw(t_dram):.0f} MB/s"),
        ("restart_bb_range", t_range * 1e6, f"{bw(t_range):.0f} MB/s"),
        ("restart_pfs", t_pfs * 1e6, f"{bw(t_pfs):.0f} MB/s"),
    ]


def main():
    return run()
