# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness.

  python -m benchmarks.run            # all benches
  python -m benchmarks.run --only fig5,fig6
  python -m benchmarks.run --json out.json   # machine-readable results too

Benches (paper artifact -> module):
  Fig 5 ingress scaling        -> bench_ingress  (sim: calibrated Titan model;
                                                  real: threaded implementation)
  Fig 6 hybrid storage         -> bench_hybrid   (real LogStore tiers)
  SIII-B two-phase I/O         -> bench_twophase (real system flush)
  SIII-C restart               -> bench_restart  (real BB vs PFS reads)
  checkpoint stall (framework) -> bench_ckpt     (train-state save paths)
  QoS lanes + bypass           -> bench_qos      (priority under contention)
  roofline summary             -> roofline_report (dry-run artifacts)
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import jsonout


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write every bench's rows as JSON to PATH")
    args = ap.parse_args()

    from benchmarks import (bench_ablation, bench_ckpt, bench_hybrid,
                            bench_ingress, bench_qos, bench_restart,
                            bench_twophase, roofline_report)
    benches = {
        "fig5": bench_ingress.main,
        "fig6": bench_hybrid.main,
        "twophase": bench_twophase.main,
        "restart": bench_restart.main,
        "ckpt": bench_ckpt.main,
        "ablation": bench_ablation.main,
        "qos": lambda: _qos_rows(bench_qos),
        "roofline": roofline_report.main,
    }
    only = [s for s in args.only.split(",") if s]
    print("name,us_per_call,derived")
    failed = 0
    doc = {}
    for key, fn in benches.items():
        if only and key not in only:
            continue
        try:
            rows = fn()
            for name, us, derived in rows:
                print(f"{name},{us:.1f},{derived}")
            doc[key] = jsonout.rows_to_records(rows)
        except Exception as e:
            failed += 1
            doc[key] = {"error": repr(e)}
            print(f"{key},nan,ERROR {e!r}")
            traceback.print_exc(file=sys.stderr)
    jsonout.dump(args.json, "run", doc)
    if failed:
        raise SystemExit(1)


def _qos_rows(bench_qos):
    """bench_qos reports dicts; fold the headline numbers into rows."""
    res = bench_qos.run()
    return [
        ("qos_ckpt_p99_fifo", res["fifo"]["ckpt_p99_ms"] * 1e3,
         f"{res['fifo']['ckpt_p99_ms']:.0f} ms p99 under contention"),
        ("qos_ckpt_p99_lanes", res["qos"]["ckpt_p99_ms"] * 1e3,
         f"{res['qos']['ckpt_p99_ms']:.0f} ms p99 "
         f"({res['p99_speedup']:.1f}x better, ok={res['ok']})"),
        ("qos_bypass_occupancy", 0.0,
         f"max {res['bypass']['max_occupancy']:.2f} vs low-watermark "
         f"{res['bypass']['low_watermark']:.2f}"),
    ]


if __name__ == '__main__':
    main()
