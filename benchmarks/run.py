# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness.

  python -m benchmarks.run            # all benches
  python -m benchmarks.run --only fig5,fig6

Benches (paper artifact -> module):
  Fig 5 ingress scaling        -> bench_ingress  (sim: calibrated Titan model;
                                                  real: threaded implementation)
  Fig 6 hybrid storage         -> bench_hybrid   (real LogStore tiers)
  SIII-B two-phase I/O         -> bench_twophase (real system flush)
  SIII-C restart               -> bench_restart  (real BB vs PFS reads)
  checkpoint stall (framework) -> bench_ckpt     (train-state save paths)
  roofline summary             -> roofline_report (dry-run artifacts)
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    from benchmarks import (bench_ablation, bench_ckpt, bench_hybrid,
                            bench_ingress, bench_restart, bench_twophase,
                            roofline_report)
    benches = {
        "fig5": bench_ingress.main,
        "fig6": bench_hybrid.main,
        "twophase": bench_twophase.main,
        "restart": bench_restart.main,
        "ckpt": bench_ckpt.main,
        "ablation": bench_ablation.main,
        "roofline": roofline_report.main,
    }
    only = [s for s in args.only.split(",") if s]
    print("name,us_per_call,derived")
    failed = 0
    for key, fn in benches.items():
        if only and key not in only:
            continue
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:
            failed += 1
            print(f"{key},nan,ERROR {e!r}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
