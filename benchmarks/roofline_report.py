"""Roofline table from dry-run artifacts (results/dryrun/*.json)."""
from __future__ import annotations

import glob
import json
import os


def load(out_dir="results/dryrun"):
    rows = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        rows.append(json.load(open(f)))
    return rows


def table(rows, mesh_prefix="pod"):
    ok = [r for r in rows if r.get("status") == "ok"
          and r["mesh"].startswith(mesh_prefix)]
    lines = []
    hdr = (f"{'arch':22s} {'shape':12s} {'t_comp':>8s} {'t_mem':>8s} "
           f"{'t_link':>8s} {'bneck':>7s} {'useful':>7s} {'roofline':>9s}")
    lines.append(hdr)
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} {r['t_compute']:8.2f} "
            f"{r['t_memory']:8.2f} {r['t_collective']:8.2f} "
            f"{r['bottleneck'][:7]:>7s} {r['useful_flops_ratio']:7.2f} "
            f"{r['roofline_fraction']:9.4f}")
    return "\n".join(lines)


def main():
    rows = load()
    if not rows:
        return [("roofline", 0.0, "no dry-run artifacts yet")]
    ok = [r for r in rows if r.get("status") == "ok"]
    sk = [r for r in rows if r.get("status") == "skipped"]
    err = [r for r in rows if r.get("status") == "error"]
    out = [("dryrun_cells", 0.0,
            f"{len(ok)} ok / {len(sk)} documented-skip / {len(err)} error")]
    for r in sorted(ok, key=lambda r: -r["roofline_fraction"])[:3]:
        out.append((f"roofline_best_{r['arch']}_{r['shape']}", 0.0,
                    f"{r['roofline_fraction']:.4f} ({r['bottleneck']})"))
    return out
