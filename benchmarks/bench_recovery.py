"""ISSUE 8: whole-cluster crash recovery from the SSD logs.

The paper's case for SSD log-structuring (§V, Fig 6) is that the SSD tier
is durable local media — so a full-cluster restart must be a recovery, not
a wipe. This bench measures exactly that promise:

  1. A checkpoint is written through a BBFileSystem handle into a cluster
     with ``dram_capacity=0`` and the drain engine off, so every acked byte
     is SSD-resident (spilled + fsynced into the per-server record logs)
     and NONE of it reaches the PFS — the only durable copy is the logs.
  2. The whole cluster is torn down. Only the SSD directory (record logs +
     manager journal) survives, exactly what a node reboot leaves behind.
  3. A cold cluster starts over the surviving directory and is timed to
     first-readable-byte (construction + log replay + manifest rebuild +
     ring formation + one chunk read) and to a full byte-exact readback.

``ok`` requires byte-exact reads (first chunk and whole file), a non-zero
recovered-key count from the server stats, and the manager journal having
rebuilt the namespace entry (path known, synced, right size). ``--smoke``
runs a capped version for CI; ``--json`` feeds benchmarks.compare against
the committed BENCH_recovery baseline (headline: recovered_mbps).
"""
from __future__ import annotations

import argparse
import os
import shutil
import sys
import tempfile
import time

import numpy as np

from repro.core import BBConfig, BurstBufferSystem


def _cfg(n_servers: int, seg: int, ssd_dir: str, pfs_dir: str) -> BBConfig:
    cfg = BBConfig(num_servers=n_servers, num_clients=n_servers,
                   dram_capacity=0,          # every acked byte spills to SSD
                   ssd_capacity=4 << 30,     # soft cap: keep occupancy low
                   ssd_dir=ssd_dir, pfs_dir=pfs_dir,
                   chunk_bytes=seg)
    cfg.drain.enabled = False                # nothing drains to the PFS:
    return cfg                               # the logs are the only copy


def run_recovery(total_mb=16, seg_kb=64, n_servers=4) -> dict:
    base = tempfile.mkdtemp(prefix="bbrec_")
    ssd_dir = os.path.join(base, "ssd")
    pfs_dir = os.path.join(base, "pfs")
    total, seg = total_mb << 20, seg_kb << 10
    rng = np.random.default_rng(2)
    data = rng.integers(0, 256, total, dtype=np.uint8).tobytes()
    out = {"total_mb": total_mb, "seg_kb": seg_kb, "servers": n_servers}

    sys_ = BurstBufferSystem(_cfg(n_servers, seg, ssd_dir, pfs_dir)).start()
    try:
        fs = sys_.fs()
        t0 = time.perf_counter()
        with fs.open("ckpt", "w", policy="batched", chunk_bytes=seg) as f:
            f.pwrite(data, 0)
        out["write_s"] = time.perf_counter() - t0
        st = fs.stat("ckpt")
        out["pre_dram"] = st["residency"]["dram"]
        out["pre_ssd"] = st["residency"]["ssd"]
    finally:
        # the "crash": every thread dies; the system tmpdir is wiped; only
        # the explicit ssd_dir (record logs + manager journal) and pfs_dir
        # survive — what a real reboot leaves on local media
        sys_.stop()

    t0 = time.perf_counter()
    sys2 = BurstBufferSystem(_cfg(n_servers, seg, ssd_dir, pfs_dir)).start()
    try:
        fs2 = sys2.fs()
        r = fs2.open("ckpt", "r")
        first = r.pread(0, seg)
        out["first_byte_s"] = time.perf_counter() - t0
        got = r.pread(0, total)
        out["recover_s"] = time.perf_counter() - t0
        out["first_exact"] = first == data[:seg]
        out["exact"] = got == data
        ns = sys2.manager.namespace.get("ckpt", {})
        out["ns_known"] = bool(ns.get("synced"))
        out["ns_size"] = ns.get("size", 0)
        stats = sys2.server_stats()
        out["recovered_keys"] = sum(s.get("recovered_keys", 0)
                                    for s in stats.values())
        out["recovered_mb"] = sum(s.get("recovered_bytes", 0)
                                  for s in stats.values()) / 1e6
        out["server_errors"] = len(sys2.manager.errors)
        out["recovered_mbps"] = total / out["recover_s"] / 1e6
        out["ok"] = (out["exact"] and out["first_exact"]
                     and out["recovered_keys"] > 0
                     and out["ns_known"] and out["ns_size"] == total
                     and out["server_errors"] == 0)
    finally:
        sys2.stop()
        shutil.rmtree(base, ignore_errors=True)
    return out


def main():
    res = run_recovery()
    total = res["total_mb"] << 20
    return [
        ("recovery_first_byte", res["first_byte_s"] * 1e6,
         f"cold restart -> first chunk in {res['first_byte_s']:.3f}s"),
        ("recovery_full_readback", res["recover_s"] * 1e6,
         f"{res['recovered_mbps']:.0f} MB/s over {total >> 20} MB "
         f"({res['recovered_keys']} keys replayed)"),
    ], res


if __name__ == "__main__":
    from benchmarks import jsonout
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="capped CI run: fails unless the cold restart "
                         "recovers every acked SSD-resident byte byte-exact")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write machine-readable results to PATH")
    args = ap.parse_args()
    if args.smoke:
        res = run_recovery(total_mb=8, seg_kb=64, n_servers=2)
        for k, v in res.items():
            print(f"{k:>16}: {v:.2f}" if isinstance(v, float)
                  else f"{k:>16}: {v}")
        jsonout.dump(args.json, "bench_recovery", res)
        if not res["ok"]:
            print("bench_recovery: FAILED (see fields above)",
                  file=sys.stderr)
            raise SystemExit(1)
        print(f"bench_smoke_recovery,0.0,"
              f"{res['recovered_mbps']:.0f}MB/s OK")
    else:
        rows, res = main()
        print("name,us_per_call,derived")
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        jsonout.dump(args.json, "bench_recovery", res)
