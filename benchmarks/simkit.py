"""Calibrated bandwidth models for the paper's Titan/Spider-II testbed.

This container has one CPU and no Lustre/Gemini, so Fig-5-scale ingress
curves are produced from closed-form contention models whose *structure*
encodes the paper's physics and whose two free parameters (shared-file lock
contention, ketama fan-in contention) are calibrated so the 128-server
ratios match the paper's reported results (BB-ISO = 2.78x IOR-SF,
1.745x IOR-SFP). Everything else (linear ISO scaling, PFS saturation,
sub-linear ketama growth) is then *predicted* by the model, not fitted.

The real (threads + real bytes) small-scale counterpart of these curves is
measured in bench_ingress.run_real() against the actual implementation.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class Testbed:
    b_pair: float = 0.9e9       # client->server ingest per ISO pair (CCI, B/s)
    b_ost: float = 0.515e9      # single-stream OST write (B/s)
    pfs_cap: float = 1e12       # Spider II aggregate (B/s)
    lock_lambda: float = 0.0192     # shared-file extent-lock contention
    ketama_gamma: float = 0.2       # per-server fan-in contention (log n)


def ingress_bandwidth(n: int, mode: str, tb: Testbed = Testbed()) -> float:
    """Aggregate ingress bandwidth (B/s) for n clients + n servers/OSTs."""
    if mode == "bb_iso":
        # isolated placement: each client pinned to one server; no fan-in
        return n * tb.b_pair
    if mode == "bb_ketama":
        # every client sprays every server: fan-in contention per server
        eff = tb.b_pair / (1.0 + tb.ketama_gamma * math.log2(max(n, 2)))
        return n * eff
    if mode == "ior_sfp":
        # file-per-process, stripe 1: n independent OST streams, PFS cap
        return min(n * tb.b_ost, tb.pfs_cap)
    if mode == "ior_sf":
        # shared file, stripe n: extent-lock contention across writers
        eff = tb.b_ost / (1.0 + tb.lock_lambda * (n - 1))
        return min(n * eff, tb.pfs_cap)
    raise ValueError(mode)


def fig5_table(ns=(1, 2, 4, 8, 16, 32, 64, 128), tb: Testbed = Testbed()):
    rows = []
    for n in ns:
        rows.append({
            "servers": n,
            "bb_iso": ingress_bandwidth(n, "bb_iso", tb),
            "bb_ketama": ingress_bandwidth(n, "bb_ketama", tb),
            "ior_sfp": ingress_bandwidth(n, "ior_sfp", tb),
            "ior_sf": ingress_bandwidth(n, "ior_sf", tb),
        })
    return rows
