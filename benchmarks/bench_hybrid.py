"""Fig 6 reproduction: hybrid-storage (DRAM/SSD) burst buffer throughput.

Real measurement on this machine's storage via LogStore:
  bbIORMEM — all data fits DRAM
  bbIORHYB — half the data spills to the SSD-tier log
  bbIORSSD — DRAM=0, everything spills (log-structured sequential)
  IORSSD   — two interleaved writers seek/write one file directly
             (the paper's "semi-random arrival order")
  SSDSeq   — single sequential stream (device ceiling for logs)
  SSDRND   — random 16 KB writes
Expected ordering (paper): MEM > HYB > SSD ~= SSDSeq > IORSSD > RND.
Absolute numbers reflect this container's disk, not an OCZ-VERTEX4.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core.tiering import LogStore

TOTAL_MB = 64
SEG_KB = 16        # paper uses 16 KB transfers for Fig 6


def _bb_case(tmp, dram_mb: int, name: str) -> float:
    store = LogStore(dram_mb << 20, tmp, name=name)
    seg = SEG_KB << 10
    n = (TOTAL_MB << 20) // seg
    payload = os.urandom(seg)
    t0 = time.perf_counter()
    for i in range(n):
        store.put(f"k{i}", payload)
    _sync(tmp)
    return (TOTAL_MB << 20) / (time.perf_counter() - t0)


def _sync(tmp):
    for f in os.listdir(tmp):
        fd = os.open(os.path.join(tmp, f), os.O_RDONLY)
        os.fsync(fd)
        os.close(fd)


def _direct_interleaved(tmp) -> float:
    """Two clients' 16 KB writes arriving interleaved at semi-random
    offsets of a shared file (what the device sees without a burst buffer)."""
    path = os.path.join(tmp, "direct.dat")
    seg = SEG_KB << 10
    n = (TOTAL_MB << 20) // seg
    half = n // 2
    payload = os.urandom(seg)
    t0 = time.perf_counter()
    with open(path, "wb") as f:
        for i in range(half):
            for client in (0, 1):                  # interleaved arrival
                off = (client * half + i) * seg
                f.seek(off)
                f.write(payload)
        f.flush()
        os.fsync(f.fileno())
    return (TOTAL_MB << 20) / (time.perf_counter() - t0)


def _seq(tmp) -> float:
    path = os.path.join(tmp, "seq.dat")
    payload = os.urandom(1 << 20)
    t0 = time.perf_counter()
    with open(path, "wb") as f:
        for _ in range(TOTAL_MB):
            f.write(payload)
        f.flush()
        os.fsync(f.fileno())
    return (TOTAL_MB << 20) / (time.perf_counter() - t0)


def _rnd(tmp) -> float:
    path = os.path.join(tmp, "rnd.dat")
    seg = SEG_KB << 10
    n = (TOTAL_MB << 20) // seg
    rng = np.random.default_rng(0)
    order = rng.permutation(n)
    payload = os.urandom(seg)
    t0 = time.perf_counter()
    with open(path, "wb") as f:
        f.truncate(TOTAL_MB << 20)
        for i in order:
            f.seek(int(i) * seg)
            f.write(payload)
        f.flush()
        os.fsync(f.fileno())
    return (TOTAL_MB << 20) / (time.perf_counter() - t0)


def main(tmpdir: str = "/tmp/bench_hybrid"):
    import shutil
    shutil.rmtree(tmpdir, ignore_errors=True)
    os.makedirs(tmpdir, exist_ok=True)
    res = {
        "bbIORMEM": _bb_case(tmpdir, TOTAL_MB * 2, "mem"),
        "bbIORHYB": _bb_case(tmpdir, TOTAL_MB // 2, "hyb"),
        "bbIORSSD": _bb_case(tmpdir, 0, "ssd"),
        "IORSSD_direct": _direct_interleaved(tmpdir),
        "SSDSeq": _seq(tmpdir),
        "SSDRND": _rnd(tmpdir),
    }
    shutil.rmtree(tmpdir, ignore_errors=True)
    out = [(f"fig6_{k}", 0.0, f"{v/1e6:.0f} MB/s") for k, v in res.items()]
    ok = res["bbIORMEM"] >= res["bbIORHYB"] >= res["bbIORSSD"] * 0.8
    out.append(("fig6_ordering_mem>=hyb>=ssd", 0.0, str(ok)))
    return out


if __name__ == "__main__":
    from benchmarks import jsonout
    jsonout.cli_main(main, "bench_hybrid")
