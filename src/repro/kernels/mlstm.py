"""mLSTM (xLSTM matrix-memory cell) chunkwise-parallel Pallas TPU kernel.

The sequential cell is
    m_t = max(lf_t + m_{t-1}, li_t)
    C_t = exp(lf_t + m_{t-1} - m_t) C_{t-1} + exp(li_t - m_t) k_t v_t^T
    n_t = exp(lf_t + m_{t-1} - m_t) n_{t-1} + exp(li_t - m_t) k_t
    h_t = C_t q_t / max(|n_t . q_t|, exp(-m_t))

TPU adaptation (chunkwise-parallel form): within a chunk of size c the
contribution of in-chunk tokens is an attention-like masked matmul
(MXU-friendly (c×c) x (c×d)), while the cross-chunk contribution comes from
the carried (d×d) state; both are stabilized in a shared log-space max. The
(d×d) state, (d,) normalizer and scalar stabilizer live in VMEM scratch and
carry across the sequential chunk grid dimension — the state never touches
HBM. This replaces the GPU formulation's warp-level recurrence with a
systolic-matmul-dominant form.

Grid: (batch, heads, s_chunks) — trailing dim sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _mlstm_kernel(q_ref, k_ref, v_ref, lf_ref, li_ref,
                  h_ref, c_out, n_out, m_out,
                  c_scr, n_scr, m_scr, *, chunk, scale):
    si = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(si == 0)
    def _init():
        c_scr[...] = jnp.zeros_like(c_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        m_scr[...] = jnp.full_like(m_scr, NEG)

    q = q_ref[0, 0].astype(jnp.float32)                   # (c, d)
    k = k_ref[0, 0].astype(jnp.float32) * scale
    v = v_ref[0, 0].astype(jnp.float32)
    lf = lf_ref[0, 0, 0].astype(jnp.float32)              # (c,)
    li = li_ref[0, 0, 0].astype(jnp.float32)

    # cumulative log-forget within the chunk: F[t] = sum_{u<=t} lf[u]
    F = jnp.cumsum(lf)                                    # (c,)
    m_prev = m_scr[0, 0]

    # log coefficient of the *carried* state at step t: F[t] + m_prev
    # log coefficient of in-chunk source u<=t: (F[t] - F[u]) + li[u]
    src = li - F                                          # (c,)
    # running stabilizer per step: m_t = max(m_prev + F[t], max_{u<=t}(F[t]+src[u]))
    run_src = jax.lax.cummax(src)
    m_t = F + jnp.maximum(m_prev, run_src)                # (c,)

    # in-chunk attention-like term
    d_mat = F[:, None] + src[None, :] - m_t[:, None]      # (c, c) log weights
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    u_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    d_mat = jnp.where(u_idx <= t_idx, d_mat, NEG)
    w = jnp.exp(d_mat)                                    # (c, c)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (c, c)
    ws = w * s
    intra_num = jax.lax.dot_general(ws, v, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    # n_t . q_t = carry_coeff * (n_prev . q_t) + sum_u w[t,u] * (k_u . q_t)
    intra_den = jnp.sum(ws, axis=1)                       # (c,)

    carry_coeff = jnp.exp(F + m_prev - m_t)               # (c,)
    inter_num = jax.lax.dot_general(q, c_scr[0], (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    inter_den = jax.lax.dot_general(q, n_scr[0][:, None],
                                    (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)[:, 0]

    num = inter_num * carry_coeff[:, None] + intra_num    # (c, d)
    den = inter_den * carry_coeff + intra_den             # (c,)
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
    h_ref[0, 0] = (num / den[:, None]).astype(h_ref.dtype)

    # ---- state update to end of chunk ----
    m_last = m_t[-1]
    # carried state coefficient
    f_all = F[-1]
    state_coeff = jnp.exp(f_all + m_prev - m_last)
    # each in-chunk source u contributes exp(F[c-1]-F[u]+li[u]-m_last) k_u v_u^T
    src_coeff = jnp.exp(f_all + src - m_last)             # (c,)
    kc = k * src_coeff[:, None]
    c_new = c_scr[0] * state_coeff + jax.lax.dot_general(
        kc, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    n_new = n_scr[0] * state_coeff + jnp.sum(kc, axis=0)
    c_scr[0] = c_new
    n_scr[0] = n_new
    m_scr[0, 0] = m_last

    @pl.when(si == ns - 1)
    def _final():
        c_out[0, 0] = c_new.astype(c_out.dtype)
        n_out[0, 0] = n_new.astype(n_out.dtype)
        m_out[0, 0, 0] = m_last


def mlstm_pallas(q, k, v, log_f, log_i, *, chunk=128, interpret=False):
    """q/k/v: (B, S, H, D); log_f/log_i: (B, S, H).

    Returns (h (B,S,H,D), (C (B,H,D,D), n (B,H,D), m (B,H))).
    Fresh state (zero init), matching ref.mlstm with no initial state.
    """
    b, s, h, d = q.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    qt = q.transpose(0, 2, 1, 3)                          # (B,H,S,D)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    lft = log_f.transpose(0, 2, 1)[:, :, None, :]         # (B,H,1,S)
    lit = log_i.transpose(0, 2, 1)[:, :, None, :]

    grid = (b, h, s // chunk)
    kernel = functools.partial(_mlstm_kernel, chunk=chunk, scale=d ** -0.5)

    hseq, c_f, n_f, m_f = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, si: (b_, h_, si, 0)),
            pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, si: (b_, h_, si, 0)),
            pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, si: (b_, h_, si, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda b_, h_, si: (b_, h_, 0, si)),
            pl.BlockSpec((1, 1, 1, chunk), lambda b_, h_, si: (b_, h_, 0, si)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, si: (b_, h_, si, 0)),
            pl.BlockSpec((1, 1, d, d), lambda b_, h_, si: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, d), lambda b_, h_, si: (b_, h_, 0)),
            pl.BlockSpec((1, 1, 1), lambda b_, h_, si: (b_, h_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
            jax.ShapeDtypeStruct((b, h, d, d), q.dtype),
            jax.ShapeDtypeStruct((b, h, d), q.dtype),
            jax.ShapeDtypeStruct((b, h, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, d, d), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt, lft, lit)
    return hseq.transpose(0, 2, 1, 3), (c_f, n_f, m_f[..., 0])
