"""Fused blockwise attention (flash attention) as a Pallas TPU kernel.

TPU adaptation: the grid's trailing dimension iterates KV blocks sequentially
(TPU grids execute in order), so the online-softmax statistics (m, l) and the
output accumulator live in VMEM scratch and carry across KV iterations —
no HBM round-trips for the S×S score matrix. Q blocks of (block_q × head_dim)
and KV blocks of (block_k × head_dim) are staged HBM→VMEM by BlockSpecs; the
two matmuls per block hit the MXU with 128-aligned shapes.

Supports: causal masking, sliding-window masking, gemma-style logit softcap,
GQA (kv-head indexed via the BlockSpec index_map — no materialized repeat).
Fully-masked KV blocks are skipped via the grid bounds per q-block row
(causal/window block pruning happens in the index domain, not with @pl.when,
so skipped blocks are never fetched).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 block_q, block_k, seq_k, causal, window, softcap, scale,
                 q_offset):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale           # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                   # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)                   # (bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) \
        + q_offset
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = kpos < seq_k
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                   # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, window=0, softcap=0.0,
                           q_offset=0, block_q=128, block_k=128,
                           interpret=False):
    """q: (B, Sq, H, D); k/v: (B, Sk, KV, D) -> (B, Sq, H, D)."""
    b, sq, h, d = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    assert h % kvh == 0
    group = h // kvh
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    # pad seq lengths to block multiples (masked out by kpos < seq_k)
    pq = (-sq) % block_q
    pk = (-sk) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    sq_p, sk_p = sq + pq, sk + pk

    # (B, S, H, D) -> (B, H, S, D) blocks; kv head via index_map h -> h // group
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    grid = (b, h, sq_p // block_q, sk_p // block_k)

    kernel = functools.partial(
        _attn_kernel, block_q=block_q, block_k=block_k, seq_k=sk,
        causal=causal, window=window, softcap=softcap, scale=d ** -0.5,
        q_offset=q_offset)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, qi, ki, g=group: (b_, h_ // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, qi, ki, g=group: (b_, h_ // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum
            pltpu.VMEM((block_q, d), jnp.float32),   # output accum
        ],
        interpret=interpret,
    )(qt, kt, vt)

    out = out.transpose(0, 2, 1, 3)
    if pq:
        out = out[:, :sq]
    return out
