"""Public kernel API. Dispatches per backend:

- TPU: Pallas kernels (flash_attention.py / rg_lru.py / mlstm.py / quantize.py)
- CPU (this container, incl. the 512-device dry-run): pure-jnp implementations
  with the SAME blockwise structure — attention is a lax.scan over KV chunks
  with online softmax, so the lowered HLO never materializes the S x S score
  matrix and the dry-run's memory/FLOP profile matches the fused kernel.

Set REPRO_FORCE_INTERPRET=1 to run the real Pallas kernels in interpret mode
(used by kernel unit tests).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _force_interpret() -> bool:
    return os.environ.get("REPRO_FORCE_INTERPRET", "0") == "1"


# ---------------------------------------------------------------------------
# flash attention


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    q_offset=0, chunk=512):
    """q: (B,Sq,H,D); k/v: (B,Sk,KV,D) -> (B,Sq,H,D).

    Differentiable with a FLASH BACKWARD (custom VJP): the forward saves only
    (o, m, l); the backward recomputes scores chunkwise. Without this,
    differentiating through the online-softmax scan saves every chunk's
    probability matrix — measured at ~16 GB per layer on train_4k shapes.
    """
    if _on_tpu() or _force_interpret():
        from repro.kernels.flash_attention import flash_attention_pallas
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, softcap=softcap,
            q_offset=q_offset, interpret=not _on_tpu())
    return _flash_vjp(q, k, v, causal, window, softcap, q_offset, chunk)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_vjp(q, k, v, causal, window, softcap, q_offset, chunk):
    return _flash_chunked_jnp(q, k, v, causal=causal, window=window,
                              softcap=softcap, q_offset=q_offset, chunk=chunk)


def _flash_fwd(q, k, v, causal, window, softcap, q_offset, chunk):
    o, m, l = _flash_chunked_jnp(q, k, v, causal=causal, window=window,
                                 softcap=softcap, q_offset=q_offset,
                                 chunk=chunk, return_stats=True)
    return o, (q, k, v, o, m, l)


def _flash_bwd(causal, window, softcap, q_offset, chunk, res, g_out):
    """Chunkwise flash backward: recompute p per KV chunk; no saved scores."""
    q, k, v, o, m, l = res
    b, sq, h, d = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    chunk_ = min(chunk, sk)
    pad = (-sk) % chunk_
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nkc = (sk + pad) // chunk_
    scale = d ** -0.5

    qf = q.astype(jnp.float32).reshape(b, sq, kvh, g, d)
    go = g_out.astype(jnp.float32).reshape(b, sq, kvh, g, d)
    of = o.astype(jnp.float32).reshape(b, sq, kvh, g, d)
    linv = 1.0 / jnp.maximum(l, 1e-30)                       # (b,sq,kvh,g)
    D = jnp.sum(go * of, axis=-1)                            # (b,sq,kvh,g)
    qpos = (jnp.arange(sq, dtype=jnp.int32) + q_offset)[:, None]

    kc = k.reshape(b, nkc, chunk_, kvh, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nkc, chunk_, kvh, d).transpose(1, 0, 2, 3, 4)

    def body(dq_acc, xs):
        kb, vb, ci = xs                                      # (b,c,kv,d)
        kpos = ci * chunk_ + jnp.arange(chunk_, dtype=jnp.int32)[None, :]
        s = jnp.einsum("bqkgd,bckd->bqkgc", qf * scale,
                       kb.astype(jnp.float32))
        if softcap:
            sc = jnp.tanh(s / softcap) * softcap
            dcap = 1.0 - jnp.square(sc / softcap)
        else:
            sc = s
            dcap = None
        mask = kpos < sk
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        maskb = mask[None, :, None, None, :]
        p = jnp.where(maskb, jnp.exp(sc - m[..., None]), 0.0) \
            * linv[..., None]                                # (b,q,kv,g,c)
        dv = jnp.einsum("bqkgc,bqkgd->bckd", p, go)
        dp = jnp.einsum("bqkgd,bckd->bqkgc", go, vb.astype(jnp.float32))
        ds = p * (dp - D[..., None])
        if softcap:
            ds = ds * dcap
        ds = ds * scale
        dq_acc = dq_acc + jnp.einsum("bqkgc,bckd->bqkgd", ds,
                                     kb.astype(jnp.float32))
        dk = jnp.einsum("bqkgc,bqkgd->bckd", ds, qf)
        return dq_acc, (dk, dv)

    dq0 = jnp.zeros((b, sq, kvh, g, d), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(
        body, dq0, (kc, vc, jnp.arange(nkc, dtype=jnp.int32)))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(b, sk + pad, kvh, d)[:, :sk]
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(b, sk + pad, kvh, d)[:, :sk]
    return (dq.reshape(b, sq, h, d).astype(q.dtype),
            dk.astype(k.dtype), dv.astype(v.dtype))


_flash_vjp.defvjp(_flash_fwd, _flash_bwd)


def _flash_chunked_jnp(q, k, v, *, causal, window, softcap, q_offset, chunk,
                       return_stats=False):
    """Online-softmax over KV chunks (lax.scan). Flash memory profile in HLO."""
    b, sq, h, d = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    chunk = min(chunk, sk)
    pad = (-sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nkc = (sk + pad) // chunk

    qf = q.astype(jnp.float32) * (d ** -0.5)
    qg = qf.reshape(b, sq, kvh, g, d)
    qpos = (jnp.arange(sq, dtype=jnp.int32) + q_offset)[:, None]    # (sq,1)

    kc = k.reshape(b, nkc, chunk, kvh, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nkc, chunk, kvh, d).transpose(1, 0, 2, 3, 4)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, ci = xs
        kpos = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)[None, :]
        s = jnp.einsum("bqkgd,bckd->bqkgc", qg, kb.astype(jnp.float32))
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        mask = kpos < sk
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask[None, :, None, None, :], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, kvh, g), -1e30, jnp.float32)
    l0 = jnp.zeros((b, sq, kvh, g), jnp.float32)
    a0 = jnp.zeros((b, sq, kvh, g, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, jnp.arange(nkc, dtype=jnp.int32)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.reshape(b, sq, h, d).astype(q.dtype)
    if return_stats:
        return out, m, l
    return out


# ---------------------------------------------------------------------------
# RG-LRU linear recurrence


def rg_lru(a, gx, h0=None):
    """h_t = a_t * h_{t-1} + gx_t. a/gx: (B,S,D) -> (h, h_last)."""
    if _on_tpu() or _force_interpret():
        from repro.kernels.rg_lru import rg_lru_pallas
        return rg_lru_pallas(a, gx, h0, interpret=not _on_tpu())
    return _rg_lru_assoc(a, gx, h0)


def _rg_lru_assoc(a, gx, h0=None):
    """O(log S) associative scan — the CPU/compile path."""
    af = a.astype(jnp.float32)
    gf = gx.astype(jnp.float32)
    if h0 is not None:
        # fold h0 into the first element: h_1 = a_1 * h0 + gx_1
        gf = gf.at[:, 0].add(af[:, 0] * h0.astype(jnp.float32))

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, b1 * a2 + b2

    aa, hh = jax.lax.associative_scan(combine, (af, gf), axis=1)
    return hh.astype(a.dtype), hh[:, -1].astype(a.dtype)


# ---------------------------------------------------------------------------
# mLSTM


def mlstm(q, k, v, log_f, log_i, state=None, chunk=128):
    """Chunkwise mLSTM. state: optional (C, n, m) carry (decode path)."""
    if state is None and (_on_tpu() or _force_interpret()):
        from repro.kernels.mlstm import mlstm_pallas
        return mlstm_pallas(q, k, v, log_f, log_i, interpret=not _on_tpu())
    s = q.shape[1]
    if s > 1 and s % min(chunk, s) == 0:
        return _mlstm_chunked_jnp(q, k, v, log_f, log_i, state,
                                  chunk=min(chunk, s))
    if state is None:
        return ref.mlstm(q, k, v, log_f, log_i)
    return ref.mlstm(q, k, v, log_f, log_i, *state)


def _mlstm_chunked_jnp(q, k, v, log_f, log_i, state=None, chunk=128):
    """Chunkwise-parallel mLSTM (same math as the Pallas kernel): within a
    chunk the in-chunk contribution is a masked attention-like matmul; the
    (d x d) state carries across chunks via lax.scan. Replaces the O(S)
    per-timestep scan (whose HBM traffic is S x state bytes) with S/chunk
    steps of MXU-friendly matmuls — this is also what makes the dry-run's
    memory roofline reflect the kernel's behaviour."""
    b, s, h, d = q.shape
    nc = s // chunk
    scale = d ** -0.5
    qf = q.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(b, h, nc, chunk, d)
    kf = k.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(b, h, nc, chunk, d) * scale
    vf = v.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(b, h, nc, chunk, d)
    lf = log_f.astype(jnp.float32).transpose(0, 2, 1).reshape(b, h, nc, chunk)
    li = log_i.astype(jnp.float32).transpose(0, 2, 1).reshape(b, h, nc, chunk)

    if state is None:
        C0 = jnp.zeros((b, h, d, d), jnp.float32)
        n0 = jnp.zeros((b, h, d), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)
    else:
        C0, n0, m0 = (x.astype(jnp.float32) for x in state)

    t_idx = jnp.arange(chunk)
    causal = t_idx[:, None] >= t_idx[None, :]

    def step(carry, xs):
        C, n, m = carry                                  # (b,h,d,d),(b,h,d),(b,h)
        qc, kc, vc, lfc, lic = xs                        # (b,h,chunk,...)
        F = jnp.cumsum(lfc, axis=-1)                     # (b,h,c)
        src = lic - F
        run_src = jax.lax.cummax(src, axis=src.ndim - 1)
        m_t = F + jnp.maximum(m[..., None], run_src)     # (b,h,c)

        d_mat = F[..., :, None] + src[..., None, :] - m_t[..., :, None]
        d_mat = jnp.where(causal, d_mat, -1e30)
        w = jnp.exp(d_mat)                               # (b,h,c,c)
        sc = jnp.einsum("bhtd,bhud->bhtu", qc, kc)
        ws = w * sc
        intra_num = jnp.einsum("bhtu,bhud->bhtd", ws, vc)
        intra_den = jnp.sum(ws, axis=-1)                 # (b,h,c)

        carry_coeff = jnp.exp(F + m[..., None] - m_t)    # (b,h,c)
        inter_num = jnp.einsum("bhtd,bhdk->bhtk", qc, C)
        inter_den = jnp.einsum("bhtd,bhd->bht", qc, n)
        num = inter_num * carry_coeff[..., None] + intra_num
        den = inter_den * carry_coeff + intra_den
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
        hout = num / den[..., None]                      # (b,h,c,d)

        m_last = m_t[..., -1]
        f_all = F[..., -1]
        state_coeff = jnp.exp(f_all + m - m_last)
        src_coeff = jnp.exp(f_all[..., None] + src - m_last[..., None])
        kc_s = kc * src_coeff[..., None]
        C_new = C * state_coeff[..., None, None] \
            + jnp.einsum("bhud,bhuk->bhdk", kc_s, vc)
        n_new = n * state_coeff[..., None] + jnp.sum(kc_s, axis=-2)
        return (C_new, n_new, m_last), hout

    xs = tuple(a.transpose(2, 0, 1, 3, 4) if a.ndim == 5
               else a.transpose(2, 0, 1, 3)
               for a in (qf, kf, vf, lf, li))
    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), xs)
    hs = hs.transpose(1, 2, 0, 3, 4).reshape(b, h, s, d).transpose(0, 2, 1, 3)
    return hs.astype(q.dtype), (C.astype(q.dtype), n.astype(q.dtype), m)


# ---------------------------------------------------------------------------
# checkpoint quantization


def quantize_blockwise(x, *, block=2048):
    if _on_tpu() or _force_interpret():
        from repro.kernels.quantize import quantize_blockwise_pallas
        return quantize_blockwise_pallas(x, block=block,
                                         interpret=not _on_tpu())
    return ref.quantize_blockwise(x, block)


def dequantize_blockwise(q, scale, *, block=2048, out_dtype=jnp.float32):
    if _on_tpu() or _force_interpret():
        from repro.kernels.quantize import dequantize_blockwise_pallas
        return dequantize_blockwise_pallas(q, scale, block=block,
                                           out_dtype=out_dtype,
                                           interpret=not _on_tpu())
    return ref.dequantize_blockwise(q, scale, block).astype(out_dtype)
