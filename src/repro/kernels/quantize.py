"""Blockwise symmetric int8 quantization as a Pallas TPU kernel.

Used by the burst-buffer checkpoint path: checkpoint shards are quantized
*on device* (bf16 -> int8 + f32 scale per 2048-element block) before the
HBM->host DMA, halving the bytes that cross the host link and the burst
buffer's ingress volume. Pure VPU work; tiles are (rows x 2048) so the
reduction (max|x|) runs along lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                    # (rows, block)
    scale = jnp.max(jnp.abs(x), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale[:, 0]


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = (q_ref[...].astype(jnp.float32)
                  * s_ref[...][:, None]).astype(x_ref.dtype)


def quantize_blockwise_pallas(x, *, block=2048, rows_per_tile=64,
                              interpret=False):
    """x: flat (N,), N % block == 0 -> (q int8 (N,), scales f32 (N/block,))."""
    n = x.shape[0]
    assert n % block == 0, (n, block)
    nb = n // block
    rows = min(rows_per_tile, nb)
    while nb % rows:
        rows -= 1
    xb = x.reshape(nb, block)
    grid = (nb // rows,)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((rows, block), lambda i: (i, 0)),
                   pl.BlockSpec((rows,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((nb, block), jnp.int8),
                   jax.ShapeDtypeStruct((nb,), jnp.float32)],
        interpret=interpret,
    )(xb)
    return q.reshape(n), s


def dequantize_blockwise_pallas(q, scale, *, block=2048, rows_per_tile=64,
                                out_dtype=jnp.float32, interpret=False):
    n = q.shape[0]
    nb = n // block
    rows = min(rows_per_tile, nb)
    while nb % rows:
        rows -= 1
    grid = (nb // rows,)
    x = pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, block), lambda i: (i, 0)),
                  pl.BlockSpec((rows,), lambda i: (i,))],
        out_specs=pl.BlockSpec((rows, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), out_dtype),
        interpret=interpret,
    )(q.reshape(nb, block), scale)
    return x.reshape(n)
