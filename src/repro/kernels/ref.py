"""Pure-jnp oracles for every Pallas kernel. Small, obviously-correct, f32."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    q_offset=0):
    """Naive full-matrix attention oracle.

    q: (B, Sq, H, D); k/v: (B, Sk, KV, D). GQA via kv-head repetition.
    ``q_offset``: absolute position of q[0] relative to k[0] (prefill=0).
    """
    b, sq, h, d = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    if kvh != h:
        rep = h // kvh
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.astype(jnp.float32) * (d ** -0.5)
    s = jnp.einsum("bqhd,bkhd->bhqk", qf, k.astype(jnp.float32))
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32))
    return o.astype(q.dtype)


def rg_lru(a, gx, h0=None):
    """Linear recurrence h_t = a_t * h_{t-1} + gx_t.

    a, gx: (B, S, D) (already gated/scaled inputs); h0: (B, D) or None.
    Returns (h_seq (B,S,D), h_last (B,D)). f32 scan oracle.
    """
    af = a.astype(jnp.float32)
    gf = gx.astype(jnp.float32)
    b, s, d = a.shape
    init = jnp.zeros((b, d), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, t):
        at, gt = t
        h = at * h + gt
        return h, h

    h_last, hs = jax.lax.scan(step, init, (af.swapaxes(0, 1), gf.swapaxes(0, 1)))
    return hs.swapaxes(0, 1).astype(a.dtype), h_last.astype(a.dtype)


def mlstm(q, k, v, log_f, log_i, c0=None, n0=None, m0=None):
    """mLSTM (xLSTM matrix memory) sequential oracle, log-space stabilized.

    q/k/v: (B, S, H, D); log_f/log_i: (B, S, H) log forget/input gates.
    C: (B,H,D,D) matrix state; n: (B,H,D) normalizer; m: (B,H) stabilizer.
    h_t = (C_t q_t) / max(|n_t . q_t|, exp(-m_t))   [xLSTM eq. 19-27]
    Returns (h (B,S,H,D), (C,n,m) final).
    """
    b, s, h, d = q.shape
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    lf = log_f.astype(jnp.float32)
    li = log_i.astype(jnp.float32)
    scale = d ** -0.5
    C = jnp.zeros((b, h, d, d), jnp.float32) if c0 is None else c0.astype(jnp.float32)
    n = jnp.zeros((b, h, d), jnp.float32) if n0 is None else n0.astype(jnp.float32)
    m = jnp.full((b, h), -1e30, jnp.float32) if m0 is None else m0.astype(jnp.float32)

    def step(carry, t):
        C, n, m = carry
        qt, kt, vt, lft, lit = t                     # (B,H,D)... (B,H)
        m_new = jnp.maximum(lft + m, lit)
        fg = jnp.exp(lft + m - m_new)[..., None]     # (B,H,1)
        ig = jnp.exp(lit - m_new)[..., None]
        kt = kt * scale
        C = fg[..., None] * C + ig[..., None] * (kt[..., :, None] * vt[..., None, :])
        n = fg * n + ig * kt
        num = jnp.einsum("bhdk,bhd->bhk", C, qt)
        den = jnp.abs(jnp.einsum("bhd,bhd->bh", n, qt))
        den = jnp.maximum(den, jnp.exp(-m_new))[..., None]
        return (C, n, m_new), num / den

    xs = (qf.transpose(1, 0, 2, 3), kf.transpose(1, 0, 2, 3),
          vf.transpose(1, 0, 2, 3), lf.transpose(1, 0, 2), li.transpose(1, 0, 2))
    (C, n, m), hs = jax.lax.scan(step, (C, n, m), xs)
    return hs.transpose(1, 0, 2, 3).astype(q.dtype), (C.astype(q.dtype),
                                                      n.astype(q.dtype),
                                                      m.astype(jnp.float32))


def quantize_blockwise(x, block: int = 2048):
    """Blockwise symmetric int8 quantization. x: flat (N,) with N % block == 0.

    Returns (q int8 (N,), scales f32 (N/block,)).
    """
    n = x.shape[0]
    xb = x.astype(jnp.float32).reshape(n // block, block)
    scale = jnp.max(jnp.abs(xb), axis=1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(n), scale


def dequantize_blockwise(q, scale, block: int = 2048):
    n = q.shape[0]
    xb = q.astype(jnp.float32).reshape(n // block, block) * scale[:, None]
    return xb.reshape(n)
