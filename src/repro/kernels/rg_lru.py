"""RG-LRU linear recurrence as a Pallas TPU kernel (RecurrentGemma/Griffin).

h_t = a_t * h_{t-1} + gx_t, elementwise over the channel dim. The gates
(a_t, gx_t) are computed outside (einsum-friendly); the kernel fuses the
sequential scan so the carry never leaves VMEM.

Grid: (batch, d_blocks, s_blocks) — the trailing seq dimension runs
sequentially on TPU, so the (1, block_d) carry persists in VMEM scratch
across seq blocks. Inside a block the time loop is a fori_loop over rows
already resident in VMEM: pure VPU work, one HBM read per input element and
one write per output element (memory-bound optimal).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rg_lru_kernel(a_ref, gx_ref, h0_ref, h_ref, hlast_ref, carry, *,
                   block_s, seq_len):
    si = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(si == 0)
    def _init():
        carry[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)        # (block_s, block_d)
    gx = gx_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + gx[t]
        h_ref[0, t] = h.astype(h_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_s, step, carry[0])
    carry[0] = h

    @pl.when(si == ns - 1)
    def _final():
        hlast_ref[0] = h.astype(hlast_ref.dtype)


def rg_lru_pallas(a, gx, h0=None, *, block_s=256, block_d=128,
                  interpret=False):
    """a, gx: (B, S, D); h0: (B, D) or None -> (h (B,S,D), h_last (B,D))."""
    b, s, d = a.shape
    if h0 is None:
        h0 = jnp.zeros((b, d), a.dtype)
    block_s = min(block_s, s)
    block_d = min(block_d, d)
    assert s % block_s == 0 and d % block_d == 0, (s, d, block_s, block_d)

    grid = (b, d // block_d, s // block_s)
    kernel = functools.partial(_rg_lru_kernel, block_s=block_s, seq_len=s)

    h, hlast = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, block_d), lambda b_, di, si: (b_, si, di)),
            pl.BlockSpec((1, block_s, block_d), lambda b_, di, si: (b_, si, di)),
            pl.BlockSpec((1, block_d), lambda b_, di, si: (b_, di)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_s, block_d), lambda b_, di, si: (b_, si, di)),
            pl.BlockSpec((1, block_d), lambda b_, di, si: (b_, di)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, d), a.dtype),
            jax.ShapeDtypeStruct((b, d), a.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((1, block_d), jnp.float32)],
        interpret=interpret,
    )(a, gx, h0)
    return h, hlast
