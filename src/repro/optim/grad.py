"""Gradient utilities: global-norm clipping and int8 compression with error
feedback (a cross-pod DCN bandwidth optimization — beyond-paper trick,
applied to the *gradient* traffic the same way the burst buffer's int8
kernel is applied to *checkpoint* traffic)."""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale
                                   ).astype(x.dtype), tree), norm


def compress_int8(tree):
    """Per-leaf symmetric int8 quantization. Returns (q_tree, scale_tree)."""
    def q(x):
        xf = x.astype(jnp.float32)
        s = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
        return jnp.clip(jnp.round(xf / s), -127, 127).astype(jnp.int8), s
    qs = jax.tree.map(q, tree)
    pick = lambda i: jax.tree.map(lambda t: t[i], qs,
                                  is_leaf=lambda t: isinstance(t, tuple))
    return pick(0), pick(1)


def decompress_int8(q_tree, scale_tree, dtype=jnp.float32):
    return jax.tree.map(lambda q, s: (q.astype(jnp.float32) * s).astype(dtype),
                        q_tree, scale_tree)


def compress_error_feedback(tree, residual):
    """int8 compress (tree + residual); returns (q, scales, new_residual)."""
    biased = jax.tree.map(
        lambda g, r: g.astype(jnp.float32) + r.astype(jnp.float32),
        tree, residual)
    q, s = compress_int8(biased)
    recon = decompress_int8(q, s)
    new_res = jax.tree.map(lambda b, r: b - r, biased, recon)
    return q, s, new_res
