"""Adafactor (factored second moments) — the XXL-config optimizer.

For a (n, m) matrix the second moment is stored as row/col vectors (n,)+(m,)
instead of (n, m): optimizer state for deepseek-v3-671b drops from ~5.4 TB
(Adam fp32) to ~2 GB + a bf16 momentum term if enabled. Factored dims are the
trailing two; rank-0/1 params fall back to unfactored.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdafactorState(NamedTuple):
    step: jax.Array
    vr: Any      # row second-moment (or full v for rank<2)
    vc: Any      # col second-moment (or None sentinel zeros(0,))
    m: Any       # optional momentum (zeros(0,) sentinel when disabled)


def _factored(p) -> bool:
    return p.ndim >= 2


@dataclasses.dataclass(frozen=True)
class Adafactor:
    lr: Callable[[jax.Array], jax.Array]
    decay: float = 0.8            # hat{beta2}_t = 1 - t^-decay
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    momentum: float = 0.0         # 0 disables the first moment
    momentum_dtype: str = "bfloat16"

    def init(self, params):
        def vr(p):
            return jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p) \
                else jnp.zeros(p.shape, jnp.float32)

        def vc(p):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32) \
                if _factored(p) else jnp.zeros((0,), jnp.float32)

        def m(p):
            return jnp.zeros(p.shape, jnp.dtype(self.momentum_dtype)) \
                if self.momentum else jnp.zeros((0,), jnp.float32)

        return AdafactorState(step=jnp.zeros((), jnp.int32),
                              vr=jax.tree.map(vr, params),
                              vc=jax.tree.map(vc, params),
                              m=jax.tree.map(m, params))

    # OPTIONAL layer-chunked update (lax.map over the stacked dim). Measured
    # on the deepseek-v3 dry-run: temp went UP 34.3 -> 45.9 GB/chip — the
    # mapped operands stay live alongside the scan buffers under XLA-CPU
    # buffer assignment, refuting the "full-leaf f32 temporaries dominate"
    # hypothesis (EXPERIMENTS.md It-7). Disabled by default; kept for
    # TPU-side re-evaluation where donation/aliasing differs.
    CHUNKED_UPDATE_MIN = 1 << 62

    def update(self, grads, state, params):
        step = state.step + 1
        t = step.astype(jnp.float32)
        beta2 = 1.0 - t ** (-self.decay)
        lr = self.lr(step)

        def upd(g, vr, vc, m, p):
            chunkable = (p.size >= self.CHUNKED_UPDATE_MIN and p.ndim >= 3
                         and p.shape[0] > 1
                         and vr.ndim and vr.shape[0] == p.shape[0]
                         and vc.ndim and vc.shape[0] == p.shape[0]
                         and (not self.momentum
                              or m.shape[0] == p.shape[0]))
            if chunkable:
                return jax.lax.map(
                    lambda args: _upd_one(*args), (g, vr, vc, m, p))
            return _upd_one(g, vr, vc, m, p)

        def _upd_one(g, vr, vc, m, p):
            gf = g.astype(jnp.float32)
            g2 = jnp.square(gf) + self.eps
            if _factored(p):
                vr_new = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc_new = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
                r = vr_new / jnp.maximum(
                    jnp.mean(vr_new, axis=-1, keepdims=True), self.eps)
                u = gf / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc_new)[..., None, :]
                          + self.eps)
            else:
                vr_new = beta2 * vr + (1 - beta2) * g2
                vc_new = vc
                u = gf / (jnp.sqrt(vr_new) + self.eps)
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms / self.clip_threshold)
            if self.momentum:
                m_new = self.momentum * m.astype(jnp.float32) \
                    + (1 - self.momentum) * u
                u = m_new
                m_out = m_new.astype(m.dtype)
            else:
                m_out = m
            if self.weight_decay and p.ndim >= 2:
                u = u + self.weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr * u
            return p_new.astype(p.dtype), vr_new, vc_new, m_out

        out = jax.tree.map(upd, grads, state.vr, state.vc, state.m, params)
        pick = lambda i: jax.tree.map(lambda tup: tup[i], out,
                                      is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), AdafactorState(step=step, vr=pick(1), vc=pick(2),
                                       m=pick(3))
