"""AdamW with dtype-configurable moments (pure JAX, optax-free).

State moments can be held in bf16 (XXL configs) — quantization error of the
moments is tolerated by Adam's normalization; this halves optimizer-state
HBM and checkpoint bytes (which the burst buffer then halves again with the
int8 kernel).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array]   # step -> lr
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: str = "float32"

    def init(self, params):
        dt = jnp.dtype(self.state_dtype)
        zeros = lambda p: jnp.zeros(p.shape, dt)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree.map(zeros, params),
                          v=jax.tree.map(zeros, params))

    def update(self, grads, state, params):
        step = state.step + 1
        lr = self.lr(step)
        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)
        dt = jnp.dtype(self.state_dtype)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
            mhat = m_new / c1
            vhat = v_new / c2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay and p.ndim >= 2:   # no decay on norms/bias
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr * delta
            return p_new.astype(p.dtype), m_new.astype(dt), v_new.astype(dt)

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        p_new = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        m_new = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        v_new = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return p_new, AdamWState(step=step, m=m_new, v=v_new)
