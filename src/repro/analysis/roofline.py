"""Three-term roofline analysis from dry-run compile artifacts.

Terms (seconds, per step, per chip — TPU v5e constants):
  compute    = flops_per_chip / PEAK_FLOPS          (197 TFLOP/s bf16)
  memory     = hbm_bytes_per_chip / HBM_BW          (819 GB/s)
  collective = ici_traffic_per_chip / LINK_BW       (~50 GB/s/link)

Sources: ``compiled.cost_analysis()`` reports per-chip flops and per-chip
"bytes accessed" (an upper-bound HBM-traffic proxy: XLA counts operand +
output bytes per op, so fusion-internal reuse is already excluded but
VMEM-resident reuse between ops is counted — we report it as-is and note the
bias). Collective traffic is parsed from the compiled HLO: every
all-gather/all-reduce/reduce-scatter/all-to-all/collective-permute op's
output shape, dtype and replica-group size, converted to per-chip link bytes
with ring-algorithm factors:
  all-gather (n-1)/n * out | reduce-scatter (n-1) * out (out is the shard)
  all-reduce 2(n-1)/n * size | all-to-all (n-1)/n * size | permute 1 * size
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?:\(.*?\)\s+)?(\w+)\[([\d,]*)\][^=]*?"
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", )
_TUPLE_COLL_RE = re.compile(
    r"=\s+\((.*?)\)\s+(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_SHAPE_IN_TUPLE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _participants(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    if "source_target_pairs" in line:
        return 2
    return 1


_FACTORS = {
    "all-gather": lambda n: (n - 1) / max(n, 1),
    "reduce-scatter": lambda n: (n - 1),
    "all-reduce": lambda n: 2 * (n - 1) / max(n, 1),
    "all-to-all": lambda n: (n - 1) / max(n, 1),
    "collective-permute": lambda n: 1.0,
}


def parse_collectives(hlo_text: str) -> List[dict]:
    """Extract collective ops with per-chip link-byte estimates."""
    out = []
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _COLL_RE.search(line)
        shapes: List[tuple] = []
        op = None
        if m:
            op = m.group(3)
            shapes = [(m.group(1), m.group(2))]
        else:
            mt = _TUPLE_COLL_RE.search(line)
            if mt:
                op = mt.group(2)
                shapes = _SHAPE_IN_TUPLE.findall(mt.group(1))
        if not op or not shapes:
            continue
        n = _participants(line)
        if n <= 1:
            continue
        bytes_out = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        link_bytes = bytes_out * _FACTORS[op](n)
        out.append({"op": op, "bytes": bytes_out, "participants": n,
                    "link_bytes": link_bytes})
    return out


def collective_summary(colls: List[dict]) -> dict:
    summary: Dict[str, dict] = {}
    for c in colls:
        s = summary.setdefault(c["op"], {"count": 0, "bytes": 0,
                                         "link_bytes": 0.0})
        s["count"] += 1
        s["bytes"] += c["bytes"]
        s["link_bytes"] += c["link_bytes"]
    return summary


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    hbm_bytes_per_chip: float
    link_bytes_per_chip: float
    model_flops: float                  # 6ND train / 2ND inference (total)
    params_bytes_per_chip: float = 0.0
    temp_bytes_per_chip: float = 0.0
    collectives: Optional[dict] = None

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.link_bytes_per_chip / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_chip * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline the *useful* model flops achieve
        at the step time implied by the dominant term (ideal overlap)."""
        t_step = max(self.t_compute, self.t_memory, self.t_collective)
        if t_step <= 0:
            return 0.0
        achieved = self.model_flops / self.chips / t_step
        return achieved / PEAK_FLOPS

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d


def model_flops_for(cfg, shape, n_active: int) -> float:
    """6·N·D for training, 2·N·D for inference forward passes."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch          # one new token per sequence
    return 2.0 * n_active * tokens
