"""Loop-aware cost analysis over compiled HLO text.

``compiled.cost_analysis()`` counts each while-loop body ONCE — with
scan-over-layers and microbatch accumulation that undercounts flops, HBM
bytes and collectives by orders of magnitude. This analyzer parses the HLO
module, builds per-computation symbol tables (op -> output shape), resolves
the call graph (while condition/body, fusion calls, to_apply), extracts loop
trip counts from while-condition integer constants, and accumulates per-op
costs scaled by the product of enclosing trip counts.

Costs (all PER CHIP — the HLO is the per-device SPMD program):
  flops      — dot/conv: 2 * prod(out) * prod(lhs contracting dims);
               1/elem for arithmetic + transcendental ops; reduce: in-elems.
  hbm_bytes  — per post-fusion op: operand + output bytes (bookkeeping ops
               and fusion internals excluded — they stay in VMEM/registers).
  link_bytes — collectives with ring-algorithm factors (see roofline.py).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.analysis.roofline import (_DTYPE_BYTES, _FACTORS, _GROUPS_LIST_RE,
                                     _GROUPS_RE)

_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->.*{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\]\{\},\s]+?))\s+"
    r"([\w\-]+)\((.*?)\)(.*)$")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_REF_RE = re.compile(r"%([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:to_apply|calls)=%?([\w\.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_ELEMWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "tanh", "log", "rsqrt", "sqrt", "power", "negate",
    "select", "compare", "and", "or", "xor", "abs", "floor", "ceil",
    "sign", "cosine", "sine", "logistic", "atan2", "round-nearest-even",
    "clamp", "remainder", "exponential-minus-one", "log-plus-one",
}
_SKIP_BYTES = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "copy-start",
    "copy-done", "while", "fusion", "call", "conditional",
    "opt-barrier", "domain",
}
_COLL_BASE = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
              "collective-permute"}


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _text_bytes(text: str) -> int:
    return sum(_shape_elems(dims) * _DTYPE_BYTES.get(dt, 4)
               for dt, dims in _SHAPE_RE.findall(text))


@dataclass
class Op:
    name: str
    opcode: str
    out_text: str
    operands: str
    attrs: str


@dataclass
class Computation:
    name: str
    is_entry: bool
    ops: List[Op] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)   # op name -> type text


def parse_module(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    for line in hlo.splitlines():
        if current is None:
            m = _COMP_HEADER.match(line.strip())
            if m:
                current = Computation(m.group(2), bool(m.group(1)))
            continue
        stripped = line.strip()
        if stripped.startswith("}"):
            comps[current.name] = current
            current = None
            continue
        m = _OP_RE.match(line)
        if m:
            op = Op(m.group(1), m.group(3), m.group(2).strip(),
                    m.group(4), m.group(5))
            current.ops.append(op)
            current.shapes[op.name] = op.out_text
    return comps


_FUSION_CHARGED = {
    "dot", "convolution", "gather", "scatter", "dynamic-slice",
    "dynamic-update-slice", "reduce", "reduce-window", "sort", "copy",
    "concatenate", "pad", "slice", "transpose", "rng", "cholesky",
    "triangular-solve", "fft",
}


@dataclass
class Stats:
    flops: float = 0.0
    hbm_bytes: float = 0.0        # raw: every post-fusion op's operands+out
    hbm_fused: float = 0.0        # TPU-optimistic: elementwise assumed fused
    link_bytes: float = 0.0
    coll_detail: Dict[str, dict] = field(default_factory=dict)

    def add(self, other: "Stats", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.hbm_fused += other.hbm_fused * mult
        self.link_bytes += other.link_bytes * mult
        for k, v in other.coll_detail.items():
            d = self.coll_detail.setdefault(
                k, {"count": 0.0, "bytes": 0.0, "link_bytes": 0.0})
            for kk in d:
                d[kk] += v[kk] * mult

    def as_dict(self) -> dict:
        return {"flops": self.flops, "hbm_bytes": self.hbm_bytes,
                "hbm_fused": self.hbm_fused, "link_bytes": self.link_bytes,
                "collectives": {k: dict(v)
                                for k, v in self.coll_detail.items()}}


class Analyzer:
    def __init__(self, hlo: str):
        self.comps = parse_module(hlo)
        entries = [n for n, c in self.comps.items() if c.is_entry]
        self.entry = entries[0] if entries else next(iter(self.comps))
        self._memo: Dict[str, Stats] = {}

    # ------------------------------------------------------------- helpers
    def _operand_bytes(self, comp: Computation, op: Op) -> int:
        total = 0
        for ref in _REF_RE.findall(op.operands):
            total += _text_bytes(comp.shapes.get(ref, ""))
        return total

    def _operand_shape(self, comp: Computation, op: Op, idx: int) -> str:
        refs = _REF_RE.findall(op.operands)
        if idx < len(refs):
            return comp.shapes.get(refs[idx], "")
        return ""

    def _trip_count(self, cond_name: str) -> int:
        """Loop bound = the integer constant feeding the condition's compare
        (directly or through the wrapped-compare fusion). Falling back to the
        max constant would over-count when index-clamp constants (e.g.
        ``min(i, S-1)``) appear in the condition."""
        cond = self.comps.get(cond_name)
        if cond is None:
            return 1

        def const_val(comp, ref):
            op = next((o for o in comp.ops if o.name == ref), None)
            if op is not None and op.opcode == "constant":
                try:
                    return int(op.operands.strip())
                except ValueError:
                    return None
            return None

        # 1) direct compare in the condition
        for op in cond.ops:
            refs = _REF_RE.findall(op.operands)
            if op.opcode == "compare":
                for r in refs:
                    v = const_val(cond, r)
                    if v is not None:
                        return max(v, 1)
            if op.opcode == "fusion" and op.out_text.startswith("pred"):
                # operands of the wrapped-compare fusion
                for r in refs:
                    v = const_val(cond, r)
                    if v is not None:
                        return max(v, 1)
        # 2) fallback: max integer constant
        best = 1
        for op in cond.ops:
            if op.opcode == "constant" and op.out_text.startswith(
                    ("s32", "u32", "s64")):
                try:
                    best = max(best, int(op.operands.strip()))
                except ValueError:
                    pass
        return best

    def _dot_flops(self, comp: Computation, op: Op) -> float:
        out_elems = _shape_elems(_SHAPE_RE.search(op.out_text).group(2)) \
            if _SHAPE_RE.search(op.out_text) else 0
        m = _CONTRACT.search(op.attrs)
        contract = 1
        if m:
            lhs = self._operand_shape(comp, op, 0)
            sm = _SHAPE_RE.search(lhs)
            if sm:
                dims = [int(d) for d in sm.group(2).split(",") if d]
                for ci in m.group(1).split(","):
                    if ci and int(ci) < len(dims):
                        contract *= dims[int(ci)]
        return 2.0 * out_elems * contract

    def _participants(self, op: Op) -> int:
        text = op.operands + op.attrs
        m = _GROUPS_RE.search(text)
        if m:
            return int(m.group(2))
        m = _GROUPS_LIST_RE.search(text)
        if m:
            return len(m.group(1).split(","))
        if "source_target_pairs" in text:
            return 2
        return 1

    # --------------------------------------------------------------- main
    def stats(self, comp_name: Optional[str] = None,
              in_fusion: bool = False) -> Stats:
        name = comp_name or self.entry
        key = f"{name}|{in_fusion}"
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(name)
        total = Stats()
        self._memo[key] = total
        if comp is None:
            return total
        for op in comp.ops:
            oc = op.opcode
            if oc == "while":
                cond = _COND_RE.search(op.attrs)
                body = _BODY_RE.search(op.attrs)
                trip = self._trip_count(cond.group(1)) if cond else 1
                if body and body.group(1) in self.comps:
                    total.add(self.stats(body.group(1)), trip)
                continue
            if oc == "fusion":
                if not in_fusion:
                    b = self._fusion_bytes(comp, op)
                    total.hbm_bytes += b
                    total.hbm_fused += b
                for c in _CALLS_RE.findall(op.attrs):
                    sub = self.stats(c, in_fusion=True)
                    total.flops += sub.flops
                    total.link_bytes += sub.link_bytes
                continue
            if oc in ("call", "conditional", "map", "sort", "scatter",
                      "reduce", "reduce-window", "select-and-scatter",
                      "custom-call"):
                for c in _CALLS_RE.findall(op.attrs):
                    sub = self.stats(c, in_fusion=True)
                    # applied computations are per-element; their cost is
                    # folded into the reduce charge below, except real calls
                    if oc in ("call", "conditional", "custom-call"):
                        total.add(sub)

            # --- per-op costs ---
            if oc in ("dot", "convolution"):
                total.flops += self._dot_flops(comp, op)
            elif oc in _ELEMWISE:
                m = _SHAPE_RE.search(op.out_text)
                if m:
                    total.flops += _shape_elems(m.group(2))
            elif oc in ("reduce", "reduce-window"):
                total.flops += self._operand_bytes(comp, op) // 4 or \
                    _shape_elems(_SHAPE_RE.search(op.out_text).group(2))

            base = oc.replace("-start", "")
            if base in _COLL_BASE and not oc.endswith("-done"):
                n = self._participants(op)
                if n > 1:
                    b = _text_bytes(op.out_text)
                    lb = b * _FACTORS[base](n)
                    total.link_bytes += lb
                    d = total.coll_detail.setdefault(
                        base, {"count": 0.0, "bytes": 0.0, "link_bytes": 0.0})
                    d["count"] += 1
                    d["bytes"] += b
                    d["link_bytes"] += lb

            if not in_fusion and oc not in _SKIP_BYTES \
                    and base not in _COLL_BASE:
                if oc == "dynamic-slice":
                    b = 2 * _text_bytes(op.out_text)     # read slice + write
                elif oc == "dynamic-update-slice":
                    upd = self._operand_shape(comp, op, 1)
                    b = 2 * _text_bytes(upd)             # in-place update
                else:
                    b = _text_bytes(op.out_text) \
                        + self._operand_bytes(comp, op)
                total.hbm_bytes += b
                if oc in _FUSION_CHARGED:
                    total.hbm_fused += b
        return total

    def _fusion_bytes(self, comp: Computation, op: Op) -> int:
        """External traffic of a fusion op, accounting for sliced access:
        - an operand consumed ONLY by dynamic-slice/gather inside the fused
          computation is charged at the slice size (scan xs / stacked-param
          reads), not the full array;
        - a root dynamic-update-slice writing into a param-aliased buffer is
          charged at the update size (scan ys writes are in-place)."""
        called = _CALLS_RE.findall(op.attrs)
        sub = self.comps.get(called[0]) if called else None
        out_b = _text_bytes(op.out_text)
        refs = _REF_RE.findall(op.operands)
        if sub is None:
            return out_b + self._operand_bytes(comp, op)

        param_name = {}
        for o in sub.ops:
            if o.opcode == "parameter":
                try:
                    param_name[int(o.operands.strip())] = o.name
                except ValueError:
                    pass

        aliased_buf = None
        root = sub.ops[-1] if sub.ops else None
        if root is not None and root.opcode == "dynamic-update-slice":
            rrefs = _REF_RE.findall(root.operands)
            if len(rrefs) >= 2:
                upd_b = _text_bytes(sub.shapes.get(rrefs[1], ""))
                if upd_b:
                    out_b = upd_b
                aliased_buf = rrefs[0]

        total = out_b
        for i, ref in enumerate(refs):
            full = _text_bytes(comp.shapes.get(ref, ""))
            pname = param_name.get(i)
            if pname is None:
                total += full
                continue
            if pname == aliased_buf:
                continue                      # in-place scan buffer
            consumers = [o for o in sub.ops
                         if pname in _REF_RE.findall(o.operands)]
            if consumers and all(o.opcode in ("dynamic-slice", "gather")
                                 for o in consumers):
                total += sum(_text_bytes(o.out_text) for o in consumers)
            else:
                total += full
        return total


def analyze(hlo: str) -> Stats:
    return Analyzer(hlo).stats()
