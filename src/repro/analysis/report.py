"""Generate the EXPERIMENTS.md roofline/dry-run tables from artifacts."""
from __future__ import annotations

import glob
import json
import os
from collections import defaultdict


def load(out_dir="results/dryrun"):
    rows = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        rows.append(json.load(open(f)))
    return rows


def md_roofline_table(rows, mesh_prefix="pod_"):
    ok = [r for r in rows if r.get("status") == "ok"
          and r["mesh"].startswith(mesh_prefix)]
    lines = [
        "| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) "
        "| bottleneck | MODEL/HLO flops | roofline frac | temp GB/chip | "
        "1-sentence lever |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"])):
        lever = _lever(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.2f} | "
            f"{r['t_memory']:.2f} | {r['t_collective']:.2f} | "
            f"{r['bottleneck']} | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.4f} | "
            f"{r['memory_analysis']['temp_size_in_bytes'] / 1e9:.1f} | "
            f"{lever} |")
    return "\n".join(lines)


def _lever(r) -> str:
    b = r["bottleneck"]
    if b == "collective":
        top = max((r.get("collectives") or {}).items(),
                  key=lambda kv: kv[1]["link_bytes"], default=(None, None))[0]
        return (f"cut {top} traffic (overlap with compute / coarser "
                f"grain / different sharding axis)")
    if b == "memory":
        if r["shape"].startswith("decode") or r["shape"] == "long_500k":
            return "decode is cache-read bound: quantize KV cache / batch up"
        return ("reduce activation traffic: larger fused blocks, fp8/bf16 "
                "intermediates, less remat recompute")
    return "increase per-chip arithmetic intensity (bigger microbatch)"


def md_skip_table(rows):
    sk = [r for r in rows if r.get("status") == "skipped"
          and "multipod" not in r["mesh"]]
    lines = ["| arch | shape | reason |", "|---|---|---|"]
    for r in sorted(sk, key=lambda r: (r["arch"], r["shape"])):
        lines.append(f"| {r['arch']} | {r['shape']} | {r['reason'][:90]} |")
    return "\n".join(lines)


def md_multipod_delta(rows):
    by = defaultdict(dict)
    for r in rows:
        if r.get("status") == "ok":
            key = "multipod" if "multipod" in r["mesh"] else "pod"
            by[(r["arch"], r["shape"])][key] = r
    lines = [
        "| arch | shape | pod t_coll (s) | multipod t_coll (s) | "
        "pod temp GB | multipod temp GB |",
        "|---|---|---|---|---|---|",
    ]
    for (a, s), d in sorted(by.items()):
        if "pod" in d and "multipod" in d:
            p, m = d["pod"], d["multipod"]
            lines.append(
                f"| {a} | {s} | {p['t_collective']:.2f} | "
                f"{m['t_collective']:.2f} | "
                f"{p['memory_analysis']['temp_size_in_bytes']/1e9:.1f} | "
                f"{m['memory_analysis']['temp_size_in_bytes']/1e9:.1f} |")
    return "\n".join(lines)


def compare(dir_a, dir_b, shape="train_4k", mesh_prefix="pod_"):
    """Per-arch before/after across two artifact dirs."""
    def idx(d):
        return {(r["arch"], r["shape"]): r for r in load(d)
                if r.get("status") == "ok" and r["mesh"].startswith(mesh_prefix)}
    A, B = idx(dir_a), idx(dir_b)
    out = []
    for key in sorted(B):
        if key in A and key[1] == shape:
            a, b = A[key], B[key]
            out.append((key[0],
                        a["memory_analysis"]["temp_size_in_bytes"] / 1e9,
                        b["memory_analysis"]["temp_size_in_bytes"] / 1e9,
                        a["t_memory"], b["t_memory"],
                        a["t_collective"], b["t_collective"]))
    return out
