"""End-to-end training driver with burst-buffer checkpointing.

Wires together: config -> model -> optimizer -> sharded train step ->
synthetic data pipeline -> BBCheckpointManager (async save/flush) ->
failure handling (restore from BB replicas on simulated node loss).

Usage (CPU-scale):
  PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b \
      --reduced --steps 50 --batch 8 --seq 64 --ckpt-every 10
On a real pod, drop --reduced and point --mesh at the production mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.bbckpt import BBCheckpointManager
from repro.configs.base import get_config, reduced
from repro.core import BBConfig, BurstBufferSystem
from repro.data.pipeline import SyntheticLMPipeline
from repro.models.registry import build_model
from repro.runtime.train_step import (TrainState, init_train_state,
                                      make_optimizer, make_train_step)


def build(cfg, *, accum=1, peak_lr=3e-4, seed=0):
    model = build_model(cfg)
    optimizer = make_optimizer(cfg, peak_lr=peak_lr)
    state = init_train_state(cfg, model, optimizer, jax.random.PRNGKey(seed))
    step_fn = jax.jit(make_train_step(cfg, model, optimizer,
                                      accum_steps=accum))
    return model, optimizer, state, step_fn


def train_loop(cfg, *, steps, global_batch, seq_len, ckpt_every,
               bb_system=None, quantize_ckpt=True, accum=1, log_every=10,
               restore=False):
    model, optimizer, state, step_fn = build(cfg, accum=accum)
    pipe = SyntheticLMPipeline(
        vocab_size=cfg.vocab_size, seq_len=seq_len, global_batch=global_batch,
        enc_seq=cfg.encoder_seq, enc_dim=cfg.encoder_dim).start_prefetch()

    own_bb = bb_system is None
    bb = bb_system or BurstBufferSystem(BBConfig(
        num_servers=4, num_clients=4, dram_capacity=256 << 20)).start()
    mgr = BBCheckpointManager(bb, quantize=quantize_ckpt)

    start_step = 0
    if restore:
        target = {"params": state.params, "opt_state": state.opt_state,
                  "data": {"step": jnp.zeros((), jnp.int32)}}
        try:
            restored, ck_step = mgr.restore(target)
            state = TrainState(restored["params"], restored["opt_state"])
            pipe.load_state_dict({**pipe.state_dict(),
                                  "step": int(restored["data"]["step"])})
            start_step = ck_step + 1
            print(f"[train] restored from step {ck_step}")
        except FileNotFoundError:
            pass

    history = []
    t_last = time.perf_counter()
    for step in range(start_step, steps):
        batch = next(pipe)
        state, metrics = step_fn(state, batch)
        if ckpt_every and step and step % ckpt_every == 0:
            ckpt = {"params": state.params, "opt_state": state.opt_state,
                    "data": {"step": jnp.asarray(pipe.step, jnp.int32)}}
            ingest = mgr.save(step, ckpt)
            print(f"[ckpt] step {step}: ingest {ingest*1e3:.1f} ms "
                  f"({mgr.metrics[step]['bytes']/1e6:.1f} MB), "
                  f"flush async")
        if step % log_every == 0:
            dt = time.perf_counter() - t_last
            t_last = time.perf_counter()
            loss = float(metrics["loss"])
            history.append((step, loss))
            print(f"[train] step {step} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} ({dt:.2f}s)")
    mgr.wait_flushes()
    pipe.stop_prefetch()
    if own_bb:
        bb.stop()
    return state, history, mgr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--no-quant", action="store_true")
    ap.add_argument("--restore", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    state, history, mgr = train_loop(
        cfg, steps=args.steps, global_batch=args.batch, seq_len=args.seq,
        ckpt_every=args.ckpt_every, quantize_ckpt=not args.no_quant,
        accum=args.accum, restore=args.restore)
    print("final losses:", [f"{l:.4f}" for _, l in history[-5:]])
    print("ckpt metrics:", {k: v for k, v in sorted(mgr.metrics.items())})


if __name__ == "__main__":
    main()
