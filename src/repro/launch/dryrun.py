import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_DRYRUN_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above run before ANY other import — jax locks the device count
at first init, and the production meshes need 512 host devices.

For each runnable cell (see configs/cells.py):
  train_4k     -> jit(train_step)   with sharded TrainState + batch
  prefill_32k  -> jit(prefill)      params + cache + (B, S) tokens
  decode_32k   -> jit(decode_step)  params + seq-sharded KV cache + (B, 1)
  long_500k    -> decode with a 524288-token cache (sub-quadratic archs)

All inputs are ShapeDtypeStructs (no allocation). The compiled artifact's
memory_analysis / cost_analysis / collective schedule are dumped to JSON for
the roofline analysis (analysis/roofline.py) and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch gemma3-4b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def input_specs(cfg, shape):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    specs = {}
    if shape.kind == "train":
        specs["inputs"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    else:
        n_tok = s if shape.kind == "prefill" else 1
        specs["tokens"] = jax.ShapeDtypeStruct((b, n_tok), jnp.int32)
    if cfg.encoder_seq and shape.kind in ("train", "prefill"):
        specs["enc_input"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.encoder_dim), jnp.bfloat16)
    return specs


def accum_steps_for(cfg, shape, dp_shards: int) -> int:
    from repro.configs.cells import TRAIN_ACCUM
    want = TRAIN_ACCUM.get(cfg.name, 4)
    b = shape.global_batch
    accum = min(want, max(b // dp_shards, 1))
    while b % accum or (b // accum) % dp_shards:
        accum -= 1
    return max(accum, 1)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             donate: bool = True, hlo_path: str = "") -> dict:
    from repro.analysis import roofline as rl
    from repro.configs.base import SHAPES_BY_NAME, get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.sharding import (RuleSet, batch_axes, cache_axes,
                                       use_rules)
    from repro.models.registry import build_model, count_params
    from repro.runtime.train_step import (init_train_state, make_optimizer,
                                          make_train_step,
                                          state_logical_axes)

    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(mesh.devices.shape))
    rules = RuleSet(mesh)
    model = build_model(cfg)
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    t0 = time.time()

    key = jax.random.PRNGKey(0)
    params_struct = jax.eval_shape(model.init, key)
    dp = chips // mesh.shape["model"]

    with mesh, use_rules(rules):
        if shape.kind == "train":
            optimizer = make_optimizer(cfg)
            opt_struct = jax.eval_shape(optimizer.init, params_struct)
            from repro.runtime.train_step import TrainState
            state_struct = TrainState(params_struct, opt_struct)
            axes = state_logical_axes(cfg, model, optimizer)
            state_shardings = rules.tree_shardings(axes, state_struct)
            batch = input_specs(cfg, shape)
            b_shardings = rules.tree_shardings(batch_axes(batch), batch)
            accum = accum_steps_for(cfg, shape, dp)
            step_fn = make_train_step(cfg, model, optimizer,
                                      accum_steps=accum)
            jitted = jax.jit(step_fn,
                             in_shardings=(state_shardings, b_shardings),
                             out_shardings=(state_shardings, None),
                             donate_argnums=(0,) if donate else ())
            lowered = jitted.lower(state_struct, batch)
        elif shape.kind == "prefill":
            cache_struct = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            p_shardings = rules.tree_shardings(
                jax.tree.map(lambda d: d, model.param_axes(),
                             is_leaf=lambda x: isinstance(x, tuple)),
                params_struct)
            c_shardings = rules.tree_shardings(
                cache_axes(cfg, cache_struct), cache_struct)
            specs = input_specs(cfg, shape)
            tok_sh = rules.tree_shardings(batch_axes(specs), specs)

            def prefill_fn(params, cache, specs):
                return model.prefill(params, cache, specs["tokens"],
                                     specs.get("enc_input"))

            jitted = jax.jit(prefill_fn,
                             in_shardings=(p_shardings, c_shardings, tok_sh),
                             out_shardings=(None, c_shardings),
                             donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(params_struct, cache_struct, specs)
        else:   # decode
            cache_struct = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            p_shardings = rules.tree_shardings(model.param_axes(),
                                               params_struct)
            c_shardings = rules.tree_shardings(
                cache_axes(cfg, cache_struct), cache_struct)
            specs = input_specs(cfg, shape)
            tok_sh = rules.tree_shardings(batch_axes(specs), specs)

            def decode_fn(params, cache, specs, pos):
                return model.decode_step(params, cache, specs["tokens"], pos)

            jitted = jax.jit(decode_fn,
                             in_shardings=(p_shardings, c_shardings, tok_sh,
                                           None),
                             out_shardings=(None, c_shardings),
                             donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(params_struct, cache_struct, specs,
                                   jax.ShapeDtypeStruct((), jnp.int32))

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    from repro.analysis.hlo_stats import analyze

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    if hlo_path:
        import gzip
        with gzip.open(hlo_path, "wt") as f:
            f.write(hlo)
    stats = analyze(hlo)          # loop-aware per-chip flops/bytes/collectives

    n_active = count_params(cfg, active_only=True)
    result = rl.Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        flops_per_chip=stats.flops,
        hbm_bytes_per_chip=stats.hbm_fused,
        link_bytes_per_chip=stats.link_bytes,
        model_flops=rl.model_flops_for(cfg, shape, n_active),
        params_bytes_per_chip=float(getattr(mem, "argument_size_in_bytes", 0)),
        temp_bytes_per_chip=float(getattr(mem, "temp_size_in_bytes", 0)),
        collectives=stats.coll_detail,
    ).to_dict()
    result.update(
        status="ok", lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        memory_analysis={
            k: int(getattr(mem, k, 0)) for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "alias_size_in_bytes")} if mem else None,
        hbm_bytes_raw_per_chip=stats.hbm_bytes,
        xla_cost_analysis={"flops_body_once": float(cost.get("flops", 0.0)),
                           "bytes_accessed_body_once":
                               float(cost.get("bytes accessed", 0.0))},
        n_params=count_params(cfg), n_active=n_active,
        hlo_bytes=len(hlo),
    )
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("pod", "multipod", "both"),
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true",
                    help="gzip the compiled HLO next to each JSON")
    args = ap.parse_args()

    from repro.configs.cells import all_cells

    cells = [c for c in all_cells()
             if (args.all or ((not args.arch or c.arch == args.arch)
                              and (not args.shape or c.shape == args.shape)))]
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    failures = 0
    for cell in cells:
        for multi_pod in meshes:
            mesh_name = "multipod" if multi_pod else "pod"
            path = os.path.join(args.out,
                                f"{cell.key}__{mesh_name}.json")
            if os.path.exists(path) and not args.force:
                print(f"[skip-cached] {cell.key} {mesh_name}")
                continue
            if cell.skip:
                json.dump({"arch": cell.arch, "shape": cell.shape,
                           "mesh": mesh_name, "status": "skipped",
                           "reason": cell.skip}, open(path, "w"), indent=1)
                print(f"[skipped] {cell.key}: {cell.skip}")
                continue
            print(f"[run] {cell.key} {mesh_name} ...", flush=True)
            try:
                hlo_path = path.replace(".json", ".hlo.gz") \
                    if args.save_hlo else ""
                res = run_cell(cell.arch, cell.shape, multi_pod,
                               hlo_path=hlo_path)
                json.dump(res, open(path, "w"), indent=1)
                print(f"  ok: compile={res['compile_s']}s "
                      f"flops/chip={res['flops_per_chip']:.3g} "
                      f"hbm/chip={res['hbm_bytes_per_chip']:.3g} "
                      f"link/chip={res['link_bytes_per_chip']:.3g} "
                      f"bottleneck={res['bottleneck']}", flush=True)
            except Exception as e:
                failures += 1
                json.dump({"arch": cell.arch, "shape": cell.shape,
                           "mesh": mesh_name, "status": "error",
                           "error": repr(e),
                           "traceback": traceback.format_exc()},
                          open(path, "w"), indent=1)
                print(f"  ERROR: {e!r}", flush=True)
    print(f"done, failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
