"""Logical-axis -> mesh sharding rules (DP / FSDP / TP / EP / SP).

Every parameter descriptor carries logical axis names; this module resolves
them to PartitionSpecs against the active mesh with (a) divisibility checks
(a dim only shards if evenly divisible — jit input shardings require it) and
(b) conflict avoidance (one mesh axis at most once per tensor, resolved in
dim order).

Baseline rule table (the §Perf iterations adjust per-arch overrides):
  batch        -> (pod, data)   data parallelism (pod = DCN-only axis)
  seq          -> model         sequence-sharded KV caches (decode) / CP
  embed        -> data          FSDP: weights gathered at use
  ffn/vocab    -> model         tensor parallelism (Megatron col/row)
  heads        -> model         head TP when head count divides the axis
  experts      -> (data, model) 256-expert one-per-chip EP (deepseek) or
                  model         16-way EP (llama4)
Activation constraints are applied inside model code via ``constrain`` —
a no-op unless a rule set is active (models stay mesh-agnostic).
"""
from __future__ import annotations

import contextlib
import contextvars
import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# candidates: logical axis -> tuple of options; each option is a tuple of
# mesh axes used jointly for that dim (tried in order until one fits)
DEFAULT_RULES: Dict[Optional[str], Tuple[Tuple[str, ...], ...]] = {
    "batch": (("pod", "data"), ("data",), ("pod",)),
    "seq": (("model",),),
    "embed": (("data",),),
    "embed_out": (("model",),),
    "ffn": (("model",),),
    "ffn_out": (("data",),),
    "vocab": (("model",),),
    "heads": (("model",),),
    "kv_heads": (),
    "head_dim": (),
    "head_dim2": (),
    "q_lora": (),
    "kv_lora": (),
    "rope_dim": (),
    "experts": (("data", "model"), ("data",), ("model",)),
    "experts_flat": (("model",),),
    "layers": (),
    "enc_dim": (),
    None: (),
}


class RuleSet:
    def __init__(self, mesh: Mesh, overrides: Optional[dict] = None):
        self.mesh = mesh
        self.sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.rules = dict(DEFAULT_RULES)
        if overrides:
            self.rules.update(overrides)

    def spec(self, logical_axes: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None) -> P:
        """Resolve logical axes to a PartitionSpec with divisibility +
        conflict checks. shape=None skips divisibility (constraints only)."""
        used: set = set()
        out = []
        for i, name in enumerate(logical_axes):
            choice = None
            for option in self.rules.get(name, ()):
                axes = tuple(a for a in option if a in self.sizes)
                if not axes or any(a in used for a in axes):
                    continue
                k = math.prod(self.sizes[a] for a in axes)
                if shape is not None and shape[i] % k != 0:
                    continue
                choice = axes
                break
            if choice:
                used.update(choice)
                out.append(choice if len(choice) > 1 else choice[0])
            else:
                out.append(None)
        return P(*out)

    def sharding(self, logical_axes, shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))

    def tree_shardings(self, axes_tree, shape_tree):
        """axes_tree: logical-axis tuples; shape_tree: matching
        ShapeDtypeStructs (or arrays). Returns a NamedSharding tree."""
        def is_axes_leaf(x):
            return isinstance(x, tuple) and all(
                a is None or isinstance(a, str) for a in x)
        flat_axes, treedef = jax.tree.flatten(axes_tree, is_leaf=is_axes_leaf)
        flat_shapes = treedef.flatten_up_to(shape_tree)
        shardings = [self.sharding(a, s.shape)
                     for a, s in zip(flat_axes, flat_shapes)]
        return jax.tree.unflatten(treedef, shardings)


# ---------------------------------------------------------------------------
# activation constraints from inside model code (contextvar-scoped)

_ACTIVE: contextvars.ContextVar[Optional[RuleSet]] = \
    contextvars.ContextVar("repro_ruleset", default=None)


@contextlib.contextmanager
def use_rules(rules: Optional[RuleSet]):
    token = _ACTIVE.set(rules)
    try:
        yield
    finally:
        _ACTIVE.reset(token)


def active_rules() -> Optional[RuleSet]:
    return _ACTIVE.get()


def constrain(x, logical_axes: Sequence[Optional[str]]):
    """with_sharding_constraint against the active rule set (no-op outside
    a distributed context). Divisibility-checked against x.shape."""
    rules = _ACTIVE.get()
    if rules is None:
        return x
    spec = rules.spec(logical_axes, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


# ---------------------------------------------------------------------------
# cache logical axes (mirrors transformer.init_cache structure)


def cache_axes(cfg, cache) -> Any:
    """Assign logical axes to decode-cache leaves by their role. The cache
    tree is {seg*: {pos*: kind-cache}}; leaves are identified by key path."""
    def assign(path, leaf):
        names = [_pstr(p) for p in path]
        rank = np.ndim(leaf)
        last = names[-1] if names else ""
        if last in ("k", "v"):              # (L,B,S,KV,HD) attn ring/cross
            return ("layers", "batch", "seq", "kv_heads", "head_dim")[:rank]
        if last == "c_kv":
            return ("layers", "batch", "seq", "kv_lora")[:rank]
        if last == "k_rope":
            return ("layers", "batch", "seq", "rope_dim")[:rank]
        if last == "C":                     # (L,B,H,dk,dv) mlstm state
            return ("layers", "batch", "heads", "head_dim", "head_dim2")[:rank]
        if last == "n":
            return ("layers", "batch", "heads", "head_dim")[:rank]
        if last == "m":
            return ("layers", "batch", "heads")[:rank]
        if last == "conv":                  # (L,B,W-1,du)
            return ("layers", "batch", None, "ffn")[:rank]
        if last == "h":                     # (L,B,width) rglru state
            return ("layers", "batch", "ffn")[:rank]
        if "state" in names:                # slstm tuple (L,B,H,dh)
            return ("layers", "batch", "heads", "head_dim")[:rank]
        return ("layers", "batch") + (None,) * max(rank - 2, 0)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    return jax.tree_util.tree_unflatten(
        treedef, [tuple(assign(p, l)) for p, l in flat])


def _pstr(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def batch_axes(batch) -> Any:
    """Input batch dict: tokens/labels (B,S); enc_input (B,S,E)."""
    return jax.tree.map(
        lambda x: ("batch",) + (None,) * (np.ndim(x) - 1), batch)
