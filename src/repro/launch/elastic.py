"""Elastic scaling + straggler mitigation hooks.

Checkpoint-restart elasticity: because burst-buffer checkpoints key shards
by *logical tree path* (not device), a job can restart on a different mesh
(fewer/more hosts after failures) and restore exactly — `reshard_plan`
computes the new shardings and `elastic_restore` rebuilds the train state
under them. Straggler mitigation happens at two levels:
  - ingest: the paper's overload-redirect (core/server.py) routes traffic
    away from slow/overloaded burst-buffer servers automatically;
  - flush: `rebalance_domains` reassigns PFS file domains away from servers
    whose recent flush throughput lags the ring median (work stealing at
    two-phase shuffle time).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import RuleSet


def degraded_mesh(total_hosts: int, lost_hosts: int, *,
                  model_axis: int = 16):
    """Largest (data, model) mesh that fits the surviving hosts, keeping the
    model axis intact (TP groups must stay whole; DP shrinks)."""
    surviving = total_hosts - lost_hosts
    data = max(1, surviving // model_axis)
    return make_host_mesh(data=data, model=model_axis)


def reshard_plan(cfg, model, optimizer, mesh) -> Tuple[RuleSet, object]:
    from repro.runtime.train_step import state_logical_axes
    rules = RuleSet(mesh)
    axes = state_logical_axes(cfg, model, optimizer)
    return rules, axes


def elastic_restore(mgr, cfg, model, optimizer, mesh, target_state,
                    step: Optional[int] = None):
    """Restore a BB checkpoint onto a (possibly different) mesh: values are
    fetched by logical key, then device_put with the new shardings."""
    rules, axes = reshard_plan(cfg, model, optimizer, mesh)
    restored, ck_step = mgr.restore(target_state, step)
    shardings = rules.tree_shardings(
        {"params": axes.params, "opt_state": axes.opt_state},
        {"params": restored["params"], "opt_state": restored["opt_state"]})
    with mesh:
        placed = jax.tree.map(jax.device_put,
                              {"params": restored["params"],
                               "opt_state": restored["opt_state"]},
                              shardings)
    return placed, ck_step


def rebalance_domains(flush_throughput: Dict[str, float],
                      servers: Sequence[str],
                      slack: float = 0.5) -> List[str]:
    """Weighted server order for domain assignment: servers slower than
    ``slack`` x median get proportionally fewer (possibly zero) domains.
    Returns a server list (with repetitions) to pass as the 'servers'
    argument of twophase.domains — slow servers own fewer bytes."""
    if not flush_throughput:
        return list(servers)
    med = float(np.median(list(flush_throughput.values()))) or 1.0
    weighted: List[str] = []
    for s in servers:
        w = flush_throughput.get(s, med) / med
        reps = max(0 if w < slack else 1, round(w))
        weighted.extend([s] * reps)
    return weighted or list(servers)
