"""Production mesh construction.

Single pod: (data=16, model=16) — 256 chips, both axes on ICI.
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the ``pod`` axis crosses
DCN, so the sharding rules place only data parallelism (gradient all-reduce,
batch splitting) on it; ``model`` carries TP/EP/SP collectives on ICI.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh for tests (requires xla_force_host_platform_device_count)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
