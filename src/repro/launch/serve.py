"""Batched serving driver: prefill + decode loop with a request queue.

Requests are batched up to --batch; each batch is prefended (prefill) and
decoded greedily for --gen tokens. Model weights can be restored from the
burst buffer (serving restarts read hot weights from server DRAM instead of
the PFS — the paper's restart path applied to inference)."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.models.registry import build_model
from repro.runtime.serve_step import greedy_token, make_decode_step, \
    make_prefill


def serve_batch(cfg, model, params, prompts, *, gen_tokens=16,
                max_seq=None, enc_input=None):
    """prompts: (B, S) int32 -> generated (B, gen_tokens)."""
    b, s = prompts.shape
    max_seq = max_seq or (s + gen_tokens)
    cache = model.init_cache(b, max_seq)
    prefill = jax.jit(make_prefill(cfg, model))
    decode = jax.jit(make_decode_step(cfg, model), donate_argnums=(1,))

    logits, cache = prefill(params, cache, prompts, enc_input)
    tok = greedy_token(cfg, logits)
    out = [tok]
    pos = s
    for i in range(gen_tokens - 1):
        logits, cache = decode(params, cache, tok, jnp.asarray(pos, jnp.int32))
        tok = greedy_token(cfg, logits)
        out.append(tok)
        pos += 1
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--requests", type=int, default=3)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    enc = None
    if cfg.encoder_seq:
        enc = jnp.asarray(rng.normal(
            0, 1, (args.batch, cfg.encoder_seq, cfg.encoder_dim)),
            jnp.float32)

    for r in range(args.requests):
        prompts = jnp.asarray(rng.integers(
            1, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)
        t0 = time.perf_counter()
        toks = serve_batch(cfg, model, params, prompts,
                           gen_tokens=args.gen, enc_input=enc)
        dt = time.perf_counter() - t0
        print(f"[serve] request-batch {r}: {toks.shape} in {dt:.2f}s "
              f"({args.batch * args.gen / dt:.1f} tok/s) "
              f"sample={np.asarray(toks[0, :8]).tolist()}")


if __name__ == "__main__":
    main()
