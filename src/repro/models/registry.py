"""Model registry: build per-arch model handles + analytic param counting."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.models import transformer
from repro.models.common import count_tree, is_desc


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: Any
    init: Callable                      # (key) -> params
    param_axes: Callable                # () -> logical axes tree
    forward: Callable                   # (params, tokens, enc_input=None) -> logits
    init_cache: Callable                # (batch, max_seq) -> cache
    decode_step: Callable               # (params, cache, tokens, pos) -> (logits, cache)
    prefill: Callable                   # (params, cache, tokens, enc_input=None)


def build_model(cfg) -> Model:
    return Model(
        cfg=cfg,
        init=lambda key: transformer.init_params(cfg, key),
        param_axes=lambda: transformer.param_axes(cfg),
        forward=lambda params, tokens, enc_input=None: transformer.forward(
            cfg, params, tokens, enc_input),
        init_cache=lambda batch, max_seq: transformer.init_cache(
            cfg, batch, max_seq),
        decode_step=lambda params, cache, tokens, pos, enc_input=None:
            transformer.decode_step(cfg, params, cache, tokens, pos, enc_input),
        prefill=lambda params, cache, tokens, enc_input=None:
            transformer.prefill(cfg, params, cache, tokens, enc_input),
    )


def count_params(cfg, active_only: bool = False) -> int:
    """Analytic parameter count from the descriptor tree.

    active_only: count routed-expert params at the top_k/num_experts fraction
    (MoE "activated parameters" — used for MODEL_FLOPS = 6 * N_active * D).
    """
    tree = transformer.model_descs(cfg)
    total = 0
    for path, d in jax.tree_util.tree_flatten_with_path(
            tree, is_leaf=is_desc)[0]:
        n = int(np.prod(d.shape))
        if active_only and "experts" in (d.axes or ()):
            n = int(n * cfg.top_k / max(cfg.num_experts, 1))
        total += n
    return total
