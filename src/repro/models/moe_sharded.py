"""Expert-parallel MoE via shard_map with fixed-capacity all-to-all.

Pure-SPMD sort-based dispatch (moe.py) lowers its cross-shard scatter to
"replicate + mask + all-reduce" of (T*k, d) f32 tensors — measured at ~2/3
of all collective traffic and ~8x the temp memory on deepseek-v3 train.
This module replaces it with the explicit schedule real MoE systems use:

GRID mode (E divisible by data*model, e.g. deepseek 256 on a 16x16 pod —
expert e lives wholly on device (e // ncols, e % ncols)):
  1. tokens are batch-sharded over `data` rows, replicated over `model` cols
  2. each col c filters assignments routed to experts with e % ncols == c
     (cols partition the assignment set — no duplicated expert work)
  3. bin by destination row (e // ncols), capacity-clip, all_to_all over
     `data` (the only cross-row traffic: cap-padded token payloads)
  4. local expert FFN (weights fully resident), reverse all_to_all
  5. scatter-add weighted outputs locally, psum over `model` to merge cols

ROW mode (E divisible by data only, e.g. llama4 16 experts — expert e lives
on row e, f-dim sharded over `model`):
  same dispatch with dest row = e, no col filter (cols replicate dispatch);
  expert FFN contracts its f-shard and psums over `model` inside the expert;
  no final psum.

Capacity per (src device, dest bin): ceil(T_loc * k / bins * cf), padded to
8. Overflow drops (standard dropping MoE); zeros flow through the FFN to a
zero contribution, so no masking is needed on the payload path.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
try:
    from jax import shard_map
except ImportError:                      # older jax
    from jax.experimental.shard_map import shard_map

from repro.models.common import activation


def _cap(n_assign: int, bins: int, cf: float) -> int:
    c = math.ceil(n_assign / bins * cf)
    return max(8, ((c + 7) // 8) * 8)


def sharded_moe_available(cfg, rules) -> bool:
    if rules is None or cfg.num_experts == 0:
        return False
    sizes = rules.sizes
    if "data" not in sizes or "model" not in sizes:
        return False
    e = cfg.num_experts
    grid = e == sizes["data"] * sizes["model"]
    row = (not grid) and e == sizes["data"] \
        and cfg.d_ff_expert % sizes["model"] == 0
    return grid or row


def apply_moe_sharded(cfg, p, x, rules):
    """x: (B, S, d) batch-sharded over (pod?, data). Returns (B, S, d)."""
    mesh = rules.mesh
    sizes = rules.sizes
    nrows, ncols = sizes["data"], sizes["model"]
    e = cfg.num_experts
    grid_mode = e == nrows * ncols

    x_spec = rules.spec(("batch", None, None), x.shape)
    router_spec = P(None, None)
    if grid_mode:
        w_spec = P(("data", "model"), None, None)
    else:
        w_spec = P("data", None, "model")          # experts x d x f-shard
    wd_spec = P(("data", "model"), None, None) if grid_mode \
        else P("data", "model", None)
    out_spec = x_spec

    has_pod = "pod" in sizes

    def local_moe(xl, router, wg, wu, wd):
        b_l, s_l, d = xl.shape
        t = b_l * s_l
        k = cfg.top_k
        xt = xl.reshape(t, d)
        col = jax.lax.axis_index("model")

        # --- routing (replicated across cols; f32) ---
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                            router.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        topw, topi = jax.lax.top_k(probs, k)
        topw = topw / jnp.maximum(jnp.sum(topw, -1, keepdims=True), 1e-9)

        flat_e = topi.reshape(-1)
        flat_w = topw.reshape(-1).astype(xl.dtype)
        flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)

        if grid_mode:
            mine = (flat_e % ncols) == col          # this col's experts
            dest = flat_e // ncols                  # dest data-row
            bins = nrows
            cap = _cap(t * k, nrows * ncols, cfg.capacity_factor)
        else:
            mine = jnp.ones_like(flat_e, dtype=bool)
            dest = flat_e                           # dest row == expert id
            bins = nrows
            cap = _cap(t * k, nrows, cfg.capacity_factor)

        dest = jnp.where(mine, dest, bins)          # invalid -> dump bin
        order = jnp.argsort(dest)
        sdest, stok, sw = dest[order], flat_t[order], flat_w[order]
        counts = jnp.bincount(sdest, length=bins + 1)
        starts = jnp.cumsum(counts) - counts
        rank = jnp.arange(t * k, dtype=jnp.int32) - starts[sdest]
        keep = (rank < cap) & (sdest < bins)
        slot = jnp.where(keep, sdest * cap + rank, bins * cap)

        send = jnp.zeros((bins * cap + 1, d), xl.dtype).at[slot].set(xt[stok])
        send = send[:-1].reshape(bins, cap, d)
        # slot-aligned metadata stays local (a2a preserves slot order)
        meta_tok = jnp.full((bins * cap + 1,), -1, jnp.int32
                            ).at[slot].set(jnp.where(keep, stok, -1))[:-1]
        meta_w = jnp.zeros((bins * cap + 1,), xl.dtype
                           ).at[slot].set(jnp.where(keep, sw, 0))[:-1]

        recv = jax.lax.all_to_all(send, "data", split_axis=0, concat_axis=0,
                                  tiled=True)       # (bins*cap, d) grouped
        h = recv.reshape(bins * cap, d)

        # --- expert FFN (weights local) ---
        wg_l, wu_l, wd_l = wg[0], wu[0], wd[0]      # local expert (1, d, f)
        gate = jnp.einsum("nd,df->nf", h, wg_l.astype(h.dtype))
        up = jnp.einsum("nd,df->nf", h, wu_l.astype(h.dtype))
        y = jnp.einsum("nf,fd->nd", activation(cfg, gate) * up,
                       wd_l.astype(h.dtype))
        if not grid_mode:
            # f is sharded over model: partial sums -> psum inside expert
            y = jax.lax.psum(y, "model")

        back = jax.lax.all_to_all(y.reshape(bins, cap, d), "data",
                                  split_axis=0, concat_axis=0, tiled=True)
        back = back.reshape(bins * cap, d)

        contrib = back * meta_w[:, None]
        tok_safe = jnp.where(meta_tok >= 0, meta_tok, t)
        out = jnp.zeros((t + 1, d), xl.dtype).at[tok_safe].add(contrib)[:-1]
        if grid_mode:
            out = jax.lax.psum(out, "model")        # merge col contributions
        return out.reshape(b_l, s_l, d)

    fn = shard_map(
        local_moe, mesh=mesh,
        in_specs=(x_spec, router_spec, w_spec, w_spec, wd_spec),
        out_specs=out_spec,
        check_vma=False)
    out = fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    if cfg.num_shared_experts:
        sp = p["shared"]
        g = jnp.einsum("bsd,df->bsf", x, sp["w_gate"].astype(x.dtype))
        u = jnp.einsum("bsd,df->bsf", x, sp["w_up"].astype(x.dtype))
        out = out + jnp.einsum("bsf,fd->bsd", activation(cfg, g) * u,
                               sp["w_down"].astype(x.dtype))
    return out
