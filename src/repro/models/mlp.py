"""Dense MLPs: gated (SwiGLU/GeGLU) and plain two-layer."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.common import P, activation


def mlp_descs(cfg, d_ff=None):
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    descs = {
        "w_up": P((d, f), ("embed", "ffn"), "fanin"),
        "w_down": P((f, d), ("ffn", "embed"), "fanin"),
    }
    if cfg.mlp_gated:
        descs["w_gate"] = P((d, f), ("embed", "ffn"), "fanin")
    return descs


def apply_mlp(cfg, p, x):
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    if cfg.mlp_gated:
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
        h = activation(cfg, gate) * up
    else:
        h = activation(cfg, up)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))
