"""Multi-head Latent Attention (DeepSeek-V2/V3).

Queries go through a low-rank bottleneck (q_lora_rank); keys/values share a
compressed latent c_kv (kv_lora_rank) plus a single decoupled RoPE key per
token. The decode cache stores only (c_kv, k_rope) — (r_kv + d_rope) floats
per token instead of 2*H*head_dim — which is the reason this arch is eligible
for the 500k-context decode cell.

Decode uses the *absorbed* formulation: W_uk is folded into the query so
attention scores are computed directly in the compressed latent space
(q_abs . c_kv), and W_uv is applied once after the softmax — per-step FLOPs
independent of reconstructing per-head K/V over the full cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import P, apply_rope, norm_descs, apply_norm
from repro.kernels import ops as kops


def mla_descs(cfg):
    d = cfg.d_model
    h = cfg.num_heads
    rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    return {
        "wq_a": P((d, rq), ("embed", "q_lora"), "fanin"),
        "q_norm": norm_descs(cfg, rq),
        "wq_b": P((rq, h, dn + dr), ("q_lora", "heads", "head_dim"), "fanin"),
        "wkv_a": P((d, rkv + dr), ("embed", "kv_lora"), "fanin"),
        "kv_norm": norm_descs(cfg, rkv),
        "wk_b": P((rkv, h, dn), ("kv_lora", "heads", "head_dim"), "fanin"),
        "wv_b": P((rkv, h, dv), ("kv_lora", "heads", "head_dim"), "fanin"),
        "wo": P((h, dv, d), ("heads", "head_dim", "embed"), "fanin"),
    }


def _project_q(cfg, p, x, positions):
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    cq = jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(x.dtype))
    cq = apply_norm(cfg, p["q_norm"], cq)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"].astype(x.dtype))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _compress_kv(cfg, p, x, positions):
    rkv, dr = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    ckv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(x.dtype))
    c_kv, k_rope = ckv[..., :rkv], ckv[..., rkv:]
    c_kv = apply_norm(cfg, p["kv_norm"], c_kv)
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def mla_attention(cfg, p, x, positions):
    """Training/prefill path: reconstruct per-head K/V, use the fused kernel."""
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    h = cfg.num_heads
    q_nope, q_rope = _project_q(cfg, p, x, positions)
    c_kv, k_rope = _compress_kv(cfg, p, x, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["wk_b"].astype(x.dtype))
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["wv_b"].astype(x.dtype))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope[:, :, None, :],
                                          k_rope.shape[:2] + (h, dr))], axis=-1)
    # pad v head_dim to qk dim for the fused kernel, slice after
    pad = (dn + dr) - dv
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad))) if pad > 0 else v
    o = kops.flash_attention(q, k, vp, causal=True)
    o = o[..., :dv]
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# decode with compressed cache (absorbed matmuls)


def init_mla_cache(cfg, batch: int, max_seq: int):
    dt = jnp.dtype(cfg.compute_dtype)
    return {
        "c_kv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dt),
        "k_rope": jnp.zeros((batch, max_seq, cfg.qk_rope_head_dim), dt),
    }


def decode_mla_attention(cfg, p, x, cache, pos):
    """x: (B, 1, d); cache seq dim shardable over the model axis."""
    b = x.shape[0]
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    rkv = cfg.kv_lora_rank
    pos_b = jnp.full((b, 1), pos, jnp.int32)

    q_nope, q_rope = _project_q(cfg, p, x, pos_b)           # (B,1,H,dn/dr)
    c_new, kr_new = _compress_kv(cfg, p, x, pos_b)          # (B,1,rkv),(B,1,dr)

    size = cache["c_kv"].shape[1]
    slot = jnp.mod(pos, size)
    c_kv = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, slot, 0))
    k_rope = jax.lax.dynamic_update_slice(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), (0, slot, 0))
    new_cache = {"c_kv": c_kv, "k_rope": k_rope}

    # absorb W_uk into q: q_abs (B,1,H,rkv)
    q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, p["wk_b"].astype(x.dtype))
    scale = (dn + dr) ** -0.5
    s = jnp.einsum("bshr,btr->bhst", q_abs.astype(jnp.float32),
                   c_kv.astype(jnp.float32)) * scale
    s = s + jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32),
                       k_rope.astype(jnp.float32)) * scale

    idx = jnp.arange(size, dtype=jnp.int32)
    valid = idx <= pos                                      # ring never wraps here
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    # attend in latent space, then decompress once per new token
    o_lat = jnp.einsum("bhst,btr->bshr", w, c_kv.astype(jnp.float32))
    o = jnp.einsum("bshr,rhk->bshk", o_lat.astype(x.dtype),
                   p["wv_b"].astype(x.dtype))               # (B,1,H,dv)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype)), new_cache
