"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel kernel) and sLSTM
(scalar memory, inherently sequential -> lax.scan).

mLSTM block (pre up-projection, proj_factor 2):
  x -> norm -> up (2x: value path v & output gate z)
            -> causal conv4 on value path -> q,k projections
            -> mlstm(q,k,v, log_f, log_i) -> headwise groupnorm
            -> (* silu(z)) -> down-projection
sLSTM block: norm -> fused gates (input + recurrent, per-head block-diagonal
recurrence) -> stabilized scalar cell -> headwise groupnorm -> out proj,
followed by a gated FFN (proj_factor 4/3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import P, norm_descs, apply_norm
from repro.kernels import ops as kops


def _conv_descs(dim, width):
    return {"kernel": P((width, dim), (None, "embed"), "fanin"),
            "bias": P((dim,), ("embed",), "zeros")}


def _causal_conv(p, x, state=None):
    """x: (B,S,D). state: (B,W-1,D) trailing inputs from the previous step.
    Returns (y, new_state)."""
    w = p["kernel"].shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], w - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * p["kernel"][i].astype(x.dtype)
            for i in range(w))
    y = y + p["bias"].astype(x.dtype)
    new_state = xp[:, -(w - 1):]
    return y, new_state


def _groupnorm_heads(x, eps=1e-6):
    """x: (B,S,H,D) — normalize per head (no learned params here)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


# ---------------------------------------------------------------------------
# mLSTM block


def mlstm_descs(cfg):
    d = cfg.d_model
    du = int(d * cfg.mlstm_proj_factor)
    h = cfg.num_heads
    return {
        "norm": norm_descs(cfg),
        "w_up_v": P((d, du), ("embed", "ffn"), "fanin"),
        "w_up_z": P((d, du), ("embed", "ffn"), "fanin"),
        "conv": _conv_descs(du, cfg.conv1d_width),
        "wq": P((du, du), ("ffn", "ffn_out"), "fanin"),
        "wk": P((du, du), ("ffn", "ffn_out"), "fanin"),
        "w_if": P((d, 2 * h), ("embed", None), "fanin"),
        "w_down": P((du, d), ("ffn", "embed"), "fanin"),
    }


def _mlstm_qkv(cfg, p, xn, conv_state=None):
    b, s, _ = xn.shape
    du = p["w_up_v"].shape[1]
    h = cfg.num_heads
    dh = du // h
    v_path = jnp.einsum("bsd,de->bse", xn, p["w_up_v"].astype(xn.dtype))
    z = jnp.einsum("bsd,de->bse", xn, p["w_up_z"].astype(xn.dtype))
    c, new_conv = _causal_conv(p["conv"], v_path, conv_state)
    c = jax.nn.silu(c)
    q = jnp.einsum("bse,ef->bsf", c, p["wq"].astype(xn.dtype))
    k = jnp.einsum("bse,ef->bsf", c, p["wk"].astype(xn.dtype))
    gates = jnp.einsum("bsd,dg->bsg", xn, p["w_if"].astype(xn.dtype))
    log_i = gates[..., :h].astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(gates[..., h:].astype(jnp.float32) + 3.0)
    shp = (b, s, h, dh)
    return (q.reshape(shp), k.reshape(shp), v_path.reshape(shp),
            log_f, log_i, z, new_conv)


def apply_mlstm_block(cfg, p, x):
    xn = apply_norm(cfg, p["norm"], x)
    q, k, v, log_f, log_i, z, _ = _mlstm_qkv(cfg, p, xn)
    hseq, _ = kops.mlstm(q, k, v, log_f, log_i)
    hseq = _groupnorm_heads(hseq)
    b, s = x.shape[:2]
    hflat = hseq.reshape(b, s, -1) * jax.nn.silu(z)
    return x + jnp.einsum("bse,ed->bsd", hflat, p["w_down"].astype(x.dtype))


def init_mlstm_cache(cfg, batch):
    du = int(cfg.d_model * cfg.mlstm_proj_factor)
    h = cfg.num_heads
    dh = du // h
    dt = jnp.dtype(cfg.compute_dtype)
    return {
        "C": jnp.zeros((batch, h, dh, dh), dt),
        "n": jnp.zeros((batch, h, dh), dt),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv1d_width - 1, du), dt),
    }


def decode_mlstm_block(cfg, p, x, cache):
    xn = apply_norm(cfg, p["norm"], x)
    q, k, v, log_f, log_i, z, new_conv = _mlstm_qkv(cfg, p, xn, cache["conv"])
    hseq, (C, n, m) = kops.mlstm(q, k, v, log_f, log_i,
                                 state=(cache["C"], cache["n"], cache["m"]))
    hseq = _groupnorm_heads(hseq)
    hflat = hseq.reshape(x.shape[0], x.shape[1], -1) * jax.nn.silu(z)
    out = x + jnp.einsum("bse,ed->bsd", hflat, p["w_down"].astype(x.dtype))
    return out, {"C": C, "n": n, "m": m, "conv": new_conv}


# ---------------------------------------------------------------------------
# sLSTM block


def slstm_descs(cfg):
    d = cfg.d_model
    h = cfg.num_heads
    dh = d // h
    df = int(d * cfg.slstm_proj_factor)
    return {
        "norm": norm_descs(cfg),
        "w_in": P((d, 4 * d), ("embed", None), "fanin"),
        "w_rec": P((h, dh, 4 * dh), ("heads", "head_dim", None), "fanin",
                   0.5),
        "w_out": P((d, d), ("embed", "embed_out"), "fanin"),
        "norm2": norm_descs(cfg),
        "w_ff_gate": P((d, df), ("embed", "ffn"), "fanin"),
        "w_ff_up": P((d, df), ("embed", "ffn"), "fanin"),
        "w_ff_down": P((df, d), ("ffn", "embed"), "fanin"),
    }


def _slstm_scan(cfg, p, gates_in, state):
    """gates_in: (B,S,4d) input contribution; sequential over S."""
    b, s, _ = gates_in.shape
    h = cfg.num_heads
    d = cfg.d_model
    dh = d // h
    w_rec = p["w_rec"].astype(jnp.float32)

    def step(carry, g_in):
        c, n, m, hprev = carry                       # (B,H,dh) x3, m:(B,H,dh)
        g_rec = jnp.einsum("bhd,hdg->bhg", hprev, w_rec)
        g = g_in.reshape(b, h, 4 * dh).astype(jnp.float32) + g_rec
        zi, ii, fi, oi = jnp.split(g, 4, axis=-1)    # (B,H,dh)
        zt = jnp.tanh(zi)
        ot = jax.nn.sigmoid(oi)
        log_i = ii
        log_f = jax.nn.log_sigmoid(fi + 3.0)
        m_new = jnp.maximum(log_f + m, log_i)
        c_new = jnp.exp(log_f + m - m_new) * c + jnp.exp(log_i - m_new) * zt
        n_new = jnp.exp(log_f + m - m_new) * n + jnp.exp(log_i - m_new)
        h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, m_new, h_new), h_new

    state, hs = jax.lax.scan(step, state, gates_in.swapaxes(0, 1))
    return hs.swapaxes(0, 1).reshape(b, s, d), state


def _slstm_init_state(cfg, batch):
    h, dh = cfg.num_heads, cfg.d_model // cfg.num_heads
    z = jnp.zeros((batch, h, dh), jnp.float32)
    return (z, z, jnp.full((batch, h, dh), -1e30, jnp.float32), z)


def apply_slstm_block(cfg, p, x):
    xn = apply_norm(cfg, p["norm"], x)
    g_in = jnp.einsum("bsd,dg->bsg", xn, p["w_in"].astype(x.dtype))
    hs, _ = _slstm_scan(cfg, p, g_in, _slstm_init_state(cfg, x.shape[0]))
    hs = _groupnorm_heads(hs.reshape(*x.shape[:2], cfg.num_heads, -1))
    hs = hs.reshape(x.shape).astype(x.dtype)
    x = x + jnp.einsum("bsd,de->bse", hs, p["w_out"].astype(x.dtype))
    xn2 = apply_norm(cfg, p["norm2"], x)
    gate = jnp.einsum("bsd,df->bsf", xn2, p["w_ff_gate"].astype(x.dtype))
    up = jnp.einsum("bsd,df->bsf", xn2, p["w_ff_up"].astype(x.dtype))
    return x + jnp.einsum("bsf,fd->bsd", jax.nn.silu(gate) * up,
                          p["w_ff_down"].astype(x.dtype))


def init_slstm_cache(cfg, batch):
    return {"state": _slstm_init_state(cfg, batch)}


def decode_slstm_block(cfg, p, x, cache):
    xn = apply_norm(cfg, p["norm"], x)
    g_in = jnp.einsum("bsd,dg->bsg", xn, p["w_in"].astype(x.dtype))
    hs, state = _slstm_scan(cfg, p, g_in, cache["state"])
    hs = _groupnorm_heads(hs.reshape(x.shape[0], x.shape[1], cfg.num_heads, -1))
    hs = hs.reshape(x.shape).astype(x.dtype)
    x = x + jnp.einsum("bsd,de->bse", hs, p["w_out"].astype(x.dtype))
    xn2 = apply_norm(cfg, p["norm2"], x)
    gate = jnp.einsum("bsd,df->bsf", xn2, p["w_ff_gate"].astype(x.dtype))
    up = jnp.einsum("bsd,df->bsf", xn2, p["w_ff_up"].astype(x.dtype))
    out = x + jnp.einsum("bsf,fd->bsd", jax.nn.silu(gate) * up,
                         p["w_ff_down"].astype(x.dtype))
    return out, {"state": state}
