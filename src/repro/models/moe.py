"""Mixture-of-experts FFN with sorted capacity-based dispatch.

Dispatch is sort-based (no (T, E, C) one-hot blow-up): assignments are sorted
by expert id, ranked within expert, dropped beyond capacity, gathered into an
(E, C, d) buffer, run through a batched expert MLP (einsum over the expert
dim — MXU-friendly, EP-shardable on E), and scatter-added back weighted by the
router probabilities. All shapes static; capacity = ceil(T*topk/E * cf).

Sharding: the expert dim is annotated "experts" -> EP over the model axis (or
(data, model) when E is divisible by 256, e.g. deepseek-v3's 256 experts map
one-per-chip on a single pod).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import P, activation
from repro.launch.sharding import constrain


def moe_descs(cfg):
    d, e, f = cfg.d_model, cfg.num_experts, cfg.d_ff_expert
    descs = {
        "router": P((d, e), ("embed", "experts_flat"), "fanin"),
        "w_gate": P((e, d, f), ("experts", "embed", "ffn"), "fanin"),
        "w_up": P((e, d, f), ("experts", "embed", "ffn"), "fanin"),
        "w_down": P((e, f, d), ("experts", "ffn", "embed"), "fanin"),
    }
    if cfg.num_shared_experts:
        fs = cfg.d_ff_shared or cfg.d_ff_expert * cfg.num_shared_experts
        descs["shared"] = {
            "w_gate": P((d, fs), ("embed", "ffn"), "fanin"),
            "w_up": P((d, fs), ("embed", "ffn"), "fanin"),
            "w_down": P((fs, d), ("ffn", "embed"), "fanin"),
        }
    return descs


def capacity(cfg, tokens: int) -> int:
    c = math.ceil(tokens * cfg.top_k / cfg.num_experts * cfg.capacity_factor)
    return max(8, ((c + 7) // 8) * 8)   # pad to 8 for layout friendliness


def apply_moe(cfg, p, x):
    """x: (B, S, d) -> (B, S, d). Uses the shard_map expert-parallel path
    (moe_sharded.py) when a distributed rule set is active and the expert
    count matches the mesh; else the pure-SPMD sort-based dispatch below."""
    from repro.launch.sharding import active_rules
    from repro.models import moe_sharded
    rules = active_rules()
    if moe_sharded.sharded_moe_available(cfg, rules):
        return moe_sharded.apply_moe_sharded(cfg, p, x, rules)
    return _apply_moe_dense(cfg, p, x)


def _apply_moe_dense(cfg, p, x):
    """Pure-SPMD sort-based dispatch (reference path; also the oracle for
    the shard_map path in tests)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.top_k
    xt = x.reshape(t, d)

    # --- routing (f32 for numerics) ---
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)                    # (t, k)
    topw = topw / jnp.maximum(jnp.sum(topw, axis=-1, keepdims=True), 1e-9)

    # --- sorted capacity dispatch ---
    cap = capacity(cfg, t)
    flat_e = topi.reshape(-1)                               # (t*k,)
    flat_w = topw.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    order = jnp.argsort(flat_e)                             # stable
    se, stok, sw = flat_e[order], flat_tok[order], flat_w[order]
    # rank within expert: position - start offset of that expert
    counts = jnp.bincount(se, length=e)                     # (e,)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(t * k, dtype=jnp.int32) - starts[se]
    keep = rank < cap
    slot = jnp.where(keep, se * cap + rank, e * cap)        # overflow -> dump row

    xe = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].set(xt[stok])
    xe = constrain(xe[:-1].reshape(e, cap, d), ("experts", None, None))

    # --- batched expert MLP (EP-sharded on e) ---
    gate = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(x.dtype))
    up = jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(x.dtype))
    ye = jnp.einsum("ecf,efd->ecd", activation(cfg, gate) * up,
                    p["w_down"].astype(x.dtype))
    ye = constrain(ye, ("experts", None, None))

    # --- combine ---
    ye_flat = jnp.concatenate([ye.reshape(e * cap, d),
                               jnp.zeros((1, d), x.dtype)], axis=0)
    contrib = ye_flat[slot] * sw[:, None].astype(x.dtype) \
        * keep[:, None].astype(x.dtype)
    out = jnp.zeros((t, d), x.dtype).at[stok].add(contrib)

    if cfg.num_shared_experts:
        sp = p["shared"]
        g = jnp.einsum("td,df->tf", xt, sp["w_gate"].astype(x.dtype))
        u = jnp.einsum("td,df->tf", xt, sp["w_up"].astype(x.dtype))
        out = out + jnp.einsum("tf,fd->td", activation(cfg, g) * u,
                               sp["w_down"].astype(x.dtype))
    return out.reshape(b, s, d)
