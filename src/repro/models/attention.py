"""Attention: GQA/MQA/MHA self-attention (global / sliding-window), cross-attention.

Training/prefill uses the fused flash-attention op from ``repro.kernels.ops``
(Pallas on TPU, chunked online-softmax jnp on CPU — same math, flash-like
memory profile). Decode attends one query token against a fixed-size KV cache
(ring buffer), written so the cache can be *sequence-sharded* across the
``model`` mesh axis: softmax max/sum reductions and the PV contraction over
the sharded seq dim lower to small all-reduces under GSPMD.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import P, apply_rope
from repro.kernels import ops as kops


# ---------------------------------------------------------------------------
# parameter descriptors


def attn_descs(cfg, *, cross: bool = False):
    d, h, kv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    descs = {
        "wq": P((d, h, hd), ("embed", "heads", "head_dim"), "fanin"),
        "wk": P((d, kv, hd), ("embed", "kv_heads", "head_dim"), "fanin"),
        "wv": P((d, kv, hd), ("embed", "kv_heads", "head_dim"), "fanin"),
        "wo": P((h, hd, d), ("heads", "head_dim", "embed"), "fanin"),
    }
    return descs


# ---------------------------------------------------------------------------
# projections


def _project_qkv(cfg, p, x, ctx=None):
    """q from x; k/v from ctx (cross) or x (self)."""
    src = x if ctx is None else ctx
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(x.dtype))
    return q, k, v


def _out_proj(cfg, p, o):
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))


# ---------------------------------------------------------------------------
# train / prefill


def _cp_eligible(cfg, seq: int) -> bool:
    """Context parallelism for archs whose head count cannot shard over the
    model axis (e.g. gemma3's 8 heads on a 16-wide axis): shard Q over the
    sequence instead, so attention compute splits n-ways instead of running
    replicated on every model rank. KV stays replicated (it already is —
    kv_heads are unsharded), so each rank scans the full KV against its
    query block; causal/window masks use absolute positions and need no
    ring exchange."""
    from repro.launch.sharding import active_rules
    rules = active_rules()
    if rules is None:
        return False
    m = rules.sizes.get("model", 1)
    return cfg.num_heads % m != 0 and seq % m == 0 and seq > 1


def self_attention(cfg, p, x, positions, *, window: int = 0,
                   causal: bool = True, rope_theta: Optional[float] = None):
    """x: (B, S, d); positions: (B, S) int32. window=0 -> global."""
    from repro.launch.sharding import constrain
    q, k, v = _project_qkv(cfg, p, x)
    theta = rope_theta if rope_theta is not None else cfg.rope_theta
    if cfg.pos_embed == "rope":
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    if _cp_eligible(cfg, q.shape[1]):
        q = constrain(q, ("batch", "seq", None, None))
    o = kops.flash_attention(
        q, k, v,
        causal=causal,
        window=window,
        softcap=cfg.logit_softcap,
    )
    return _out_proj(cfg, p, o)


def cross_attention(cfg, p, x, ctx):
    """x: (B, S, d); ctx: (B, S_ctx, d) encoder/vision states (no mask)."""
    from repro.launch.sharding import constrain
    q, k, v = _project_qkv(cfg, p, x, ctx=ctx)
    if _cp_eligible(cfg, q.shape[1]):
        q = constrain(q, ("batch", "seq", None, None))
    o = kops.flash_attention(q, k, v, causal=False, window=0, softcap=0.0)
    return _out_proj(cfg, p, o)


# ---------------------------------------------------------------------------
# decode (single new token against a KV cache)


def init_self_cache(cfg, batch: int, max_seq: int, *, window: int = 0):
    """Ring-buffer KV cache. Local-attention layers only allocate the window."""
    size = min(window, max_seq) if window else max_seq
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.compute_dtype)
    return {
        "k": jnp.zeros((batch, size, kv, hd), dt),
        "v": jnp.zeros((batch, size, kv, hd), dt),
    }


def init_cross_cache(cfg, batch: int, ctx_len: int):
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.compute_dtype)
    return {
        "k": jnp.zeros((batch, ctx_len, kv, hd), dt),
        "v": jnp.zeros((batch, ctx_len, kv, hd), dt),
    }


def decode_self_attention(cfg, p, x, cache, pos, *, window: int = 0,
                          rope_theta: Optional[float] = None):
    """x: (B, 1, d); pos: scalar int32 = number of tokens already cached.

    The new token's KV is written at ``pos % cache_size`` (ring semantics for
    windowed layers); attention runs over the whole buffer with validity and
    window masking by absolute position.
    """
    b, _, _ = x.shape
    q, k_new, v_new = _project_qkv(cfg, p, x)
    theta = rope_theta if rope_theta is not None else cfg.rope_theta
    pos_b = jnp.full((b, 1), pos, jnp.int32)
    if cfg.pos_embed == "rope":
        q = apply_rope(q, pos_b, theta)
        k_new = apply_rope(k_new, pos_b, theta)

    size = cache["k"].shape[1]
    slot = jnp.mod(pos, size)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, slot, 0, 0))
    new_cache = {"k": k, "v": v}

    # absolute position held by each ring slot after the write
    idx = jnp.arange(size, dtype=jnp.int32)
    n_written = pos + 1
    wraps = (n_written + size - 1 - idx) // size          # cycles completed per slot
    abs_pos = idx + (wraps - 1) * size                    # latest abs pos in slot
    valid = (abs_pos >= 0) & (abs_pos < n_written)
    if window:
        valid &= abs_pos >= (pos - window + 1)

    o = _cache_attend(cfg, q, k, v, valid)
    return _out_proj(cfg, o=o, p=p), new_cache


def decode_cross_attention(cfg, p, x, cache):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    valid = jnp.ones((cache["k"].shape[1],), bool)
    o = _cache_attend(cfg, q, cache["k"], cache["v"], valid)
    return _out_proj(cfg, p, o)


def prefill_cross_cache(cfg, p, ctx):
    k = jnp.einsum("bsd,dhk->bshk", ctx, p["wk"].astype(ctx.dtype))
    v = jnp.einsum("bsd,dhk->bshk", ctx, p["wv"].astype(ctx.dtype))
    return {"k": k, "v": v}


def _cache_attend(cfg, q, k, v, valid):
    """q: (B,1,H,D); k/v: (B,S,KV,D); valid: (S,) bool.

    f32 softmax; seq dim of k/v may be sharded — reductions over it become
    all-reduces under GSPMD.
    """
    b, _, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, 1, kvh, g, hd).astype(jnp.float32)
    scale = hd ** -0.5
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg * scale, k.astype(jnp.float32))
    if cfg.logit_softcap:
        s = jnp.tanh(s / cfg.logit_softcap) * cfg.logit_softcap
    s = jnp.where(valid[None, None, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return o.reshape(b, 1, h, hd).astype(q.dtype)
