"""Generic multi-family transformer built from scanned layer segments.

A model = embedding -> [segments] -> final norm -> unembedding, where each
segment is ``lax.scan`` over ``repeats`` of a fixed ``unit`` (tuple of layer
kinds). HLO size is O(sum of unit lengths), independent of depth — essential
for compiling 61-100 layer models 80 times on one CPU.

Layer kinds are registered in KINDS; each provides descriptor/apply/cache/
decode functions. Heterogeneous stacks (gemma3 5:1 local:global, griffin
(R,R,A), xLSTM (m*7,s), vision cross every 5th) are expressed as periodic
units, so every kind's params stack cleanly along the scan dim.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import xlstm as xlstm_mod
from repro.models.common import (P, apply_norm, cfg_dtype, cfg_param_dtype,
                                 embed_descs, embed_tokens, init_tree,
                                 axes_tree, norm_descs, sincos_positions,
                                 stack_descs, unembed)
from repro.models.mlp import apply_mlp, mlp_descs
from repro.launch.sharding import constrain


@dataclasses.dataclass(frozen=True)
class Kind:
    descs: Callable            # (cfg) -> descriptor tree
    apply: Callable            # (cfg, p, x, ext) -> x
    init_cache: Callable       # (cfg, batch, max_seq) -> cache tree (or {})
    decode: Callable           # (cfg, p, x, cache, ext) -> (x, cache)
    prefill: Callable          # (cfg, p, x, cache, ext) -> (x, cache)


# ---------------------------------------------------------------------------
# attention-family kinds (self-attn + dense/MoE FFN)


def _attn_descs(cfg, ffn="dense"):
    d = {"norm1": norm_descs(cfg), "attn": attn.attn_descs(cfg),
         "norm2": norm_descs(cfg)}
    if ffn == "dense":
        d["mlp"] = mlp_descs(cfg)
    elif ffn == "moe":
        d["moe"] = moe_mod.moe_descs(cfg)
    return d


def _make_attn_kind(*, window_attr=None, rope=True, local_theta=False,
                    ffn="dense", causal=True):
    def descs(cfg):
        return _attn_descs(cfg, ffn)

    def _window(cfg):
        return getattr(cfg, window_attr) if window_attr else 0

    def _theta(cfg):
        if not rope:
            return None
        return cfg.rope_theta_local if local_theta else cfg.rope_theta

    def apply(cfg, p, x, ext):
        h = apply_norm(cfg, p["norm1"], x)
        if rope:
            h = attn.self_attention(cfg, p["attn"], h, ext["positions"],
                                    window=_window(cfg), causal=causal,
                                    rope_theta=_theta(cfg))
        else:
            nope = dataclasses.replace(cfg, pos_embed="none")
            h = attn.self_attention(nope, p["attn"], h, ext["positions"],
                                    window=_window(cfg), causal=causal)
        x = x + h
        h = apply_norm(cfg, p["norm2"], x)
        h = apply_mlp(cfg, p["mlp"], h) if ffn == "dense" \
            else moe_mod.apply_moe(cfg, p["moe"], h)
        return x + h

    def init_cache(cfg, batch, max_seq):
        return {"kv": attn.init_self_cache(cfg, batch, max_seq,
                                           window=_window(cfg))}

    def decode(cfg, p, x, cache, ext):
        h = apply_norm(cfg, p["norm1"], x)
        acfg = cfg if rope else dataclasses.replace(cfg, pos_embed="none")
        h, kv = attn.decode_self_attention(acfg, p["attn"], h, cache["kv"],
                                           ext["pos"], window=_window(cfg),
                                           rope_theta=_theta(cfg) if rope else None)
        x = x + h
        h = apply_norm(cfg, p["norm2"], x)
        h = apply_mlp(cfg, p["mlp"], h) if ffn == "dense" \
            else moe_mod.apply_moe(cfg, p["moe"], h)
        return x + h, {"kv": kv}

    def prefill(cfg, p, x, cache, ext):
        h = apply_norm(cfg, p["norm1"], x)
        src = h
        acfg = cfg if rope else dataclasses.replace(cfg, pos_embed="none")
        q, k, v = attn._project_qkv(acfg, p["attn"], src)
        theta = _theta(cfg)
        if rope and cfg.pos_embed == "rope":
            from repro.models.common import apply_rope
            q = apply_rope(q, ext["positions"], theta)
            k = apply_rope(k, ext["positions"], theta)
        from repro.kernels import ops as kops
        if attn._cp_eligible(cfg, q.shape[1]):
            q = constrain(q, ("batch", "seq", None, None))
        o = kops.flash_attention(q, k, v, causal=causal, window=_window(cfg),
                                 softcap=cfg.logit_softcap)
        h = attn._out_proj(cfg, p["attn"], o)
        x = x + h
        h = apply_norm(cfg, p["norm2"], x)
        h = apply_mlp(cfg, p["mlp"], h) if ffn == "dense" \
            else moe_mod.apply_moe(cfg, p["moe"], h)
        x = x + h
        # write the (possibly windowed) tail of k/v into the ring cache
        buf = cache["kv"]["k"].shape[1]
        s = k.shape[1]
        if s >= buf:
            kw, vw = k[:, -buf:], v[:, -buf:]
            kcache = kw.astype(cache["kv"]["k"].dtype)
            vcache = vw.astype(cache["kv"]["v"].dtype)
            # ring alignment: slot of token t is t % buf
            shift = s % buf
            kcache = jnp.roll(kcache, shift, axis=1)
            vcache = jnp.roll(vcache, shift, axis=1)
        else:
            kcache = jax.lax.dynamic_update_slice(
                cache["kv"]["k"], k.astype(cache["kv"]["k"].dtype), (0, 0, 0, 0))
            vcache = jax.lax.dynamic_update_slice(
                cache["kv"]["v"], v.astype(cache["kv"]["v"].dtype), (0, 0, 0, 0))
        return x, {"kv": {"k": kcache, "v": vcache}}

    return Kind(descs, apply, init_cache, decode, prefill)


# ---------------------------------------------------------------------------
# MLA kinds


def _make_mla_kind(ffn):
    def descs(cfg):
        d = {"norm1": norm_descs(cfg), "attn": mla_mod.mla_descs(cfg),
             "norm2": norm_descs(cfg)}
        if ffn == "dense":
            d["mlp"] = mlp_descs(cfg)
        else:
            d["moe"] = moe_mod.moe_descs(cfg)
        return d

    def apply(cfg, p, x, ext):
        h = apply_norm(cfg, p["norm1"], x)
        x = x + mla_mod.mla_attention(cfg, p["attn"], h, ext["positions"])
        h = apply_norm(cfg, p["norm2"], x)
        h = apply_mlp(cfg, p["mlp"], h) if ffn == "dense" \
            else moe_mod.apply_moe(cfg, p["moe"], h)
        return x + h

    def init_cache(cfg, batch, max_seq):
        return {"mla": mla_mod.init_mla_cache(cfg, batch, max_seq)}

    def decode(cfg, p, x, cache, ext):
        h = apply_norm(cfg, p["norm1"], x)
        h, c = mla_mod.decode_mla_attention(cfg, p["attn"], h, cache["mla"],
                                            ext["pos"])
        x = x + h
        h = apply_norm(cfg, p["norm2"], x)
        h = apply_mlp(cfg, p["mlp"], h) if ffn == "dense" \
            else moe_mod.apply_moe(cfg, p["moe"], h)
        return x + h, {"mla": c}

    def prefill(cfg, p, x, cache, ext):
        h = apply_norm(cfg, p["norm1"], x)
        c_kv, k_rope = mla_mod._compress_kv(cfg, p["attn"], h, ext["positions"])
        x = x + mla_mod.mla_attention(cfg, p["attn"], h, ext["positions"])
        h = apply_norm(cfg, p["norm2"], x)
        h = apply_mlp(cfg, p["mlp"], h) if ffn == "dense" \
            else moe_mod.apply_moe(cfg, p["moe"], h)
        x = x + h
        s = c_kv.shape[1]
        c = {
            "c_kv": jax.lax.dynamic_update_slice(
                cache["mla"]["c_kv"],
                c_kv.astype(cache["mla"]["c_kv"].dtype), (0, 0, 0)),
            "k_rope": jax.lax.dynamic_update_slice(
                cache["mla"]["k_rope"],
                k_rope.astype(cache["mla"]["k_rope"].dtype), (0, 0, 0)),
        }
        return x, {"mla": c}

    return Kind(descs, apply, init_cache, decode, prefill)


# ---------------------------------------------------------------------------
# recurrent kinds


def _rglru_descs(cfg):
    return {"block": rglru_mod.rglru_descs(cfg), "norm2": norm_descs(cfg),
            "mlp": mlp_descs(cfg)}


def _rglru_apply(cfg, p, x, ext):
    x = rglru_mod.apply_rglru_block(cfg, p["block"], x)
    h = apply_norm(cfg, p["norm2"], x)
    return x + apply_mlp(cfg, p["mlp"], h)


def _rglru_cache(cfg, batch, max_seq):
    return {"rec": rglru_mod.init_rglru_cache(cfg, batch)}


def _rglru_decode(cfg, p, x, cache, ext):
    x, c = rglru_mod.decode_rglru_block(cfg, p["block"], x, cache["rec"])
    h = apply_norm(cfg, p["norm2"], x)
    return x + apply_mlp(cfg, p["mlp"], h), {"rec": c}


def _rglru_prefill(cfg, p, x, cache, ext):
    # run decode-style over the full sequence to obtain the final state
    x, c = rglru_mod.decode_rglru_block(cfg, p["block"], x, cache["rec"])
    h = apply_norm(cfg, p["norm2"], x)
    return x + apply_mlp(cfg, p["mlp"], h), {"rec": c}


def _mlstm_cache(cfg, batch, max_seq):
    return {"rec": xlstm_mod.init_mlstm_cache(cfg, batch)}


def _slstm_cache(cfg, batch, max_seq):
    return xlstm_mod.init_slstm_cache(cfg, batch)


# ---------------------------------------------------------------------------
# cross-attention kind (vision layers / whisper decoder)


def _cross_descs(cfg):
    return {"norm1": norm_descs(cfg), "attn": attn.attn_descs(cfg),
            "norm_c": norm_descs(cfg), "xattn": attn.attn_descs(cfg),
            "norm2": norm_descs(cfg), "mlp": mlp_descs(cfg)}


def _cross_apply(cfg, p, x, ext):
    h = apply_norm(cfg, p["norm1"], x)
    x = x + attn.self_attention(cfg, p["attn"], h, ext["positions"])
    h = apply_norm(cfg, p["norm_c"], x)
    x = x + attn.cross_attention(cfg, p["xattn"], h, ext["ctx"])
    h = apply_norm(cfg, p["norm2"], x)
    return x + apply_mlp(cfg, p["mlp"], h)


def _cross_cache(cfg, batch, max_seq):
    return {"kv": attn.init_self_cache(cfg, batch, max_seq),
            "xkv": attn.init_cross_cache(cfg, batch, max(cfg.encoder_seq, 1))}


def _cross_decode(cfg, p, x, cache, ext):
    h = apply_norm(cfg, p["norm1"], x)
    h, kv = attn.decode_self_attention(cfg, p["attn"], h, cache["kv"],
                                       ext["pos"])
    x = x + h
    h = apply_norm(cfg, p["norm_c"], x)
    x = x + attn.decode_cross_attention(cfg, p["xattn"], h, cache["xkv"])
    h = apply_norm(cfg, p["norm2"], x)
    return x + apply_mlp(cfg, p["mlp"], h), {"kv": kv, "xkv": cache["xkv"]}


def _cross_prefill(cfg, p, x, cache, ext):
    h = apply_norm(cfg, p["norm1"], x)
    q, k, v = attn._project_qkv(cfg, p["attn"], h)
    if cfg.pos_embed == "rope":
        from repro.models.common import apply_rope
        q = apply_rope(q, ext["positions"], cfg.rope_theta)
        k = apply_rope(k, ext["positions"], cfg.rope_theta)
    from repro.kernels import ops as kops
    if attn._cp_eligible(cfg, q.shape[1]):
        q = constrain(q, ("batch", "seq", None, None))
    o = kops.flash_attention(q, k, v, causal=True)
    x = x + attn._out_proj(cfg, p["attn"], o)
    h = apply_norm(cfg, p["norm_c"], x)
    xkv = attn.prefill_cross_cache(cfg, p["xattn"], ext["ctx"])
    x = x + attn.cross_attention(cfg, p["xattn"], h, ext["ctx"])
    h = apply_norm(cfg, p["norm2"], x)
    x = x + apply_mlp(cfg, p["mlp"], h)
    kv = {
        "k": jax.lax.dynamic_update_slice(
            cache["kv"]["k"], k.astype(cache["kv"]["k"].dtype), (0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(
            cache["kv"]["v"], v.astype(cache["kv"]["v"].dtype), (0, 0, 0, 0)),
    }
    return x, {"kv": kv, "xkv": {k2: v2.astype(cache["xkv"][k2].dtype)
                                 for k2, v2 in xkv.items()}}


# ---------------------------------------------------------------------------
# registry


def _stateless(kind: Kind) -> Kind:
    return kind


KINDS: Dict[str, Kind] = {
    "attn": _make_attn_kind(),
    "attn_local": _make_attn_kind(window_attr="window_size", local_theta=True),
    "moe": _make_attn_kind(ffn="moe"),
    "moe_local": _make_attn_kind(window_attr="window_size", ffn="moe"),
    "moe_nope": _make_attn_kind(rope=False, ffn="moe"),
    "mla_dense": _make_mla_kind("dense"),
    "mla_moe": _make_mla_kind("moe"),
    "rglru": Kind(_rglru_descs, _rglru_apply, _rglru_cache, _rglru_decode,
                  _rglru_prefill),
    "mlstm": Kind(lambda cfg: xlstm_mod.mlstm_descs(cfg),
                  lambda cfg, p, x, ext: xlstm_mod.apply_mlstm_block(cfg, p, x),
                  _mlstm_cache,
                  lambda cfg, p, x, c, ext: (
                      lambda r: (r[0], {"rec": r[1]}))(
                          xlstm_mod.decode_mlstm_block(cfg, p, x, c["rec"])),
                  lambda cfg, p, x, c, ext: (
                      lambda r: (r[0], {"rec": r[1]}))(
                          xlstm_mod.decode_mlstm_block(cfg, p, x, c["rec"]))),
    "slstm": Kind(lambda cfg: xlstm_mod.slstm_descs(cfg),
                  lambda cfg, p, x, ext: xlstm_mod.apply_slstm_block(cfg, p, x),
                  _slstm_cache,
                  lambda cfg, p, x, c, ext: xlstm_mod.decode_slstm_block(
                      cfg, p, x, c),
                  lambda cfg, p, x, c, ext: xlstm_mod.decode_slstm_block(
                      cfg, p, x, c)),
    "cross": Kind(_cross_descs, _cross_apply, _cross_cache, _cross_decode,
                  _cross_prefill),
    "enc": _make_attn_kind(causal=False),
}


# ---------------------------------------------------------------------------
# model assembly


def model_descs(cfg):
    d: Dict[str, Any] = {"embed": embed_descs(cfg)}
    d["segments"] = {}
    for i, (unit, reps) in enumerate(cfg.segments):
        seg = {str(j): KINDS[k].descs(cfg) for j, k in enumerate(unit)}
        d["segments"][f"seg{i}"] = stack_descs(seg, reps)
    d["final_norm"] = norm_descs(cfg)
    if cfg.mtp_depth:
        # DeepSeek-V3 multi-token prediction module (depth 1): shares the
        # embedding/unembedding; one extra transformer layer of the same
        # kind as the trunk's last segment, fed by a projection of
        # [norm(h_t) ; norm(emb(t+1))]
        last_kind = cfg.segments[-1][0][-1]
        d["mtp"] = {
            "h_norm": norm_descs(cfg),
            "e_norm": norm_descs(cfg),
            "proj": P((2 * cfg.d_model, cfg.d_model),
                      (None, "embed"), "fanin"),
            "layer": stack_descs({"0": KINDS[last_kind].descs(cfg)}, 1),
            "final_norm": norm_descs(cfg),
        }
    if cfg.num_encoder_layers:
        d["enc_proj"] = P((cfg.encoder_dim, cfg.d_model),
                          ("enc_dim", "embed"), "fanin")
        seg = {"0": KINDS["enc"].descs(cfg)}
        d["encoder"] = stack_descs(seg, cfg.num_encoder_layers)
        d["enc_final_norm"] = norm_descs(cfg)
    elif cfg.cross_source:   # vision: projection only, no encoder stack
        d["enc_proj"] = P((cfg.encoder_dim, cfg.d_model),
                          ("enc_dim", "embed"), "fanin")
    return d


def init_params(cfg, key):
    return init_tree(model_descs(cfg), key, cfg_param_dtype(cfg))


def param_axes(cfg):
    return axes_tree(model_descs(cfg))


def _encode(cfg, params, enc_input):
    """enc_input: (B, S_enc, encoder_dim) stub frontend output -> (B,S_enc,d)."""
    x = jnp.einsum("bse,ed->bsd", enc_input.astype(cfg_dtype(cfg)),
                   params["enc_proj"].astype(cfg_dtype(cfg)))
    if not cfg.num_encoder_layers:
        return x
    pos_table = jnp.asarray(sincos_positions(x.shape[1], cfg.d_model))
    x = x + pos_table[None].astype(x.dtype)
    ext = {"positions": jnp.broadcast_to(
        jnp.arange(x.shape[1], dtype=jnp.int32), x.shape[:2]), "ctx": None}
    kind = KINDS["enc"]

    def body(h, p_layer):
        return kind.apply(cfg, p_layer["0"], h, ext), None

    body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return apply_norm(cfg, params["enc_final_norm"], x)


def forward(cfg, params, tokens, enc_input=None):
    """Training/scoring forward. tokens: (B, S) -> logits (B, S, V)."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = embed_tokens(cfg, params["embed"], tokens, positions)
    x = constrain(x, ("batch", None, None))
    ctx = _encode(cfg, params, enc_input) if enc_input is not None else None
    ext = {"positions": positions, "ctx": ctx}

    for i, (unit, reps) in enumerate(cfg.segments):
        seg_params = params["segments"][f"seg{i}"]

        def body(h, p_layer, unit=unit):
            for j, kname in enumerate(unit):
                # remat per LAYER (not per unit): the unit backward then
                # keeps at most one layer's recomputed internals live
                apply = KINDS[kname].apply
                if cfg.remat == "full":
                    apply = jax.checkpoint(apply, static_argnums=(0,))
                h = apply(cfg, p_layer[str(j)], h, ext)
            return constrain(h, ("batch", None, None)), None

        x, _ = jax.lax.scan(body, x, seg_params)

    x = apply_norm(cfg, params["final_norm"], x)
    return unembed(cfg, params["embed"], x)


def forward_with_mtp(cfg, params, tokens, enc_input=None):
    """Training forward + MTP head: returns (logits over positions 0..S-1
    predicting t+1, mtp_logits over positions 0..S-2 predicting t+2)."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = embed_tokens(cfg, params["embed"], tokens, positions)
    x = constrain(x, ("batch", None, None))
    ctx = _encode(cfg, params, enc_input) if enc_input is not None else None
    ext = {"positions": positions, "ctx": ctx}

    for i, (unit, reps) in enumerate(cfg.segments):
        seg_params = params["segments"][f"seg{i}"]

        def body(h, p_layer, unit=unit):
            for j, kname in enumerate(unit):
                apply = KINDS[kname].apply
                if cfg.remat == "full":
                    apply = jax.checkpoint(apply, static_argnums=(0,))
                h = apply(cfg, p_layer[str(j)], h, ext)
            return constrain(h, ("batch", None, None)), None

        x, _ = jax.lax.scan(body, x, seg_params)

    h_final = x
    logits = unembed(cfg, params["embed"],
                     apply_norm(cfg, params["final_norm"], h_final))

    # --- MTP: predict token t+2 from (h_t, emb(token_{t+1})) ---
    mp = params["mtp"]
    h = apply_norm(cfg, mp["h_norm"], h_final[:, :-1])
    e_next = embed_tokens(cfg, params["embed"], tokens[:, 1:],
                          positions[:, 1:])
    e = apply_norm(cfg, mp["e_norm"], e_next)
    hcat = jnp.concatenate([h, e], axis=-1)
    hm = jnp.einsum("bsd,de->bse", hcat, mp["proj"].astype(hcat.dtype))
    hm = constrain(hm, ("batch", None, None))
    last_kind = cfg.segments[-1][0][-1]
    mtp_ext = {"positions": positions[:, 1:], "ctx": ctx}
    apply = KINDS[last_kind].apply
    if cfg.remat == "full":
        apply = jax.checkpoint(apply, static_argnums=(0,))
    hm = apply(cfg, jax.tree.map(lambda a: a[0], mp["layer"]["0"]), hm,
               mtp_ext)
    mtp_logits = unembed(cfg, params["embed"],
                         apply_norm(cfg, mp["final_norm"], hm))
    return logits, mtp_logits


def init_cache(cfg, batch: int, max_seq: int):
    cache: Dict[str, Any] = {}
    for i, (unit, reps) in enumerate(cfg.segments):
        seg = {str(j): KINDS[k].init_cache(cfg, batch, max_seq)
               for j, k in enumerate(unit)}
        cache[f"seg{i}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (reps,) + a.shape).copy()
            if reps > 1 else a[None], seg)
    return cache


def decode_step(cfg, params, cache, tokens, pos, enc_input=None,
                ctx_cacheable=True):
    """One-token decode. tokens: (B, 1); pos: scalar int32 (tokens cached).

    For cross-attn models the encoder context is assumed cached inside each
    layer's xkv cache (filled by prefill); enc_input is only used when a
    fresh context is supplied.
    """
    b = tokens.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    x = embed_tokens(cfg, params["embed"], tokens, positions)
    ctx = _encode(cfg, params, enc_input) if enc_input is not None else None
    ext = {"positions": positions, "pos": pos, "ctx": ctx}

    new_cache: Dict[str, Any] = {}
    for i, (unit, reps) in enumerate(cfg.segments):
        seg_params = params["segments"][f"seg{i}"]
        seg_cache = cache[f"seg{i}"]

        def body(h, xs, unit=unit):
            p_layer, c_layer = xs
            c_out = {}
            for j, kname in enumerate(unit):
                h, c_out[str(j)] = KINDS[kname].decode(
                    cfg, p_layer[str(j)], h, c_layer[str(j)], ext)
            return h, c_out

        x, new_seg = jax.lax.scan(body, x, (seg_params, seg_cache))
        new_cache[f"seg{i}"] = new_seg

    x = apply_norm(cfg, params["final_norm"], x)
    return unembed(cfg, params["embed"], x), new_cache


def prefill(cfg, params, cache, tokens, enc_input=None):
    """Fill caches for tokens[0..S) and return last-position logits + cache."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = embed_tokens(cfg, params["embed"], tokens, positions)
    ctx = _encode(cfg, params, enc_input) if enc_input is not None else None
    ext = {"positions": positions, "ctx": ctx}

    new_cache: Dict[str, Any] = {}
    for i, (unit, reps) in enumerate(cfg.segments):
        seg_params = params["segments"][f"seg{i}"]
        seg_cache = cache[f"seg{i}"]

        def body(h, xs, unit=unit):
            p_layer, c_layer = xs
            c_out = {}
            for j, kname in enumerate(unit):
                h, c_out[str(j)] = KINDS[kname].prefill(
                    cfg, p_layer[str(j)], h, c_layer[str(j)], ext)
            return h, c_out

        x, new_seg = jax.lax.scan(body, x, (seg_params, seg_cache))
        new_cache[f"seg{i}"] = new_seg

    x = apply_norm(cfg, params["final_norm"], x[:, -1:])
    return unembed(cfg, params["embed"], x), new_cache
