"""Shared building blocks: param descriptors, norms, RoPE, embeddings.

Parameters are plain nested dicts of jnp arrays. Every module declares its
parameters as a tree of ``P`` descriptors; ``init_tree`` materializes arrays
and ``axes_tree`` extracts the logical-axis annotations consumed by
launch/sharding.py. No flax/haiku — descriptor trees keep init, sharding and
checkpoint layout in one place.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class P:
    """Parameter descriptor: shape + logical axes + init scheme."""
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis name per dim (None = replicated)
    init: str = "normal"              # normal | zeros | ones | fanin
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _materialize(desc: P, key, dtype) -> jax.Array:
    if desc.init == "zeros":
        return jnp.zeros(desc.shape, dtype)
    if desc.init == "ones":
        return jnp.ones(desc.shape, dtype)
    if desc.init == "fanin":
        fan_in = desc.shape[-2] if len(desc.shape) >= 2 else desc.shape[-1]
        std = desc.scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, desc.shape, jnp.float32) * std).astype(dtype)
    if desc.init == "normal":
        return (jax.random.normal(key, desc.shape, jnp.float32) * desc.scale).astype(dtype)
    raise ValueError(desc.init)


def is_desc(x) -> bool:
    return isinstance(x, P)


def init_tree(tree, key, dtype) -> Any:
    """Materialize a descriptor tree into a param tree (single key fold-in walk)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_desc)
    keys = jax.random.split(key, max(len(leaves), 1))
    arrs = [_materialize(d, k, dtype) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrs)


def axes_tree(tree) -> Any:
    """Same structure as the param tree, leaves = logical-axis tuples."""
    return jax.tree.map(lambda d: d.axes, tree, is_leaf=is_desc)


def stack_descs(tree, n: int, axis_name: str = "layers"):
    """Prepend a stacked (scan) dimension of size n to every descriptor."""
    def f(d: P) -> P:
        return P((n,) + d.shape, (axis_name,) + d.axes, d.init, d.scale)
    return jax.tree.map(f, tree, is_leaf=is_desc)


def count_tree(tree) -> int:
    total = 0
    for d in jax.tree.leaves(tree, is_leaf=is_desc):
        total += int(np.prod(d.shape))
    return total


# ---------------------------------------------------------------------------
# numerics


def norm_descs(cfg, dim: Optional[int] = None):
    dim = dim or cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": P((dim,), ("embed",), "ones"),
                "bias": P((dim,), ("embed",), "zeros")}
    return {"scale": P((dim,), ("embed",), "ones")}


def apply_norm(cfg, p, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        x = x - jnp.mean(x, axis=-1, keepdims=True)
        x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
        return (x * p["scale"].astype(jnp.float32)
                + p["bias"].astype(jnp.float32)).astype(dt)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dt)


def activation(cfg, x):
    if cfg.act == "gelu":
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.silu(x)


# ---------------------------------------------------------------------------
# rotary position embeddings


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)                     # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)               # (half,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(angles)[..., :, None, :]               # (..., seq, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sincos_positions(seq: int, dim: int) -> np.ndarray:
    """Fixed sinusoidal table (whisper encoder)."""
    pos = np.arange(seq)[:, None]
    i = np.arange(dim // 2)[None, :]
    angle = pos / np.power(10_000.0, 2 * i / dim)
    table = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return table.astype(np.float32)


# ---------------------------------------------------------------------------
# embedding / unembedding


def padded_vocab(cfg) -> int:
    """Vocab padded to a multiple of 256 so the vocab dim shards on any mesh
    axis combination (e.g. whisper's 51866 -> 51968). Pad logits train toward
    -inf naturally; serving masks them."""
    return ((cfg.vocab_size + 255) // 256) * 256


def embed_descs(cfg):
    v = padded_vocab(cfg)
    d = {"tokens": P((v, cfg.d_model), ("vocab", "embed"), "normal", 0.02)}
    if not cfg.tie_embeddings:
        d["unembed"] = P((cfg.d_model, v), ("embed", "vocab"), "fanin")
    if cfg.pos_embed == "learned":
        d["positions"] = P((cfg.max_position, cfg.d_model), (None, "embed"),
                           "normal", 0.02)
    return d


def embed_tokens(cfg, p, tokens, positions=None):
    x = p["tokens"].astype(cfg_dtype(cfg))[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if cfg.pos_embed == "learned":
        assert positions is not None
        x = x + p["positions"].astype(x.dtype)[positions]
    return x


def unembed(cfg, p, x):
    if cfg.tie_embeddings:
        return jnp.einsum("...d,vd->...v", x, p["tokens"].astype(x.dtype))
    return jnp.einsum("...d,dv->...v", x, p["unembed"].astype(x.dtype))


def cfg_dtype(cfg):
    return jnp.dtype(cfg.compute_dtype)


def cfg_param_dtype(cfg):
    return jnp.dtype(cfg.param_dtype)
