"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Block: norm -> [gate branch: linear+GELU] x [input branch: linear -> causal
conv4 -> gated linear recurrence] -> output projection. The recurrence is
  r_t = sigmoid(W_r xi_t);  i_t = sigmoid(W_i xi_t)
  log_a_t = -c * softplus(Lambda) * r_t          (c = 8)
  h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t * xi_t)
run by the rg_lru kernel (Pallas on TPU, associative scan on CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import P, norm_descs, apply_norm
from repro.models.xlstm import _conv_descs, _causal_conv
from repro.kernels import ops as kops

_C = 8.0


def rglru_descs(cfg):
    d = cfg.d_model
    w = cfg.lru_width or d
    return {
        "norm": norm_descs(cfg),
        "w_gate_branch": P((d, w), ("embed", "ffn"), "fanin"),
        "w_input": P((d, w), ("embed", "ffn"), "fanin"),
        "conv": _conv_descs(w, cfg.conv1d_width),
        "w_r": P((w, w), ("ffn", "ffn_out"), "fanin"),
        "w_i": P((w, w), ("ffn", "ffn_out"), "fanin"),
        "lam": P((w,), ("ffn",), "normal", 0.6),
        "w_out": P((w, d), ("ffn", "embed"), "fanin"),
    }


def _recurrence_inputs(cfg, p, xn, conv_state=None):
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", xn,
                                  p["w_gate_branch"].astype(xn.dtype)))
    xi = jnp.einsum("bsd,dw->bsw", xn, p["w_input"].astype(xn.dtype))
    xi, new_conv = _causal_conv(p["conv"], xi, conv_state)
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xi,
                                  p["w_r"].astype(xn.dtype)).astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xi,
                                  p["w_i"].astype(xn.dtype)).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gx = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) \
        * (i * xi.astype(jnp.float32))
    return a.astype(xn.dtype), gx.astype(xn.dtype), gate, new_conv


def apply_rglru_block(cfg, p, x):
    xn = apply_norm(cfg, p["norm"], x)
    a, gx, gate, _ = _recurrence_inputs(cfg, p, xn)
    h, _ = kops.rg_lru(a, gx)
    return x + jnp.einsum("bsw,wd->bsd", h * gate, p["w_out"].astype(x.dtype))


def init_rglru_cache(cfg, batch):
    w = cfg.lru_width or cfg.d_model
    dt = jnp.dtype(cfg.compute_dtype)
    return {
        "h": jnp.zeros((batch, w), dt),
        "conv": jnp.zeros((batch, cfg.conv1d_width - 1, w), dt),
    }


def decode_rglru_block(cfg, p, x, cache):
    xn = apply_norm(cfg, p["norm"], x)
    a, gx, gate, new_conv = _recurrence_inputs(cfg, p, xn, cache["conv"])
    h, h_last = kops.rg_lru(a, gx, cache["h"])
    out = x + jnp.einsum("bsw,wd->bsd", h * gate, p["w_out"].astype(x.dtype))
    return out, {"h": h_last, "conv": new_conv}
