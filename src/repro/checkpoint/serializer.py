"""Pytree <-> key-value serialization for burst-buffer checkpoints.

Each leaf of the train-state pytree becomes one logical segment of the
checkpoint "file" for the step; the key is the tree path (stable across
resharding — shards are keyed by logical position, which is what makes
elastic restore-on-a-different-mesh exact). Optionally leaves are quantized
to blockwise int8 *on device* (kernels/quantize) before the host fetch,
halving bytes into the burst buffer; f32 scales ride along. Exact dtypes are
restored on load (quantization is applied only to leaves explicitly allowed
by the policy — by default optimizer moments, never params/step counters).
"""
from __future__ import annotations

import json
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops

QUANT_BLOCK = 2048


def tree_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(_path_str(p) for p in path)
        out.append((name, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def default_quant_policy(path: str, leaf) -> bool:
    """Quantize optimizer moments only (m/v/vr/vc); never params or scalars."""
    if np.ndim(leaf) < 2 or leaf.size < QUANT_BLOCK:
        return False
    head = path.split("/", 1)[0]
    return head in ("opt_state",) and not path.endswith("step")


def serialize_leaf(leaf, quantize: bool) -> Tuple[bytes, dict]:
    """Returns (payload bytes, metadata dict)."""
    arr = np.asarray(jax.device_get(leaf))
    meta = {"shape": list(arr.shape), "dtype": str(arr.dtype),
            "quant": False}
    if not quantize:
        # bf16 has no numpy dtype name round-trip issue under ml_dtypes;
        # store raw bytes + dtype string
        return arr.tobytes(), meta
    flat = jnp.asarray(arr).reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % QUANT_BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    q, scales = kops.quantize_blockwise(flat, block=QUANT_BLOCK)
    qb = np.asarray(jax.device_get(q)).tobytes()
    sb = np.asarray(jax.device_get(scales), np.float32).tobytes()
    meta.update(quant=True, pad=int(pad), nq=len(qb), block=QUANT_BLOCK)
    return qb + sb, meta


def deserialize_leaf(payload: bytes, meta: dict):
    shape = tuple(meta["shape"])
    dtype = np.dtype(meta["dtype"]) if meta["dtype"] != "bfloat16" else None
    if not meta["quant"]:
        if meta["dtype"] == "bfloat16":
            import ml_dtypes
            arr = np.frombuffer(payload, dtype=ml_dtypes.bfloat16)
        else:
            arr = np.frombuffer(payload, dtype=dtype)
        return arr.reshape(shape)
    nq = meta["nq"]
    q = np.frombuffer(payload[:nq], dtype=np.int8)
    scales = np.frombuffer(payload[nq:], dtype=np.float32)
    x = kops.dequantize_blockwise(jnp.asarray(q), jnp.asarray(scales),
                                  block=meta["block"])
    x = np.asarray(jax.device_get(x))
    if meta["pad"]:
        x = x[:-meta["pad"]]
    if meta["dtype"] == "bfloat16":
        import ml_dtypes
        return x.reshape(shape).astype(ml_dtypes.bfloat16)
    return x.reshape(shape).astype(meta["dtype"])


def serialize_tree(tree, quant_policy: Optional[Callable] = None
                   ) -> Tuple[Dict[str, bytes], dict]:
    """Returns ({key: payload}, manifest). Manifest records order, offsets
    (for the logical checkpoint file), and per-leaf metadata."""
    quant_policy = quant_policy or (lambda p, l: False)
    payloads: Dict[str, bytes] = {}
    manifest = {"leaves": [], "treedef": None}
    offset = 0
    for name, leaf in tree_paths(tree):
        data, meta = serialize_leaf(leaf, quant_policy(name, leaf))
        payloads[name] = data
        meta.update(name=name, offset=offset, nbytes=len(data))
        manifest["leaves"].append(meta)
        offset += len(data)
    manifest["total_bytes"] = offset
    return payloads, manifest


def deserialize_tree(target_tree, payloads: Dict[str, bytes], manifest: dict):
    """Rebuild arrays in the structure of target_tree (an example pytree,
    e.g. jax.eval_shape output or a freshly-initialized state)."""
    metas = {m["name"]: m for m in manifest["leaves"]}
    flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    leaves = []
    for path, leaf in flat:
        name = "/".join(_path_str(p) for p in path)
        meta = metas[name]
        arr = deserialize_leaf(payloads[name], meta)
        leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, [l for l in leaves])


def manifest_bytes(manifest: dict) -> bytes:
    return json.dumps(manifest).encode()


def manifest_from_bytes(data: bytes) -> dict:
    return json.loads(data.decode())
