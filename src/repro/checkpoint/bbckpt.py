"""BBCheckpointManager: async burst-buffer checkpointing for JAX training.

This is the paper's checkpointing flow mapped onto a training loop:
  1. save(step, state): serialize the sharded train state into KV segments
     and stream them into the burst buffer via the pipelined put_async /
     wait_acks path (paper Fig 4) — the only part on the critical path,
     bounded by BB ingress (DRAM write + replication ACK), not PFS.
  2. A background flush thread triggers the servers' two-phase I/O so the
     checkpoint drains to the PFS while the next compute phase runs.
  3. Recent epochs are retained in the buffer (paper §III-C) so restore()
     is served from server DRAM/SSD without touching the PFS; older epochs
     are evicted once durably flushed.
  4. restore() falls back: BB get -> BB lookup-table range read -> PFS file.

On a multi-host pod each host runs one client pinned (ISO placement) to the
co-located server, and puts only its addressable shards; here one process
plays all clients round-robin.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import serializer as ser
from repro.core.system import BurstBufferSystem


class BBCheckpointManager:
    def __init__(self, system: BurstBufferSystem, *,
                 quantize: bool = False,
                 retention: int = 2,
                 chunk_bytes: int = 4 << 20,
                 io_mode: str = "async",
                 ack_timeout: float = 60.0):
        self.system = system
        self.quantize = quantize
        self.retention = retention
        self.chunk_bytes = chunk_bytes
        self.io_mode = io_mode          # "async" | "batched" | "sync"
        self.ack_timeout = ack_timeout
        self.saved_steps: List[int] = []
        self._flush_threads: List[threading.Thread] = []
        self.metrics: Dict[int, dict] = {}

    # ------------------------------------------------------------------ save
    def save(self, step: int, state, *, blocking_flush: bool = False,
             io_mode: Optional[str] = None):
        """Ingest the state into the burst buffer; flush to PFS off-path.

        io_mode "async" (default) streams every chunk through put_async
        across all clients and barriers on wait_acks — the paper Fig 4
        pipeline, so ingest is bounded by BB ingress rather than the sum of
        per-chunk replication round-trips. "batched" additionally coalesces
        small chunks into put_batch messages. "sync" is the blocking
        one-round-trip-per-chunk baseline."""
        mode = io_mode or self.io_mode
        t0 = time.perf_counter()
        policy = ser.default_quant_policy if self.quantize else None
        payloads, manifest = ser.serialize_tree(state, policy)
        fname = f"ckpt_{step:08d}"
        clients = self.system.clients
        offset_of = {m["name"]: m["offset"] for m in manifest["leaves"]}

        i = 0
        for name, data in payloads.items():
            base = offset_of[name]
            # chunk large leaves so segments stay transport-friendly and
            # spread over servers (ketama) / pipeline nicely (iso)
            for off in range(0, max(len(data), 1), self.chunk_bytes):
                piece = data[off:off + self.chunk_bytes]
                c = clients[i % len(clients)]
                key = f"{fname}:{base + off}"
                if mode == "sync":
                    if not c.put(key, piece, file=fname, offset=base + off):
                        raise RuntimeError(
                            f"burst buffer put failed: {name}")
                else:
                    # "batched": small pieces coalesce per the client's
                    # auto threshold; large chunks stay individual puts so
                    # they keep §III-A redirect-based load balancing.
                    # "async": never coalesce.
                    c.put_async(key, piece, file=fname, offset=base + off,
                                coalesce=None if mode == "batched" else False)
                i += 1
        mb = ser.manifest_bytes(manifest)
        if mode == "sync":
            if not clients[0].put(f"{fname}.manifest:0", mb,
                                  file=f"{fname}.manifest", offset=0):
                raise RuntimeError("manifest put failed")
        else:
            clients[0].put_async(f"{fname}.manifest:0", mb,
                                 file=f"{fname}.manifest", offset=0,
                                 coalesce=None if mode == "batched" else False)
            # barrier: every client's ACK ledger must drain before the
            # checkpoint counts as ingested (paper Fig 4 thread-2)
            for c in clients:
                c.flush_batches()
            for c in clients:
                if not c.wait_acks(self.ack_timeout):
                    raise RuntimeError(
                        f"async ingest incomplete: {c.tname} "
                        f"outstanding={c.outstanding()} "
                        f"failed={c.failed_keys()}")
        ingest_s = time.perf_counter() - t0

        self.saved_steps.append(step)
        self.metrics[step] = {"ingest_s": ingest_s,
                              "bytes": manifest["total_bytes"]}

        epoch = step
        if blocking_flush:
            self.system.flush(epoch)
            self._retire(step)
        else:
            t = threading.Thread(target=self._flush_async,
                                 args=(epoch, step), daemon=True)
            t.start()
            self._flush_threads.append(t)
        return ingest_s

    def _flush_async(self, epoch: int, step: int):
        t0 = time.perf_counter()
        self.system.flush(epoch)
        self.metrics[step]["flush_s"] = time.perf_counter() - t0
        self._retire(step)

    def _retire(self, step: int):
        """Evict buffered epochs beyond the retention window (they are
        durable on the PFS by now)."""
        keep = sorted(self.saved_steps)[-self.retention:]
        for s in list(self.saved_steps):
            if s not in keep:
                self.system.evict(f"ckpt_{s:08d}")
                self.saved_steps.remove(s)

    def wait_flushes(self, timeout: float = 60.0):
        for t in self._flush_threads:
            t.join(timeout)
        self._flush_threads = []

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        if self.saved_steps:
            return max(self.saved_steps)
        # fall back to PFS directory listing
        pfs = self.system.pfs_dir
        steps = [int(f[5:13]) for f in os.listdir(pfs)
                 if f.startswith("ckpt_") and not f.endswith(".manifest")]
        return max(steps) if steps else None

    def restore(self, target_state, step: Optional[int] = None):
        """Rebuild a train state. target_state provides structure/shapes
        (e.g. a freshly-initialized state)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError("no checkpoint found")
        fname = f"ckpt_{step:08d}"
        client = self.system.clients[0]

        mb = client.get(f"{fname}.manifest:0")
        if mb is None:
            mb = self._read_fallback(client, f"{fname}.manifest", 0, None)
        manifest = ser.manifest_from_bytes(bytes(mb))

        payloads: Dict[str, bytes] = {}
        for meta in manifest["leaves"]:
            data = self._read_segment(client, fname, meta["offset"],
                                      meta["nbytes"])
            payloads[meta["name"]] = data
        return ser.deserialize_tree(target_state, payloads, manifest), step

    def _read_segment(self, client, fname: str, offset: int, nbytes: int
                      ) -> bytes:
        # fast path: buffered KV pieces (chunked on save)
        out = bytearray()
        got_all = True
        for off in range(offset, offset + max(nbytes, 1), self.chunk_bytes):
            piece = client.get(f"{fname}:{off}")
            if piece is None:
                got_all = False
                break
            out += piece
        if got_all and len(out) >= nbytes:
            return bytes(out[:nbytes])
        # lookup-table range read (post-shuffle, still no PFS)
        data = client.read_file(fname, offset, nbytes)
        if data is not None:
            return data
        # durable PFS fallback
        return self._read_fallback(client, fname, offset, nbytes)

    def _read_fallback(self, client, fname: str, offset: int,
                       nbytes: Optional[int]) -> bytes:
        path = os.path.join(self.system.pfs_dir, fname)
        with open(path, "rb") as f:
            f.seek(offset)
            return f.read(nbytes if nbytes is not None else -1)
