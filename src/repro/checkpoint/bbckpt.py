"""BBCheckpointManager: async burst-buffer checkpointing for JAX training.

This is the paper's checkpointing flow mapped onto a training loop, written
entirely against the BBFileSystem file-session API:
  1. save(step, state): serialize the sharded train state and pwrite it
     through a BBFile handle. The handle stripes chunks across clients and
     the client write pipeline (paper Fig 4) carries them; close() is the
     sync barrier and raises BBWriteError if any chunk failed — ingest is
     the only part on the training critical path, bounded by BB ingress
     (DRAM write + replication ACK), not the PFS.
  2. A background flush thread triggers the servers' two-phase I/O so the
     checkpoint drains to the PFS while the next compute phase runs.
  3. Recent epochs are retained in the buffer (paper §III-C) so restore()
     is served from server DRAM/SSD without touching the PFS; older epochs
     are evicted once durably flushed (retention eviction leaves tombstones,
     so even a direct get of a retired chunk falls through to the PFS).
  4. restore() reads through BBFile.pread, which itself falls back:
     buffered chunks -> BB lookup-table range read -> PFS file. The same
     chain covers chunks the autonomous drain engine evicted under memory
     pressure mid-training — a restore spanning drained data is byte-exact
     without the checkpoint manager knowing anything moved.

When the servers run with the drain engine enabled (the default), save()
records the cluster pressure snapshot alongside ingest timings, so training
logs show how close the buffer ran to its watermarks at each step.

io_mode maps directly onto BBFile write policies: "sync" (one replicated
round-trip per chunk), "async" (pipelined, barrier at close), "batched"
(async + write coalescing into put_batch messages).

On a multi-host pod each host runs one client pinned (ISO placement) to the
co-located server, and puts only its addressable shards; here one process
plays all clients round-robin (the BBFile handle does this internally).
"""
from __future__ import annotations

import os
import threading
import time
from typing import Callable, Dict, List, Optional

from repro.checkpoint import serializer as ser
from repro.core import telemetry
from repro.core.system import BurstBufferSystem


class BBCheckpointManager:
    def __init__(self, system: BurstBufferSystem, *,
                 quantize: bool = False,
                 retention: int = 2,
                 chunk_bytes: int = 4 << 20,
                 io_mode: str = "async",
                 ack_timeout: float = 60.0,
                 clock: Callable[[], float] = time.perf_counter):
        self.system = system
        self.quantize = quantize
        self.retention = retention
        self.chunk_bytes = chunk_bytes
        self.io_mode = io_mode          # "async" | "batched" | "sync"
        self.ack_timeout = ack_timeout
        self._clock = clock
        self.saved_steps: List[int] = []
        self._flush_threads: List[threading.Thread] = []
        self.metrics: Dict[int, dict] = {}
        # telemetry (ISSUE 9): save/restore latency histograms; save() and
        # restore() also open trace roots, so one checkpoint becomes a span
        # tree across client -> server -> replica -> manager
        self._m_save = telemetry.histogram("ckpt.save_s")
        self._m_restore = telemetry.histogram("ckpt.restore_s")

    # ------------------------------------------------------------------ save
    def save(self, step: int, state, *, blocking_flush: bool = False,
             io_mode: Optional[str] = None):
        """Ingest the state into the burst buffer; flush to PFS off-path.

        Serialized leaves are pwritten at their manifest offsets through one
        BBFile handle per artifact (data + manifest); close() is the ingest
        barrier and raises if any chunk failed to achieve a replicated ACK.
        """
        mode = io_mode or self.io_mode
        t0 = self._clock()
        policy = ser.default_quant_policy if self.quantize else None
        payloads, manifest = ser.serialize_tree(state, policy)
        fname = f"ckpt_{step:08d}"
        offset_of = {m["name"]: m["offset"] for m in manifest["leaves"]}

        # checkpoint-lane writes (ISSUE 5): the highest QoS priority — a
        # concurrent background stream can no longer queue ahead of the
        # burst on either the client dispatch queue or the server put path.
        # The trace root spans the whole ingest, so every chunk put, replica
        # hop and fs RPC below parents back to this one checkpoint.
        fs = self.system.fs()
        with telemetry.span("ckpt.save", "checkpoint", step=step):
            f = fs.open(fname, "w", policy=mode,
                        chunk_bytes=self.chunk_bytes, lane="checkpoint")
            for name, data in payloads.items():
                f.pwrite(data, offset_of[name])
            mf = fs.open(f"{fname}.manifest", "w", policy=mode,
                         lane="checkpoint")
            mf.write(ser.manifest_bytes(manifest))
            # barrier: both handles' write pipelines must drain before the
            # checkpoint counts as ingested (paper Fig 4 thread-2); the
            # manifest barrier must run even when the data barrier raises,
            # or its failed ops would leak into the next save's drain cycle
            try:
                f.close(self.ack_timeout)
            finally:
                mf.close(self.ack_timeout)
        ingest_s = self._clock() - t0
        self._m_save.observe(ingest_s)

        self.saved_steps.append(step)
        self.metrics[step] = {"ingest_s": ingest_s,
                              "bytes": manifest["total_bytes"],
                              "pressure": self.system.pressure()}

        epoch = step
        if blocking_flush:
            self.system.flush(epoch)
            self._retire(step)
        else:
            t = threading.Thread(target=self._flush_async,
                                 args=(epoch, step), daemon=True)
            t.start()
            self._flush_threads.append(t)
        return ingest_s

    def _flush_async(self, epoch: int, step: int):
        t0 = self._clock()
        with telemetry.span("ckpt.flush", "checkpoint", step=step):
            self.system.flush(epoch)
        self.metrics[step]["flush_s"] = self._clock() - t0
        self._retire(step)

    def _retire(self, step: int):
        """Evict buffered epochs beyond the retention window (they are
        durable on the PFS by now)."""
        keep = sorted(self.saved_steps)[-self.retention:]
        for s in list(self.saved_steps):
            if s not in keep:
                self.system.evict(f"ckpt_{s:08d}")
                self.saved_steps.remove(s)

    def wait_flushes(self, timeout: float = 60.0):
        for t in self._flush_threads:
            t.join(timeout)
        self._flush_threads = []

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        if self.saved_steps:
            return max(self.saved_steps)
        # fall back to PFS directory listing
        pfs = self.system.pfs_dir
        steps = [int(f[5:13]) for f in os.listdir(pfs)
                 if f.startswith("ckpt_") and not f.endswith(".manifest")]
        return max(steps) if steps else None

    def restore(self, target_state, step: Optional[int] = None, *,
                stage: bool = True):
        """Rebuild a train state. target_state provides structure/shapes
        (e.g. a freshly-initialized state). All reads go through BBFile
        handles, whose pread already prefers buffered chunks, then the
        lookup table, then the PFS.

        A retired/evicted checkpoint is STAGED first (ISSUE 4): one
        manager-coordinated bulk load pulls the PFS copy back into the
        buffer with every server re-ingesting its own domain in parallel,
        instead of the deserialization loop faulting it in one miss at a
        time. Staging is best-effort — if the manager is busy or a server
        dies mid-stage, the handle's read fallback chain still returns
        byte-exact data — and the payload handle keeps ``prefetch`` on so
        any unstaged tail is read ahead of the loop."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError("no checkpoint found")
        fname = f"ckpt_{step:08d}"
        fs = self.system.fs()
        t0 = self._clock()
        with telemetry.span("ckpt.restore", "checkpoint", step=step):
            if stage:
                # short deadline: a manager busy draining (likely, if
                # pressure is why the checkpoint was evicted) must not stall
                # the restart — the fallback chain reads byte-exact without
                # the stage
                fs.stage(fname, timeout=5.0)

            with fs.open(f"{fname}.manifest", "r") as mf:
                manifest = ser.manifest_from_bytes(mf.read())
            payloads: Dict[str, bytes] = {}
            with fs.open(fname, "r", prefetch=True) as f:
                for meta in manifest["leaves"]:
                    payloads[meta["name"]] = f.pread(meta["offset"],
                                                     meta["nbytes"])
            out = ser.deserialize_tree(target_state, payloads, manifest)
        self._m_restore.observe(self._clock() - t0)
        return out, step
