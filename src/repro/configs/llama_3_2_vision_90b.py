"""Llama-3.2-Vision-90B [hf:meta-llama/Llama-3.2-90B-Vision; unverified].
100L backbone: cross-attention to (stub) vision patch embeddings every 5th
layer. d_model=8192 64H (kv=8) d_ff=28672 vocab=128256."""
from repro.configs.base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b",
        family="vlm",
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab_size=128256,
        segments=((("attn", "attn", "attn", "attn", "cross"), 20),),
        rope_theta=5e5,
        tie_embeddings=False,
        cross_source="vision",
        encoder_seq=1601,        # vision tokens (stub patch embeddings)
        encoder_dim=1280,        # pre-projection stub dim
        optimizer="adafactor",
        grad_accum_dtype="bfloat16",
        subquadratic=False,
    )
