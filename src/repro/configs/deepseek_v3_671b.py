"""DeepSeek-V3-671B [arXiv:2412.19437; hf]. MLA attention (compressed KV),
3 dense + 58 MoE layers, 256 routed experts top-8 + 1 shared.
61L d_model=7168 128H d_ff_expert=2048 (dense 18432) vocab=129280."""
from repro.configs.base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b",
        family="moe",
        d_model=7168,
        num_heads=128,
        num_kv_heads=128,       # informational; MLA cache is latent, not per-head
        d_ff=18432,             # dense layers (first 3)
        vocab_size=129280,
        segments=(
            (("mla_dense",), 3),
            (("mla_moe",), 58),
        ),
        num_experts=256,
        top_k=8,
        d_ff_expert=2048,
        num_shared_experts=1,
        d_ff_shared=2048,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        rope_theta=1e4,
        tie_embeddings=False,
        optimizer="adafactor",
        grad_accum_dtype="bfloat16",
        subquadratic=True,      # 500k decode viable: latent cache, seq-sharded
        mtp_depth=1,
    )
