"""The (architecture x input-shape) cell matrix — single source of truth.

Used by launch/dryrun.py, the roofline analysis, and EXPERIMENTS.md. Cells
skipped per the brief's rules carry an explicit reason:
  - long_500k only for sub-quadratic archs (SSM / hybrid / SWA / latent-cache)
  - decode shapes only for archs with a decoder (all 10 here have one)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.configs.base import SHAPES, SHAPES_BY_NAME, get_config, list_configs

ARCHS: Tuple[str, ...] = (
    "starcoder2-3b",
    "deepseek-coder-33b",
    "gemma3-4b",
    "h2o-danube-1.8b",
    "deepseek-v3-671b",
    "llama4-scout-17b-a16e",
    "xlstm-350m",
    "llama-3.2-vision-90b",
    "recurrentgemma-9b",
    "whisper-large-v3",
)


@dataclasses.dataclass(frozen=True)
class Cell:
    arch: str
    shape: str
    skip: Optional[str] = None          # reason, or None if runnable

    @property
    def key(self) -> str:
        return f"{self.arch}__{self.shape}"


def skip_reason(arch: str, shape_name: str) -> Optional[str]:
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    if shape_name == "long_500k" and not cfg.subquadratic:
        return ("full-attention arch: 500k decode requires sub-quadratic "
                "attention (see DESIGN.md 'Arch-applicability')")
    if shape.kind == "decode" and not cfg.has_decoder:
        return "encoder-only arch has no decode step"
    return None


def all_cells() -> Tuple[Cell, ...]:
    return tuple(
        Cell(a, s.name, skip_reason(a, s.name))
        for a in ARCHS for s in SHAPES
    )


def runnable_cells() -> Tuple[Cell, ...]:
    return tuple(c for c in all_cells() if c.skip is None)


# Per-(arch, shape) gradient-accumulation microbatch counts for train_4k —
# chosen so per-device activation memory fits 16 GB/chip on the (16,16) mesh.
TRAIN_ACCUM = {
    "starcoder2-3b": 2,
    "deepseek-coder-33b": 8,
    "gemma3-4b": 2,
    "h2o-danube-1.8b": 2,
    "deepseek-v3-671b": 16,
    "llama4-scout-17b-a16e": 8,
    "xlstm-350m": 1,
    "llama-3.2-vision-90b": 16,
    "recurrentgemma-9b": 4,
    "whisper-large-v3": 2,
}
