"""xLSTM-350M [arXiv:2405.04517; unverified]. xLSTM[7:1] — 7 mLSTM : 1 sLSTM
blocks; no positional embeddings. 24L d_model=1024 4H vocab=50304."""
from repro.configs.base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m",
        family="ssm",
        d_model=1024,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        segments=(((("mlstm",) * 7) + ("slstm",), 3),),
        mlstm_proj_factor=2.0,
        pos_embed="none",
        tie_embeddings=True,
        param_dtype="float32",   # small model; recurrent gates are bf16-fragile
        subquadratic=True,
    )
