"""Configuration dataclasses + registry for all architectures.

A ModelConfig fully describes one architecture. Layer stacks are expressed as
``segments``: an ordered tuple of (unit, repeats) where ``unit`` is a tuple of
layer-kind names. Each segment is lowered as ONE ``lax.scan`` over ``repeats``
with the unit's layers applied in order inside the scan body — this keeps HLO
size O(sum of unit lengths) regardless of depth, which matters both for
compile time and for remat policy.

Layer kinds (see models/transformer.py registry):
  attn        global self-attention + dense MLP
  attn_local  sliding-window self-attention + dense MLP (same param shapes as attn)
  moe         self-attention + mixture-of-experts FFN (+ optional shared expert)
  mla_dense   DeepSeek MLA attention + dense MLP
  mla_moe     DeepSeek MLA attention + MoE FFN
  rglru       RG-LRU recurrent block + dense MLP (RecurrentGemma)
  mlstm       xLSTM mLSTM block (integrated up/down projection)
  slstm       xLSTM sLSTM block + FFN
  cross       self-attention + cross-attention + dense MLP (vision / decoder)
  enc         bidirectional self-attention + dense MLP (encoder)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

Segment = Tuple[Tuple[str, ...], int]  # (unit kinds, repeats)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | vlm | hybrid | audio
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    segments: Tuple[Segment, ...]
    head_dim: Optional[int] = None   # default: d_model // num_heads

    # --- attention details ---
    window_size: int = 0             # sliding window for attn_local (tokens)
    rope_theta: float = 10_000.0
    rope_theta_local: float = 10_000.0
    logit_softcap: float = 0.0       # gemma-style attention logit soft-capping

    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    num_shared_experts: int = 0
    d_ff_shared: int = 0
    router_noise: float = 0.0
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.0     # load-balance aux loss (deepseek uses bias instead)

    # --- MLA (DeepSeek-V3) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- recurrent (RG-LRU / xLSTM) ---
    conv1d_width: int = 4
    lru_width: int = 0               # default d_model
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0

    # --- encoder-decoder / cross-attention ---
    num_encoder_layers: int = 0      # whisper encoder depth
    encoder_seq: int = 0             # stub frontend sequence length (frames/patches)
    encoder_dim: int = 0             # stub frontend embedding dim (pre-projection)
    cross_source: str = ""           # "audio" | "vision" | ""

    # --- embeddings / numerics ---
    tie_embeddings: bool = True
    embed_scale: bool = False        # gemma-style sqrt(d_model) embedding scaling
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "silu"                # silu | gelu | geglu is implied by mlp kind
    mlp_gated: bool = True           # SwiGLU/GeGLU vs plain 2-layer MLP
    pos_embed: str = "rope"          # rope | learned | sincos (enc side)
    max_position: int = 532_000      # learned-pos table size if pos_embed=learned

    # --- numerics / memory policy ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    grad_accum_dtype: str = "float32"
    optimizer: str = "adamw"         # adamw | adafactor
    remat: str = "full"              # full | nothing_saveable-like policy name

    # --- capability flags (drive the cell matrix) ---
    subquadratic: bool = False       # eligible for long_500k
    has_decoder: bool = True         # decode shapes apply
    mtp_depth: int = 0               # deepseek multi-token-prediction modules

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def num_layers(self) -> int:
        return sum(len(unit) * reps for unit, reps in self.segments)

    def param_count(self) -> int:
        """Analytic parameter count (matches init; used for MODEL_FLOPS)."""
        from repro.models.registry import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.registry import count_params
        return count_params(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                        # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", "train", 4_096, 256),
    ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    ShapeConfig("decode_32k", "decode", 32_768, 128),
    ShapeConfig("long_500k", "decode", 524_288, 1),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}

# ---------------------------------------------------------------------------
# registry

_REGISTRY: dict = {}


def register(fn: Callable[[], ModelConfig]):
    cfg = fn()
    _REGISTRY[cfg.name] = cfg
    return fn


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def list_configs():
    _load_all()
    return sorted(_REGISTRY)


def _load_all():
    # import every config module once so @register side effects run
    import importlib
    for mod in (
        "starcoder2_3b", "deepseek_coder_33b", "gemma3_4b", "h2o_danube_1_8b",
        "deepseek_v3_671b", "llama4_scout_17b_a16e", "xlstm_350m",
        "llama_3_2_vision_90b", "recurrentgemma_9b", "whisper_large_v3",
    ):
        importlib.import_module(f"repro.configs.{mod}")


def reduced(cfg: ModelConfig, *, d_model: int = 64, vocab: int = 128) -> ModelConfig:
    """A tiny config of the same family/pattern for CPU smoke tests.

    Keeps one repeat of every distinct segment unit so every layer kind in the
    architecture is exercised, but shrinks widths to toy scale.
    """
    heads = 4
    kv = min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 2
    segs = tuple((unit, min(reps, 1)) for unit, reps in cfg.segments)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=16,
        d_ff=d_model * 2,
        vocab_size=vocab,
        segments=segs,
        window_size=min(cfg.window_size, 16) if cfg.window_size else 0,
        num_experts=min(cfg.num_experts, 4) if cfg.num_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        d_ff_expert=d_model * 2 if cfg.d_ff_expert else 0,
        d_ff_shared=d_model * 2 if cfg.d_ff_shared else 0,
        q_lora_rank=32 if cfg.q_lora_rank else 0,
        kv_lora_rank=16 if cfg.kv_lora_rank else 0,
        qk_nope_head_dim=16 if cfg.qk_nope_head_dim else 0,
        qk_rope_head_dim=8 if cfg.qk_rope_head_dim else 0,
        v_head_dim=16 if cfg.v_head_dim else 0,
        lru_width=d_model if cfg.lru_width else 0,
        num_encoder_layers=min(cfg.num_encoder_layers, 2),
        encoder_seq=min(cfg.encoder_seq, 16) if cfg.encoder_seq else 0,
        encoder_dim=32 if cfg.encoder_dim else 0,
        max_position=4_096,
        param_dtype="float32",
        compute_dtype="float32",
    )
