"""DeepSeek-Coder-33B [arXiv:2401.14196; hf]. Llama-arch dense GQA.
62L d_model=7168 56H (kv=8) d_ff=19200 vocab=32256."""
from repro.configs.base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b",
        family="dense",
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        d_ff=19200,
        vocab_size=32256,
        segments=((("attn",), 62),),
        rope_theta=1e5,
        tie_embeddings=False,
        optimizer="adafactor",
        subquadratic=False,
    )
