"""Whisper-large-v3 [arXiv:2212.04356; unverified]. Encoder-decoder backbone;
conv frontend is a STUB (input_specs provides post-conv frame embeddings,
(B, 1500, 1280)). 32 enc + 32 dec layers, d_model=1280 20H (MHA) d_ff=5120
vocab=51866 (padded to 51968 for sharding). Decoder positions are learned;
the 4k/32k decode shapes exercise the backbone beyond whisper's native 448-
token decoder limit (noted in DESIGN.md deviations)."""
from repro.configs.base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        family="audio",
        d_model=1280,
        num_heads=20,
        num_kv_heads=20,
        d_ff=5120,
        vocab_size=51866,
        segments=((("cross",), 32),),
        num_encoder_layers=32,
        encoder_seq=1500,
        encoder_dim=1280,
        cross_source="audio",
        norm="layernorm",
        act="gelu",
        mlp_gated=False,
        pos_embed="learned",
        max_position=33_280,    # covers decode_32k; whisper native is 448
        tie_embeddings=True,
        subquadratic=False,
    )
