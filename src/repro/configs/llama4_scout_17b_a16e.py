"""Llama-4-Scout-17B-16E [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].
MoE 16 experts top-1 + shared expert every layer; iRoPE pattern — 3 chunked
local-attention layers (RoPE) : 1 global layer (NoPE).
48L d_model=5120 40H (kv=8) d_ff_expert=8192 vocab=202048."""
from repro.configs.base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202048,
        segments=((("moe_local", "moe_local", "moe_local", "moe_nope"), 12),),
        num_experts=16,
        top_k=1,
        d_ff_expert=8192,
        num_shared_experts=1,
        d_ff_shared=8192,
        window_size=8192,
        rope_theta=5e5,
        tie_embeddings=False,
        optimizer="adafactor",
        subquadratic=False,     # global NoPE layers are full attention
    )
