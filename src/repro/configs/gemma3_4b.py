"""Gemma-3-4B [hf:google/gemma-3-*-pt; unverified]. 5:1 local:global
attention (window 1024), head_dim=256, GeGLU, 262k vocab, embed scaling.
34L d_model=2560 8H (kv=4) d_ff=10240."""
from repro.configs.base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b",
        family="dense",
        d_model=2560,
        num_heads=8,
        num_kv_heads=4,
        head_dim=256,
        d_ff=10240,
        vocab_size=262144,
        segments=(
            (("attn_local",) * 5 + ("attn",), 5),   # 5 blocks of 5L:1G = 30
            (("attn_local",), 4),                   # remainder locals = 34
        ),
        window_size=1024,
        rope_theta=1e6,
        rope_theta_local=1e4,
        act="gelu",
        embed_scale=True,
        tie_embeddings=True,
        subquadratic=True,     # local-dominant; global decode cache seq-sharded
    )
