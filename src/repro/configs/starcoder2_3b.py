"""StarCoder2-3B [arXiv:2402.19173; hf]. Dense GQA + RoPE, LayerNorm,
plain-GELU MLP. 30L d_model=3072 24H (kv=2) d_ff=12288 vocab=49152."""
from repro.configs.base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b",
        family="dense",
        d_model=3072,
        num_heads=24,
        num_kv_heads=2,
        d_ff=12288,
        vocab_size=49152,
        segments=((("attn",), 30),),
        rope_theta=1e6,
        norm="layernorm",
        act="gelu",
        mlp_gated=False,
        tie_embeddings=True,
        subquadratic=False,
    )
