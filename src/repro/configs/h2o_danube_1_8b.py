"""H2O-Danube-1.8B [arXiv:2401.16818; hf]. Llama+Mistral mix with sliding-
window attention. 24L d_model=2560 32H (kv=8) d_ff=6912 vocab=32000."""
from repro.configs.base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b",
        family="dense",
        d_model=2560,
        num_heads=32,
        num_kv_heads=8,
        d_ff=6912,
        vocab_size=32000,
        segments=((("attn_local",), 24),),
        window_size=4096,
        rope_theta=1e4,
        rope_theta_local=1e4,
        tie_embeddings=False,
        subquadratic=True,     # pure SWA
    )
