"""RecurrentGemma-9B [arXiv:2402.19427; unverified]. Griffin: RG-LRU
recurrent blocks + local attention 2:1, MQA (kv=1), window 2048.
38L d_model=4096 16H d_ff=12288 vocab=256000."""
from repro.configs.base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        d_model=4096,
        num_heads=16,
        num_kv_heads=1,
        d_ff=12288,
        vocab_size=256000,
        segments=(
            (("rglru", "rglru", "attn_local"), 12),
            (("rglru",), 2),
        ),
        window_size=2048,
        lru_width=4096,
        rope_theta=1e4,
        rope_theta_local=1e4,
        act="gelu",
        embed_scale=True,
        tie_embeddings=True,
        subquadratic=True,
    )
