"""Traffic-aware QoS engine (ISSUE 5): classification, lanes, backpressure.

The system's whole premise is that burst buffers absorb *bursty* I/O, yet
until this module every byte was treated identically: a background analysis
stream filled the same DRAM/SSD tiers as a checkpoint burst, the drain
engine shovelled it all back out, and a saturated server inbox served
checkpoint chunks strictly behind whatever background traffic arrived
first. Shi et al. (arXiv:1902.05746) show that classifying traffic and
routing non-bursty streams *around* the buffer preserves BB capacity for
the bursts that need it; Romanus et al. (arXiv:1509.05492) name contention
between concurrent workloads the central shared-burst-buffer problem.

Four pure, clock-injected policy pieces (protocol drivers live in
client.py / server.py / filesystem.py):

  - ``TrafficClassifier``: per-stream sliding-window burst detector
    (arrival rate + sequentiality) that tags a stream BURSTY, SEQUENTIAL,
    or IDLE. Streams are BURSTY until proven boring — misclassifying a
    burst as background would be the expensive mistake.
  - priority lanes + ``LaneQueue``: a weighted deficit round-robin
    scheduler over CHECKPOINT > INTERACTIVE > BACKGROUND > DRAIN lanes,
    used by the client write pipeline (which ops go on the wire next) and
    the server put path (which buffered put is applied next).
  - ``CongestionWindows``: per-lane in-flight byte windows fed by the
    occupancy that server ACKs piggyback — a saturated cluster shrinks the
    background lanes first (geometrically, by lane index) so checkpoints
    never time out behind someone else's flood.
  - ``BandwidthArbiter``: ONE per-server token bucket for all background
    byte movement (drain micro-epochs AND stage-in slices), whose refill
    throttles while foreground ingest is hot — background flush can no
    longer starve a foreground burst, and drain + stage can no longer
    each claim a full bandwidth budget.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

from . import telemetry

# stream classes
BURSTY = "bursty"            # buffer it: this is what the BB exists for
SEQUENTIAL = "sequential"    # steady + in-order: bypass to the PFS
IDLE = "idle"                # no recent arrivals

# priority lanes, highest first. DRAIN covers every background byte-mover
# (drain micro-epochs, stage-in) — it is the lane foreground never waits on.
LANE_CHECKPOINT = 0
LANE_INTERACTIVE = 1
LANE_BACKGROUND = 2
LANE_DRAIN = 3
LANES = ("checkpoint", "interactive", "background", "drain")


def lane_index(lane) -> int:
    """Accept a lane index or name; return the index."""
    if isinstance(lane, str):
        try:
            return LANES.index(lane)
        except ValueError:
            raise ValueError(f"lane must be one of {LANES}, got {lane!r}")
    i = int(lane)
    if not 0 <= i < len(LANES):
        raise ValueError(f"lane index out of range: {lane}")
    return i


@dataclass
class QoSConfig:
    enabled: bool = True
    # --- traffic classifier
    window_s: float = 0.25            # arrival-rate sliding window
    bursty_bytes_per_s: int = 24 << 20  # rate at/above which a stream is BURSTY
    seq_min_run: int = 4              # consecutive in-order writes for SEQUENTIAL
    classify_min_bytes: int = 16 << 20  # evidence before leaving BURSTY
    idle_s: float = 1.0               # no arrivals for this long -> IDLE
    auto_bypass: bool = True          # SEQUENTIAL streams write through to PFS
    # --- lane scheduler (client dispatch + server put dequeue)
    lane_weights: Tuple[int, ...] = (8, 4, 2, 1)
    quantum_bytes: int = 256 << 10    # WDRR deficit quantum
    # queued puts applied per server-loop pass: ONE, so the loop re-drains
    # its inbox between services — a freshly-arrived priority put (or its
    # replica hop) never waits out more than a single background service,
    # each of which may include a multi-ms SSD spill
    server_ops_per_tick: int = 1
    server_recv_burst: int = 256      # inbox messages drained per pass
    # --- per-lane congestion windows (client, in-flight bytes on the wire)
    window_bytes: Tuple[int, ...] = (64 << 20, 16 << 20, 4 << 20, 4 << 20)
    window_floor: int = 64 << 10      # a lane is never fully closed
    low_occupancy: float = 0.50       # below this: full windows
    high_occupancy: float = 0.95      # at/above this: background at the floor
    # --- unified background-bandwidth arbiter (drain + stage, per server)
    hot_bytes_per_s: int = 96 << 20   # foreground rate that throttles background
    arb_hot_frac: float = 0.25        # background refill fraction while hot


class RateWindow:
    """Sliding-window byte-rate tracker (pure; injected clock). One
    implementation for every arrival-rate signal in the system: the
    per-stream classifier, the arbiter's foreground-hot detector, and the
    drain engine's burst detector all note (t, nbytes) events and ask for
    the windowed rate."""

    __slots__ = ("window_s", "_events", "_bytes")

    def __init__(self, window_s: float):
        self.window_s = window_s
        self._events: collections.deque = collections.deque()
        self._bytes = 0

    def note(self, nbytes: int, now: float):
        self._events.append((now, nbytes))
        self._bytes += nbytes
        self.trim(now)

    def trim(self, now: float):
        horizon = now - self.window_s
        dq = self._events
        while dq and dq[0][0] < horizon:
            self._bytes -= dq.popleft()[1]

    def rate(self, now: float) -> float:
        self.trim(now)
        return self._bytes / max(self.window_s, 1e-9)


class TrafficClassifier:
    """Per-stream burst detector (pure; injected clock).

    ``observe(offset, nbytes)`` on every write; ``classify()`` returns the
    stream's current class. A stream is BURSTY by default and stays so
    until it has produced ``classify_min_bytes`` of evidence AND its
    sliding-window arrival rate sits below ``bursty_bytes_per_s`` AND its
    writes form an in-order run of ``seq_min_run`` — only then is it
    SEQUENTIAL (steady, PFS-friendly, safe to route around the buffer).
    Misrouting a checkpoint to the PFS is the expensive mistake, so the
    default errs toward buffering."""

    def __init__(self, cfg: QoSConfig, now: Optional[float] = None):
        self.cfg = cfg
        now = time.monotonic() if now is None else now
        self._window = RateWindow(cfg.window_s)
        self._next_offset: Optional[int] = None
        self._run = 0
        self._total = 0
        self._last_arrival = now - 2 * cfg.idle_s   # fresh stream: IDLE
        self.stats = {"observed": 0, "observed_bytes": 0}

    def observe(self, offset: int, nbytes: int,
                now: Optional[float] = None):
        now = time.monotonic() if now is None else now
        self.stats["observed"] += 1
        self.stats["observed_bytes"] += nbytes
        self._window.note(nbytes, now)
        self._total += nbytes
        self._last_arrival = now
        if offset == self._next_offset or self._next_offset is None:
            self._run += 1
        else:
            self._run = 1                   # a seek breaks the run
        self._next_offset = offset + nbytes

    def rate(self, now: Optional[float] = None) -> float:
        now = time.monotonic() if now is None else now
        return self._window.rate(now)

    def classify(self, now: Optional[float] = None) -> str:
        now = time.monotonic() if now is None else now
        if now - self._last_arrival >= self.cfg.idle_s:
            return IDLE
        if self.rate(now) >= self.cfg.bursty_bytes_per_s:
            return BURSTY
        if self._total >= self.cfg.classify_min_bytes \
                and self._run >= self.cfg.seq_min_run:
            return SEQUENTIAL
        return BURSTY


class LaneQueue:
    """Weighted deficit round robin over the priority lanes.

    Entries are opaque; each is pushed with its byte cost. ``pop`` serves
    lanes highest-priority-first, each lane consuming deficit credit
    replenished in proportion to its weight — under full backlog the lanes
    share bytes ``lane_weights``-proportionally, and an empty lane banks
    nothing (its deficit resets). ``can_pop(lane, nbytes)`` lets the
    caller veto a lane (congestion-window gating); a vetoed lane is simply
    skipped, never charged."""

    def __init__(self, weights: Sequence[int] = QoSConfig.lane_weights,
                 quantum: int = QoSConfig.quantum_bytes):
        self.weights = tuple(weights)
        self.quantum = quantum
        self._qs: List[collections.deque] = \
            [collections.deque() for _ in self.weights]
        self._deficit = [0] * len(self.weights)
        self._count = 0

    def __len__(self) -> int:
        return self._count

    def push(self, lane: int, item, nbytes: int):
        self._qs[lane].append([item, nbytes])
        self._count += 1

    def pop(self, can_pop: Optional[Callable[[int, int], bool]] = None):
        """Next entry by WDRR, or None when empty / every lane is vetoed."""
        if self._count == 0:
            return None
        eligible: List[Tuple[int, int]] = []    # (lane, head nbytes)
        for lane, q in enumerate(self._qs):
            if not q:
                self._deficit[lane] = 0         # no banking while empty
                continue
            nbytes = q[0][1]
            if can_pop is not None and not can_pop(lane, nbytes):
                continue
            if self._deficit[lane] >= nbytes:
                return self._take(lane)
            eligible.append((lane, nbytes))
        if not eligible:
            return None
        # nobody's deficit covers its head: advance every eligible lane by
        # the same number of quantum rounds — the fewest that unblocks one —
        # so weighted fairness is preserved and pop() always serves an
        # eligible entry (a 1 MB batch must not wedge behind a tiny quantum)
        def rounds(lane: int, nbytes: int) -> int:
            per = max(1, self.weights[lane] * self.quantum)
            return -(-(nbytes - self._deficit[lane]) // per)
        lane, _ = min(eligible, key=lambda e: (rounds(*e), e[0]))
        r = rounds(lane, self._qs[lane][0][1])
        for other, _nb in eligible:
            self._deficit[other] += r * self.weights[other] * self.quantum
        return self._take(lane)

    def _take(self, lane: int):
        item, nbytes = self._qs[lane].popleft()
        self._count -= 1
        if self._qs[lane]:
            self._deficit[lane] -= nbytes
        else:
            self._deficit[lane] = 0
        return item

    def discard(self, pred: Callable) -> int:
        """Drop entries matching ``pred(item)`` (abandon/teardown path).
        Returns how many were removed."""
        removed = 0
        for lane, q in enumerate(self._qs):
            keep = collections.deque(e for e in q if not pred(e[0]))
            removed += len(q) - len(keep)
            self._qs[lane] = keep
        self._count -= removed
        return removed

    def entries(self) -> List:
        """Every queued item (introspection / teardown)."""
        return [e[0] for q in self._qs for e in q]


class CongestionWindows:
    """Per-lane in-flight byte windows driven by piggybacked occupancy.

    Server ACKs carry the store's occupancy fraction; an EWMA of those
    reports scales each lane's window by ``f ** lane`` where ``f`` falls
    linearly from 1 (at ``low_occupancy``) to 0 (at ``high_occupancy``) —
    so a saturating cluster closes the DRAIN lane first, then BACKGROUND,
    then INTERACTIVE, while the CHECKPOINT lane (exponent 0) keeps its
    full window: the buffer's job is absorbing exactly that burst."""

    EWMA = 0.3

    def __init__(self, cfg: QoSConfig, owner: str = ""):
        self.cfg = cfg
        self._occ = 0.0
        # telemetry (ISSUE 9): the EWMA doubles as the cluster-pressure
        # gauge, labeled by the owning client (no-op when disabled)
        self._owner = owner
        self._g_occ = telemetry.gauge("qos.occupancy_ewma")

    def on_pressure(self, occupancy: float):
        self._occ += self.EWMA * (float(occupancy) - self._occ)
        self._g_occ.set(self._occ, label=self._owner)

    def occupancy(self) -> float:
        return self._occ

    def window(self, lane: int) -> int:
        lo, hi = self.cfg.low_occupancy, self.cfg.high_occupancy
        if self._occ <= lo:
            f = 1.0
        elif self._occ >= hi:
            f = 0.0
        else:
            f = (hi - self._occ) / (hi - lo)
        scale = f ** lane            # lane 0 -> 1.0 always
        return max(self.cfg.window_floor,
                   int(self.cfg.window_bytes[lane] * scale))


class BandwidthArbiter:
    """ONE background-bandwidth budget per server, shared by the drain and
    stage engines (pre-QoS each had its own: the drain engine a token
    bucket, the stage engine an unmetered per-tick byte cap — together
    they could claim twice the intended background bandwidth against a
    foreground burst). Token bucket whose refill rate drops to
    ``arb_hot_frac`` while foreground ingest runs at/above
    ``hot_bytes_per_s`` — absorption wins while the burst lasts, and the
    full rate returns the moment it ends. ``take`` may overdraw (progress
    needs at least one segment/slice per epoch); ``peek`` then reports 0
    until the refill pays the debt, which is what enforces the average
    cap. ``refund`` gives an aborted epoch's debit back, clamped at one
    bucket."""

    def __init__(self, cfg: QoSConfig, rate_bytes_per_s: int,
                 now: Optional[float] = None):
        self.cfg = cfg
        self.rate = float(rate_bytes_per_s)
        now = time.monotonic() if now is None else now
        self._tokens = self.rate            # start full: first burst drains
        self._token_t = now
        self._fg = RateWindow(cfg.window_s)
        self.stats = {"granted_bytes": 0, "refunded_bytes": 0,
                      "throttled_s": 0.0}

    # ------------------------------------------------------- foreground load
    def note_foreground(self, nbytes: int, now: Optional[float] = None):
        now = time.monotonic() if now is None else now
        self._fg.note(nbytes, now)

    def foreground_hot(self, now: Optional[float] = None) -> bool:
        now = time.monotonic() if now is None else now
        return self._fg.rate(now) >= self.cfg.hot_bytes_per_s

    # ----------------------------------------------------------- token bucket
    def _refill(self, now: float):
        rate = self.rate
        if self.foreground_hot(now):
            rate *= self.cfg.arb_hot_frac
            # accumulate throttled WALL TIME, not call count — peek() runs
            # every server-loop pass, so a per-call counter would measure
            # loop frequency rather than throttling
            self.stats["throttled_s"] += max(0.0, now - self._token_t)
        self._tokens = min(self.rate,
                           self._tokens + (now - self._token_t) * rate)
        self._token_t = now

    def peek(self, now: Optional[float] = None) -> int:
        now = time.monotonic() if now is None else now
        self._refill(now)
        return max(0, int(self._tokens))

    def take(self, nbytes: int, now: Optional[float] = None) -> int:
        now = time.monotonic() if now is None else now
        self._refill(now)
        self._tokens = max(self._tokens - int(nbytes), -self.rate)
        self.stats["granted_bytes"] += int(nbytes)
        return int(nbytes)

    def refund(self, nbytes: int):
        self._tokens = min(self.rate, self._tokens + nbytes)
        self.stats["refunded_bytes"] += nbytes
