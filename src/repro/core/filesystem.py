"""File-session API over the burst buffer: BBFileSystem / BBFile / BBFuture.

The paper presents the burst buffer as a *file* abstraction — checkpoints
are striped across SSD servers and gradually flushed to Lustre — and
BurstFS/UnifyFS converge on the same shape: a mount-like interface with
explicit sync barriers. This module is that client-facing surface:

  fs = system.fs()
  f = fs.open("ckpt_00000001", "w", policy="batched")
  fut = f.pwrite(data, offset)      # returns a BBFuture
  f.sync()                          # barrier: raises on any failed write
  f.close()

A ``BBFile`` handle stripes data into fixed-size chunks, round-robins them
over the system's clients, and routes every chunk through the client's
single internal ``WriteOp`` pipeline (client.py). Each write returns a
``BBFuture``; per-op failures surface as exceptions on the future or on the
``sync()``/``close()`` barrier — there is no shared last-failed list to
race on.

Write policies:
  "sync"     one replicated round-trip per chunk (blocking)
  "async"    pipelined through the ACK ledger, one barrier at sync()
  "batched"  async + small chunks coalesced into put_batch messages
  "through"  QoS write-through bypass (ISSUE 5): bytes go straight to the
             durable PFS copy, never occupying the buffer; servers get
             metadata-only residency reports so reads stay transparent.
             Streams the per-handle traffic classifier tags SEQUENTIAL
             take this route automatically (unless policy is "sync").
Handles also carry a QoS ``lane`` (checkpoint > interactive > background)
that orders their chunks against other traffic end to end.

Reads assemble a byte range from three sources, freshest first: buffered
chunks via the servers' per-file manifests, post-flush lookup-table range
reads, and finally the durable PFS copy. The read side is parallel
(ISSUE 4): manifest chunk fetches and gap fills fan out across threads and
round-robin over the system's clients instead of serially hammering one
endpoint, ``fs.stage(path)`` bulk-loads an evicted file back into the
buffer through the manager-coordinated stage-in protocol, and a handle
opened with ``prefetch=True`` detects sequential reads and stages the next
window ahead of the reader.
"""
from __future__ import annotations

import itertools
import os
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import locktrack, qos, staging, telemetry
from repro.core.qos import QoSConfig
from repro.core.staging import StageConfig

POLICIES = ("sync", "async", "batched", "through")


class BBError(RuntimeError):
    """Base class for burst-buffer file/write errors."""


class BBWriteError(BBError):
    """A write op exhausted its retries or had no live server to go to."""

    def __init__(self, keys, reason: str = "write failed"):
        self.keys = [keys] if isinstance(keys, str) else list(keys)
        super().__init__(f"{reason}: {self.keys}")


class BBFuture:
    """Completion handle for one write op (or a gather of several).

    done()/result()/exception() follow concurrent.futures semantics:
    ``result`` re-raises the op's failure, ``exception`` returns it.
    Completion is first-win — a late ACK for an op that already failed
    (abandoned, timed out) is ignored.
    """

    __slots__ = ("key", "_done", "_result", "_exc", "_cbs", "_event",
                 "_lock")

    def __init__(self, key: Optional[str] = None):
        self.key = key
        self._done = False
        self._result = None
        self._exc: Optional[BaseException] = None
        self._cbs: Optional[List] = None
        # the Event is allocated lazily, only when a thread actually has to
        # block: on the hot ingest path most futures resolve before anyone
        # waits, and per-op Event allocation + set() is measurable overhead
        self._event: Optional[threading.Event] = None
        self._lock = threading.Lock()

    # -------------------------------------------------------------- completion
    def _finish(self, result, exc) -> bool:
        """First-win completion. Returns False when the future was already
        done (the late result is discarded) so callers can tell whether
        their outcome actually took effect."""
        with self._lock:
            if self._done:
                return False
            self._result, self._exc = result, exc
            self._done = True
            cbs, self._cbs = self._cbs, None
            ev = self._event
        if ev is not None:
            ev.set()
        if cbs:
            for cb in cbs:
                cb(self)
        return True

    def _set_result(self, value) -> bool:
        return self._finish(value, None)

    def _set_exception(self, exc: BaseException) -> bool:
        return self._finish(None, exc)

    # ------------------------------------------------------------------- query
    def done(self) -> bool:
        return self._done

    def _wait(self, timeout: Optional[float]) -> bool:
        if self._done:
            return True
        with self._lock:
            if self._done:
                return True
            if self._event is None:
                self._event = threading.Event()
            ev = self._event
        return ev.wait(timeout)

    def result(self, timeout: Optional[float] = None):
        if not self._wait(timeout):
            raise TimeoutError(f"write not acknowledged: {self.key}")
        if self._exc is not None:
            raise self._exc
        return self._result

    def exception(self, timeout: Optional[float] = None
                  ) -> Optional[BaseException]:
        if not self._wait(timeout):
            raise TimeoutError(f"write not acknowledged: {self.key}")
        return self._exc

    def add_done_callback(self, cb):
        with self._lock:
            if not self._done:
                if self._cbs is None:
                    self._cbs = []
                self._cbs.append(cb)
                return
        cb(self)

    @classmethod
    def gather(cls, futures: List["BBFuture"]) -> "BBFuture":
        """A future that resolves once every input does; fails on the first
        input failure (first-win, like the per-op futures)."""
        g = cls(key=None)
        if not futures:
            g._set_result(True)
            return g
        remaining = [len(futures)]
        lock = threading.Lock()

        def _cb(f: "BBFuture"):
            exc = f._exc
            if exc is not None:
                g._set_exception(exc)
                return
            with lock:
                remaining[0] -= 1
                last = remaining[0] == 0
            if last:
                g._set_result(True)

        for f in futures:
            f.add_done_callback(_cb)
        return g


@dataclass(eq=False)      # identity semantics: ops live in sets/buffers
class WriteOp:
    """One chunk travelling the client write pipeline. Every put — blocking,
    pipelined, or coalesced — is a WriteOp; the policy knobs only change how
    it is shipped and awaited. ``lane`` is the QoS priority lane (ISSUE 5):
    it orders the op against other traffic on the client dispatch queue and
    the server put path, and counts it against that lane's congestion
    window while on the wire."""
    key: str
    value: bytes
    file: Optional[str]
    offset: int
    future: BBFuture
    lane: int = qos.LANE_INTERACTIVE
    redirects: int = 0
    attempts: int = 0
    msg_id: Optional[int] = None     # current in-flight message, if any
    counted: bool = False            # held against the lane window right now
    # telemetry stamps (ISSUE 9), set only while telemetry is enabled:
    parked_at: float = 0.0           # when the op entered the lane queue
    issued_at: float = 0.0           # when it last went on the wire
    # trace context captured when the op parked (ISSUE 10): the dispatch
    # pump runs on another thread with no span of its own, so the lane
    # wait is attributed back to the submitting span through this
    trace_ctx: Optional[list] = None


class BBFile:
    """An open burst-buffer file. Write calls stripe into chunks keyed
    ``{path}:{offset}`` (so prefix eviction and the two-phase flush see the
    same namespace as the legacy KV API) and return BBFutures; ``sync()``
    flushes coalesce buffers and raises if any chunk failed.

    Mode "w" truncates an existing incarnation. Rewriting the same offset
    with the same striping is last-writer-wins (chunks share a key);
    PARTIALLY overlapping writes at different offsets have no defined
    recency across servers — write aligned, non-overlapping ranges."""

    def __init__(self, fs: "BBFileSystem", path: str, mode: str, *,
                 policy: str = "async", chunk_bytes: Optional[int] = None,
                 prefetch: Optional[bool] = None, lane=None):
        if mode not in ("r", "w", "a"):
            raise ValueError(f"mode must be r/w/a, got {mode!r}")
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}")
        if policy == "through" and not fs.pfs_dir:
            raise ValueError("policy='through' needs a PFS directory")
        self.fs = fs
        self.path = path
        self.mode = mode
        self.policy = policy
        self.chunk_bytes = chunk_bytes or fs.chunk_bytes
        # QoS (ISSUE 5): the stream's priority lane, and a per-stream
        # traffic classifier — SEQUENTIAL (steady, in-order, sub-burst-rate)
        # streams are routed around the buffer entirely (write-through to
        # the PFS) so BB capacity stays free for the bursts that need it
        self.lane = qos.lane_index(lane if lane is not None
                                   else fs.lane_default)
        self._clf = qos.TrafficClassifier(fs.qos_cfg) \
            if fs.qos_cfg.enabled and mode != "r" else None
        self.bypassed_bytes = 0
        self._thru_fh = None           # cached PFS handle (bypass writes)
        self._thru_run: Optional[List[int]] = None   # unreported [lo, hi)
        # read-ahead (ISSUE 4): sequential-access detection on positional
        # reads issues asynchronous stage-ins of the next window
        if prefetch is None:
            prefetch = fs.prefetch_default
        self._ra = staging.ReadAhead(fs.stage_cfg) \
            if prefetch and fs.stage_cfg.enabled else None
        self._pos = 0
        self._size = 0
        self._rr = 0                       # round-robin cursor over clients
        self._futures: List[BBFuture] = []
        # offset -> (key, length, holder servers), merged across servers
        self._chunks: Optional[Dict[int, Tuple]] = None
        self._closed = False
        if mode == "r":
            st = fs.stat(path)
            self._size = st["size"]
        elif mode == "a":
            try:
                self._size = fs.stat(path)["size"]
            except FileNotFoundError:
                self._size = 0
            self._pos = self._size

    # ----------------------------------------------------------------- helpers
    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def _check_open(self, writing: bool):
        if self._closed:
            raise ValueError(f"I/O on closed file {self.path!r}")
        if writing and self.mode == "r":
            raise ValueError(f"file {self.path!r} opened read-only")

    def seek(self, pos: int) -> int:
        self._pos = max(0, pos)
        return self._pos

    def tell(self) -> int:
        return self._pos

    @property
    def size(self) -> int:
        return self._size

    # ------------------------------------------------------------------ writes
    def write(self, data: bytes) -> BBFuture:
        """Append at the cursor; returns a future for the whole write."""
        fut = self.pwrite(data, self._pos)
        self._pos += len(data)
        return fut

    def pwrite(self, data: bytes, offset: int) -> BBFuture:
        """Positional write: stripe ``data`` into chunks and submit each to
        the next client's write pipeline. Under policy "sync" each chunk
        blocks on its replicated ACK (raising on failure); otherwise the
        returned future completes when every chunk of this call does.

        QoS routing (ISSUE 5): a handle opened with ``policy="through"``,
        or one whose traffic classifier has tagged the stream SEQUENTIAL
        (steady, in-order, below the burst rate), writes straight to the
        PFS — the bytes never occupy the buffer, and residency metadata
        registered with the servers keeps reads transparent."""
        self._check_open(writing=True)
        if self._clf is not None:
            self._clf.observe(offset, len(data))
        if self.policy == "through" or (
                self._clf is not None and self.fs.qos_cfg.auto_bypass
                and self.fs.pfs_dir and self.policy != "sync"
                and self.lane != qos.LANE_CHECKPOINT   # bursts stay buffered
                and self._clf.classify() == qos.SEQUENTIAL):
            return self._pwrite_through(data, offset)
        # a pending bypass run must be reported BEFORE a buffered write
        # ships: servers evict chunks a run covers, so a report chasing a
        # fresher buffered rewrite of the same range would evict new bytes
        self._flush_bypass_report()
        clients = self.fs.clients
        # "batched" forces coalescing (a chunk at/above batch_bytes still
        # ships immediately as its own batch); other policies pipeline
        # each chunk individually so §III-A redirects stay available
        coalesce = True if self.policy == "batched" else False
        futs: List[BBFuture] = []
        for off in range(0, max(len(data), 1), self.chunk_bytes):
            piece = bytes(data[off:off + self.chunk_bytes])
            c = clients[self._rr % len(clients)]
            self._rr += 1
            key = f"{self.path}:{offset + off}"
            fut = c.submit(key, piece, file=self.path, offset=offset + off,
                           coalesce=coalesce, lane=self.lane)
            if self.policy == "sync":
                try:
                    fut.result(c.sync_put_timeout())
                except TimeoutError:
                    c.abandon_by_future(fut)   # wedged op must not linger
                    c._consume_failed(key)
                    raise
                except BBWriteError:
                    c._consume_failed(key)     # observed here, not at drain
                    raise
            futs.append(fut)
        self._size = max(self._size, offset + len(data))
        self._futures.extend(futs)
        self._chunks = None    # read-after-write must see the new chunks
        return futs[0] if len(futs) == 1 else BBFuture.gather(futs)

    # report a bypass run to the servers once it grows this large (or on
    # sync/close, or when the stream seeks) — metadata stays timely without
    # a per-write broadcast
    BYPASS_REPORT_BYTES = 8 << 20

    def _pwrite_through(self, data: bytes, offset: int) -> BBFuture:
        """Write-through bypass (ISSUE 5): the bytes go straight to the
        durable PFS copy — zero BB occupancy, no replication traffic, no
        later drain work — and the write is durable when this returns, so
        the future is already complete. The servers get a metadata-only
        ``bypass_report`` per contiguous run: every one max-merges the
        file's lookup-table size (range reads cover the extent) and the
        run's placement owner records an eviction tombstone, making a
        bypassed run indistinguishable from a drained-and-evicted chunk on
        the read path. The PFS handle is cached on the BBFile (one open
        per stream, not per write) and flushed per write so concurrent
        readers of the durable copy always see the bytes."""
        fs = self.fs
        if self._thru_fh is None:
            with fs._pfs_lock:
                p = os.path.join(fs.pfs_dir, self.path)
                self._thru_fh = open(p, "r+b" if os.path.exists(p)
                                     else "w+b")
        self._thru_fh.seek(offset)
        self._thru_fh.write(data)
        self._thru_fh.flush()
        # many BBFile handles (one per writer thread) share these counters
        with fs._pfs_lock:
            fs.bypass_stats["writes"] += 1
            fs.bypass_stats["bytes"] += len(data)
        hi = offset + len(data)
        if self._thru_run is not None and offset == self._thru_run[1]:
            self._thru_run[1] = hi
        else:
            self._flush_bypass_report()
            self._thru_run = [offset, hi]
        if self._thru_run[1] - self._thru_run[0] >= self.BYPASS_REPORT_BYTES:
            self._flush_bypass_report()
        self.bypassed_bytes += len(data)
        self._size = max(self._size, hi)
        self._chunks = None
        fut = BBFuture(f"{self.path}:{offset}")
        fut._set_result(True)
        return fut

    def _flush_bypass_report(self):
        run, self._thru_run = self._thru_run, None
        if run is not None:
            self.fs._report_bypass(self.path, run[0], run[1] - run[0],
                                   self.chunk_bytes)

    def sync(self, timeout: float = 60.0) -> "BBFile":
        """Barrier (paper Fig 4 thread-2 drain, per handle): flush every
        client's coalesce buffer, wait for all of this handle's outstanding
        futures, and raise BBWriteError listing the failed chunk keys if any
        write did not achieve a replicated ACK."""
        self._flush_bypass_report()     # bypassed runs: metadata barrier
        for c in self.fs.clients:
            c.flush_coalesced()
        deadline = self.fs._clock() + timeout
        failed: List[str] = []
        try:
            for f in self._futures:
                remaining = max(0.0, deadline - self.fs._clock())
                exc = f.exception(remaining)   # raises TimeoutError on expiry
                if exc is not None:
                    failed.append(f.key if f.key is not None else "<gather>")
        except TimeoutError:
            # abandon the stragglers and consume everything this barrier
            # observed, mirroring BBClient.drain()'s timeout behaviour —
            # an errored handle must not poison a later drain cycle
            for g in self._futures:
                if not g.done():
                    for c in self.fs.clients:
                        if c.abandon_by_future(g):
                            break
            for key in failed:
                for c in self.fs.clients:
                    c._consume_failed(key)
            self._futures = []
            raise
        self._futures = []
        if failed:
            # the failure is observed HERE, on this barrier — consume it so
            # it cannot also fail a later legacy wait_acks()/drain() cycle
            for key in failed:
                for c in self.fs.clients:
                    c._consume_failed(key)
            raise BBWriteError(failed, "sync barrier found failed writes")
        self.fs._register_sync(self.path, self._size)
        # an autonomous drain may have evicted or re-tiered chunks while the
        # barrier waited; re-merge the manifests on the next read
        self._chunks = None
        return self

    def close(self, timeout: float = 60.0):
        """Sync (for writable handles) and invalidate the handle."""
        if self._closed:
            return
        try:
            if self.mode != "r":
                self.sync(timeout)
        finally:
            self._closed = True
            if self._thru_fh is not None:
                self._thru_fh.close()
                self._thru_fh = None

    # ------------------------------------------------------------------- reads
    def read(self, n: int = -1) -> bytes:
        if n < 0:
            n = max(0, self._size - self._pos)
        data = self.pread(self._pos, n)
        self._pos += len(data)
        return data

    def pread(self, offset: int, length: int) -> bytes:
        """Positional read, freshest source first:
          1. buffered chunks located via the servers' per-file manifests
             (individual gets are replica-aware, so this survives failover),
          2. post-flush lookup-table range read (paper §III-C),
          3. the durable PFS copy.
        Chunk fetches and gap fills fan out over ``fs.read_fanout`` threads
        and round-robin across the system's clients (ISSUE 4) — a restart-
        sized read keeps every server busy instead of one.
        """
        self._check_open(writing=False)
        # POSIX short-read semantics at EOF: never fabricate zero bytes
        # beyond the known size
        length = min(length, max(0, self._size - offset))
        if length <= 0:
            return b""
        if self._ra is not None:
            win = self._ra.observe(offset, length, self._size)
            if win is not None:
                # true fire-and-forget read-ahead: the request runs off a
                # daemon thread so a slow or dead manager never stalls the
                # reading thread; a rejection (manager busy with a drain
                # epoch) simply costs the prefetch
                threading.Thread(
                    target=self.fs.stage,
                    args=(self.path, win[0], win[1] - win[0]),
                    kwargs={"wait": False}, daemon=True,
                    name="bb-readahead").start()
                # staged chunks land in the servers' manifests; drop the
                # cached merge so subsequent reads see them (triggers fire
                # every half window, so staleness is bounded by design)
                self._chunks = None
        out = bytearray(length)
        covered: List[List[int]] = []
        chunks = self._chunk_map()
        jobs = []                            # (base, key, ln, holders, lo, hi)
        for base in sorted(chunks):
            key, ln, holders = chunks[base]
            lo, hi = max(offset, base), min(offset + length, base + ln)
            if lo < hi:
                jobs.append((base, key, ln, holders, lo, hi))

        def _fetch(job):
            base, key, ln, holders, _lo, _hi = job
            client = self.fs.next_client()
            for server in holders:           # primary + replicas
                piece = client.get_at(server, key)
                if piece is not None and len(piece) == ln:
                    return piece
                # wrong length = stale replica of a same-offset rewrite;
                # a raw slice-assign would silently RESIZE the bytearray
            return None                      # evicted/unreachable: fall back

        pieces = staging.parallel_map(_fetch, jobs, self.fs.read_fanout)
        # assembly stays in ascending-offset order: overlap resolution is
        # deterministic (chunks at the SAME offset are last-writer-wins via
        # their shared key; partially-overlapping writes at different
        # offsets have no cross-server recency order — avoid them)
        for (base, _key, _ln, _holders, lo, hi), piece in zip(jobs, pieces):
            if piece is None:
                continue
            out[lo - offset:hi - offset] = piece[lo - base:hi - base]
            covered.append([lo, hi])
        missing = _gaps(_merge(covered), offset, offset + length)
        if not missing:
            return bytes(out)

        def _fill(gap):
            lo, hi = gap
            data = self.fs.next_client().read_file(self.path, lo, hi - lo)
            if data is None:
                data = self._pread_pfs(lo, hi - lo)
            return data

        fills = staging.parallel_map(_fill, missing, self.fs.read_fanout)
        for (lo, hi), data in zip(missing, fills):
            if data is None or len(data) < hi - lo:
                # a short fallback read would silently zero-fill — the range
                # is inside the known size, so this is real data loss
                raise BBError(
                    f"unreadable range [{lo}, {hi}) of {self.path!r}")
            out[lo - offset:lo - offset + len(data)] = data
        return bytes(out)

    def _chunk_map(self) -> Dict[int, Tuple]:
        if self._chunks is None:
            self._chunks = self.fs.next_client().file_chunks(self.path)
        return self._chunks

    def _pread_pfs(self, offset: int, length: int) -> Optional[bytes]:
        path = os.path.join(self.fs.pfs_dir, self.path) \
            if self.fs.pfs_dir else None
        if path is None or not os.path.exists(path):
            return None
        with open(path, "rb") as f:
            f.seek(offset)
            return f.read(length)


class BBFileSystem:
    """Mount-like facade over a set of burst-buffer clients.

    One BBFileSystem per application (``system.fs()``); handles from
    ``open()`` share the clients and stripe across them. The manager keeps
    the namespace registry (fs_open/fs_sync), so ``listdir``/``exists``
    reflect every client's files, not just this process's."""

    def __init__(self, clients, *, chunk_bytes: int = 4 << 20,
                 pfs_dir: Optional[str] = None, manager: str = "manager",
                 read_fanout: int = 4, stage: Optional[StageConfig] = None,
                 prefetch: bool = False, qos_cfg: Optional[QoSConfig] = None,
                 lane_default="interactive", control_timeout: float = 1.0,
                 clock: Callable[[], float] = time.monotonic):
        if not clients:
            raise ValueError("BBFileSystem needs at least one client")
        self._clock = clock
        self.clients = list(clients)
        self.chunk_bytes = chunk_bytes
        self.pfs_dir = pfs_dir
        self.manager = manager
        self.read_fanout = max(1, read_fanout)
        self.stage_cfg = stage or StageConfig()
        self.prefetch_default = prefetch
        self.qos_cfg = qos_cfg or QoSConfig()
        self.lane_default = lane_default
        # one knob for every manager/control RPC deadline, mirroring the
        # ISSUE 4 read_timeout cleanup (was a scatter of hardcoded 1.0s)
        self.control_timeout = control_timeout
        # bypass writers share PFS files
        self._pfs_lock = locktrack.lock("BBFileSystem._pfs_lock")
        self.bypass_stats = {"writes": 0, "bytes": 0}
        self._rr = itertools.count()
        # telemetry (ISSUE 9): the registry polls the bypass counters —
        # under our own lock, only when someone scrapes — instead of the
        # hot bypass path pushing per-write updates
        telemetry.poll("fs.bypass", self._bypass_snapshot)

    def _bypass_snapshot(self) -> dict:
        with self._pfs_lock:
            return dict(self.bypass_stats)

    def next_client(self):
        """Round-robin over the system's clients. Every read-side RPC used
        to go through ``clients[0]`` — one endpoint became the funnel for
        manifest fetches, direct gets, and fallback range reads while the
        others sat idle."""
        return self.clients[next(self._rr) % len(self.clients)]

    # -------------------------------------------------------------- namespace
    def _mgr_request(self, kind: str, payload: dict,
                     timeout: Optional[float] = None):
        c = self.next_client()
        if timeout is None:
            timeout = 2 * self.control_timeout
        return c.transport.request(c.ep, self.manager, kind, payload,
                                   timeout=timeout)

    # ----------------------------------------------------- write-through path
    def _report_bypass(self, path: str, offset: int, length: int,
                       chunk_bytes: int):
        """Metadata-only broadcast for a bypassed run: every server
        max-merges the lookup-table size and evicts live chunks the run
        covers; each chunk-granular slice's placement owner records an
        eviction tombstone, so direct KV gets of ANY ``{path}:{offset}``
        inside the run fall through to the PFS just as they would for an
        identically-striped buffered-then-drained stream. Fire-and-forget
        — even with zero reports delivered, reads stay byte-exact via the
        PFS fallback."""
        c = self.next_client()
        chunks = []
        for off in range(offset, offset + length, chunk_bytes):
            ln = min(chunk_bytes, offset + length - off)
            try:
                owner = c.owner(f"{path}:{off}")
            except RuntimeError:
                owner = None
            chunks.append([off, ln, owner])
        payload = {"file": path, "offset": offset, "length": length,
                   "size": offset + length, "chunks": chunks}
        for s in c._alive_servers():
            c.transport.send(c.tname, s, "bypass_report", payload)

    def open(self, path: str, mode: str = "r", *, policy: str = "async",
             chunk_bytes: Optional[int] = None,
             prefetch: Optional[bool] = None, lane=None) -> BBFile:
        if mode in ("w", "a"):
            r = self._mgr_request("fs_open", {"path": path, "mode": mode})
            if mode == "w":
                existed = r is not None and r.payload.get("existed")
                if not existed:
                    existed = bool(self.pfs_dir) and os.path.exists(
                        os.path.join(self.pfs_dir, path))
                if not existed:
                    # chunks written through the legacy put(file=...) shims
                    # share the key namespace but bypass the manager — the
                    # servers' manifests are the source of truth
                    existed = self.clients[0].file_stat(path)["known"]
                if existed:
                    # truncate semantics: a shorter rewrite must never read
                    # back stale tail bytes of a longer previous incarnation
                    self.truncate(path)
        return BBFile(self, path, mode, policy=policy,
                      chunk_bytes=chunk_bytes, prefetch=prefetch, lane=lane)

    def stage(self, path: str, offset: int = 0,
              length: Optional[int] = None, *, wait: bool = True,
              timeout: Optional[float] = None) -> bool:
        """Bulk-load ``path`` (or a byte range of it) from the PFS back into
        the burst buffer — the drain engine run in reverse. The manager runs
        one stage epoch at a time (serialized against drain micro-epochs);
        each server re-ingests its own lookup-table domain in parallel, and
        the staged chunks are CLEAN (durable copy exists), so later pressure
        evicts them for free.

        wait=True blocks until the epoch completes and returns whether it
        did; wait=False fires the request and returns whether the manager
        accepted it (read-ahead callers just drop a rejection). Staging is
        best-effort either way: reads are byte-exact with or without it."""
        if not self.stage_cfg.enabled:
            return False
        if timeout is None:
            timeout = self.stage_cfg.stage_timeout_s
        hi = -1 if length is None else offset + length
        payload = {"path": path, "lo": offset, "hi": hi}
        deadline = self._clock() + timeout
        c = self.next_client()
        req_timeout = self.control_timeout if wait \
            else self.control_timeout / 4
        epoch = None
        while epoch is None:
            r = c.transport.request(c.ep, self.manager, "stage_request",
                                    payload, timeout=req_timeout)
            if r is not None and r.payload.get("accepted"):
                epoch = r.payload["epoch"]
                break
            if not wait or self._clock() >= deadline:
                return False     # manager busy (drain/flush in flight)
            time.sleep(self.stage_cfg.request_retry_interval)
        if not wait:
            return True
        while self._clock() < deadline:
            r = c.transport.request(c.ep, self.manager, "stage_status",
                                    {"epoch": epoch},
                                    timeout=self.control_timeout)
            if r is not None:
                state = r.payload["state"]
                if state == "done":
                    return True
                if state in ("aborted", "unknown"):
                    return False
            time.sleep(self.stage_cfg.status_poll_interval)
        return False

    def truncate(self, path: str):
        """Drop every buffered chunk of ``path`` on every server (replicas
        included), its lookup-table entries, the durable PFS copy, and the
        manager's recorded size. Raises BBError if any server fails to
        acknowledge — an unacknowledged truncation could resurrect stale
        tail bytes of a longer previous incarnation later."""
        # ops of the dead incarnation still parked client-side must never
        # ship after the truncate (they would resurrect stale chunks)
        for cl in self.clients:
            cl.cancel_parked(path)
        c = self.clients[0]
        to = self.control_timeout
        for s in c._alive_servers():
            r = c.transport.request(c.ep, s, "file_truncate", {"file": path},
                                    timeout=to)
            if r is None:       # one retry: deep inboxes happen under load
                r = c.transport.request(c.ep, s, "file_truncate",
                                        {"file": path}, timeout=to)
            if r is None:
                raise BBError(f"truncate of {path!r} unacknowledged by {s}")
        if self.pfs_dir:
            p = os.path.join(self.pfs_dir, path)
            if os.path.exists(p):
                os.remove(p)
        self._mgr_request("fs_truncate", {"path": path},
                          timeout=self.control_timeout)

    def _register_sync(self, path: str, size: int):
        self._mgr_request("fs_sync", {"path": path, "size": size},
                          timeout=self.control_timeout)

    def listdir(self, prefix: str = "") -> List[str]:
        r = self._mgr_request("fs_list", {"prefix": prefix})
        names = set(r.payload["paths"]) if r is not None else set()
        if self.pfs_dir and os.path.isdir(self.pfs_dir):
            names.update(n for n in os.listdir(self.pfs_dir)
                         if n.startswith(prefix))
        return sorted(names)

    def exists(self, path: str) -> bool:
        try:
            self.stat(path)
            return True
        except FileNotFoundError:
            return False

    def stat(self, path: str) -> dict:
        """Merged metadata: buffered extent across servers' chunk manifests,
        post-flush lookup-table size, the PFS copy, and the manager's
        namespace (which alone knows zero-byte synced files). ``residency``
        reports where the file's bytes physically sit (DRAM / SSD / PFS,
        replica copies included) — the observable trace of the autonomous
        drain engine, which moves bytes down the tiers without ever changing
        what reads return."""
        c = self.clients[0]
        st = c.file_stat(path)
        buffered = st["buffered"]
        flushed = st["flushed_size"] or 0
        pfs = 0
        if self.pfs_dir:
            p = os.path.join(self.pfs_dir, path)
            if os.path.exists(p):
                pfs = os.path.getsize(p)
        r = self._mgr_request("fs_stat", {"path": path},
                              timeout=self.control_timeout)
        ns_known = r is not None and r.payload["known"]
        ns_size = r.payload["size"] if ns_known else 0
        if not (buffered or flushed or pfs or st["known"] or ns_known):
            raise FileNotFoundError(path)
        return {"size": max(buffered, flushed, pfs, ns_size),
                "buffered": buffered, "flushed_size": flushed,
                "pfs_size": pfs, "chunks": st["chunks"],
                "residency": st.get("residency",
                                    {"dram": 0, "ssd": 0, "pfs": 0}),
                "evicted_chunks": st.get("evicted_chunks", 0)}

    def unlink(self, path: str):
        """Drop the path from the namespace and its buffered chunks on
        every server (exact-match file_truncate — unlinking ``run`` leaves
        ``run_info.txt`` alone). The durable PFS copy, if flushed, is left
        in place."""
        self._mgr_request("fs_unlink", {"path": path})


# interval helpers shared by the read-assembly path (one implementation,
# in staging.py — the stage planner needs the identical math)
_merge = staging.merge_intervals
_gaps = staging.gaps
