"""Burst buffer client (paper §II, §III, §IV-B): the compute-node-side API.

ONE write path. Every write — whether it arrives through a ``BBFile``
handle, the legacy ``put``/``put_async`` shims, or a coalesced batch — is a
``WriteOp`` submitted to the same pipeline:

  submit(key, value) -> BBFuture
      The op is either fired at its owner immediately (pipelined, paper
      Fig 4) or parked in a per-destination coalesce buffer and shipped as
      one ``put_batch`` message; a background ACK pump (the paper's Fig 4
      "thread 2") drains replies, handles redirects and failover re-issues,
      and completes the op's BBFuture. Failures surface as exceptions on
      the future / the ``BBFile.sync()`` barrier — never on a shared
      mutable error list.

Pipelining vs coalescing are *policies* on this path, not separate APIs:
  coalesce=False  ship now, ACK out-of-band          (old put_async)
  coalesce=True   buffer, ship as a batch            (old coalesced path)
  fut.result()    block the caller on the ACK        (old blocking put)

The client also handles:
  - placement (Ketama / ISO / rendezvous)
  - overload redirects from servers (paper §III-A)
  - timeout -> predecessor failure confirmation -> manager report (§IV-B2)
  - reads preferring the burst buffer, replicas on primary failure, and
    post-shuffle range reads via the servers' lookup tables (§III-C)

Compatibility shims (one release): ``put``, ``put_async``, ``wait_acks``,
``flush_batches``, ``failed_keys`` delegate to the pipeline and keep the
old bool/list semantics for callers that have not migrated to
``BBFileSystem`` handles.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Callable, Dict, List, Optional

from repro.core import locktrack, qos, staging, telemetry
from repro.core.filesystem import BBFuture, BBWriteError, WriteOp
from repro.core.hashing import IsoPlacement, KetamaRing, RendezvousHash
from repro.core.qos import QoSConfig
from repro.core.transport import Message, Transport


class _AckSink:
    """Reply sink for the ACK pump. Unlike a queue.Queue, a put() on an
    already-signalled sink is a cheap no-op wake-wise: the pump is woken
    once per BURST of ACKs, not once per ACK — under pipelined small-chunk
    load a per-ACK wake preempts the submitting thread thousands of times
    a second and throttles ingest."""
    __slots__ = ("items", "event")

    def __init__(self):
        self.items: collections.deque = collections.deque()
        self.event = threading.Event()

    def put(self, msg):                    # transport sink protocol
        self.items.append(msg)
        self.event.set()


class _Inflight:
    """One in-flight message: a single WriteOp or a coalesced batch of them."""
    __slots__ = ("ops", "target", "deadline", "batch")

    def __init__(self, ops: List[WriteOp], target: str, deadline: float,
                 batch: bool):
        self.ops = ops
        self.target = target
        self.deadline = deadline
        self.batch = batch


class BBClient:
    MAX_ATTEMPTS = 6

    def __init__(self, name: str, transport: Transport, *,
                 client_index: int = 0,
                 placement: str = "iso",
                 replication: int = 2,
                 put_timeout: float = 3.0,
                 read_timeout: float = 1.0,
                 control_timeout: float = 1.0,
                 read_fanout: int = 4,
                 batch_bytes: int = 1 << 20,
                 coalesce_threshold: int = 64 << 10,
                 ack_poll_interval: float = 0.02,
                 ack_scan_interval: float = 0.05,
                 drain_poll_interval: float = 0.003,
                 connect_retry_interval: float = 0.05,
                 pump_join_timeout: float = 1.0,
                 qos_cfg: Optional[QoSConfig] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.tname = name
        self._clock = clock
        self.ack_poll_interval = ack_poll_interval
        self.ack_scan_interval = ack_scan_interval
        self.drain_poll_interval = drain_poll_interval
        self.connect_retry_interval = connect_retry_interval
        self.pump_join_timeout = pump_join_timeout
        self.transport = transport
        self.ep = transport.register(name)
        self.client_index = client_index
        self.placement_kind = placement
        self.replication = replication
        self.put_timeout = put_timeout
        # one knob for every read-side RPC deadline (manifest fetches,
        # direct gets, stats); range reads get twice the budget since the
        # server may have to touch the PFS to fill gaps
        self.read_timeout = read_timeout
        # ... and one for every control-plane RPC (manager hellos, failure
        # confirmation probes) — mirrors the read_timeout cleanup of ISSUE 4
        self.control_timeout = control_timeout
        self.read_fanout = read_fanout
        self.batch_bytes = batch_bytes
        self.coalesce_threshold = coalesce_threshold
        # QoS (ISSUE 5): lane-ordered dispatch gated by per-lane congestion
        # windows; ACK-piggybacked occupancy feeds the windows
        self.qos_cfg = qos_cfg or QoSConfig()
        if self.qos_cfg.enabled:
            self._laneq: Optional[qos.LaneQueue] = qos.LaneQueue(
                self.qos_cfg.lane_weights, self.qos_cfg.quantum_bytes)
            self._cwnd: Optional[qos.CongestionWindows] = \
                qos.CongestionWindows(self.qos_cfg, owner=name)
        else:
            self._laneq = None
            self._cwnd = None
        self._lane_inflight = [0] * len(qos.LANES)
        self.ring: List[str] = []
        self.dead: set = set()
        self._placement = None
        self._overrides: Dict[str, str] = {}     # key -> redirected server
        self._lock = locktrack.lock("BBClient._lock")  # membership/placement
        # --- write pipeline (paper Fig 4): in-flight ops + coalesce buffers.
        # All pipeline state is guarded by _op_lock; replies funnel into one
        # completion queue drained by the ACK pump thread.
        self._op_lock = locktrack.lock("BBClient._op_lock")
        self._pending: Dict[int, _Inflight] = {}   # msg_id -> in-flight entry
        self._inflight: set = set()                # WriteOps not yet done
        self._coalesce: Dict[str, List[WriteOp]] = {}
        self._coalesce_nbytes: Dict[str, int] = {}
        self._acks = _AckSink()
        self._last_reply: Dict[str, float] = {}    # server -> last-ack time
        self._pump: Optional[threading.Thread] = None
        self._stop = threading.Event()
        # legacy-shim error snapshot (wait_acks/failed_keys compat)
        self._failed: List[str] = []
        self.last_failed: List[str] = []
        # counters are bumped from API callers, the ACK pump, and expiry
        # threads concurrently; a dedicated leaf lock keeps them exact
        self._stats_lock = locktrack.lock("BBClient._stats_lock")
        self.stats = {"puts": 0, "put_bytes": 0, "redirects": 0,
                      "failovers": 0, "gets": 0, "bb_hits": 0,
                      "async_puts": 0, "batched_puts": 0, "batches": 0,
                      "evicted_reads": 0}
        # telemetry (ISSUE 9): per-lane latency histograms bind once here
        # (shared no-ops when disabled — _tele guards the clock stamps so
        # the hot path pays nothing); the registry polls the legacy
        # counters under _stats_lock only when someone scrapes
        self._tele = telemetry.enabled()
        self._m_lane_wait = telemetry.histogram("client.lane_wait_s")
        self._m_dispatch = telemetry.histogram("client.dispatch_s")
        telemetry.poll("client.ops", self._stats_snapshot, label=name)

    def _bump(self, stat: str, n: int = 1):
        with self._stats_lock:
            self.stats[stat] += n

    def _stats_snapshot(self) -> dict:
        with self._stats_lock:
            return dict(self.stats)

    # ------------------------------------------------------------ membership
    def connect(self, timeout: float = 10.0):
        deadline = self._clock() + timeout
        while self._clock() < deadline:
            r = self.transport.request(self.ep, "manager", "client_hello", {},
                                       timeout=self.control_timeout)
            if r is not None and r.kind == "ring":
                self._set_ring(r.payload["ring"],
                               set(r.payload.get("dead", [])))
                return
            time.sleep(self.connect_retry_interval)
        raise TimeoutError("manager did not provide a ring")

    def close(self):
        """Stop the ACK pump and fail any still-in-flight ops so no thread
        is left blocked on a future that can never complete (system
        teardown path)."""
        self._stop.set()
        if self._pump is not None:
            self._pump.join(timeout=self.pump_join_timeout)
            self._pump = None
        with self._op_lock:
            pending = list(self._inflight)
            self._inflight.clear()
            self._pending.clear()
            self._coalesce.clear()
            self._coalesce_nbytes.clear()
            if self._laneq is not None:
                self._laneq.discard(lambda ent: True)
            self._lane_inflight = [0] * len(qos.LANES)
        for op in pending:
            op.future._set_exception(BBWriteError(op.key, "client closed"))

    def _set_ring(self, ring: List[str], dead: Optional[set] = None):
        with self._lock:
            self.ring = list(ring)
            self.dead = set(dead or ())
            self._rebuild_placement()

    def _rebuild_placement(self):
        alive = [s for s in self.ring if s not in self.dead]
        if self.placement_kind == "ketama":
            self._placement = KetamaRing(alive)
        elif self.placement_kind == "rendezvous":
            self._placement = RendezvousHash(alive)
        else:
            self._placement = IsoPlacement(alive)

    def _drain_membership(self):
        """Apply any ring/ring_update notifications sitting in the inbox."""
        while True:
            msg = self.ep.recv(timeout=0)
            if msg is None:
                return
            if msg.kind == "ring":
                self._set_ring(msg.payload["ring"])
            elif msg.kind == "ring_update":
                with self._lock:
                    self.dead.update(msg.payload.get("dead", []))
                    for s in msg.payload.get("joined", []):
                        self.dead.discard(s)
                        if s not in self.ring:
                            self.ring.append(s)
                    self._rebuild_placement()

    def owner(self, key: str) -> str:
        self._drain_membership()
        with self._lock:
            if key in self._overrides:
                return self._overrides[key]
            if not any(s not in self.dead for s in self.ring):
                raise RuntimeError("no alive burst-buffer servers")
            if self.placement_kind == "iso":
                return self._placement.lookup_for_client(self.client_index)
            return self._placement.lookup(key)

    def replica_set(self, key: str) -> List[str]:
        """Primary + ring successors (replica holders)."""
        primary = self.owner(key)
        with self._lock:
            alive = [s for s in self.ring if s not in self.dead]
            if primary not in alive:
                alive.append(primary)
                alive.sort()
            i = alive.index(primary)
            return [alive[(i + j) % len(alive)]
                    for j in range(min(self.replication, len(alive)))]

    # ------------------------------------------------------- write pipeline
    def submit(self, key: str, value: bytes, *, file: Optional[str] = None,
               offset: int = 0, coalesce: Optional[bool] = None,
               lane: int = qos.LANE_INTERACTIVE) -> BBFuture:
        """THE write path. Returns a BBFuture that completes with True on a
        replicated ACK or with a BBWriteError once retries are exhausted.
        ``coalesce`` None applies the size threshold; True/False force the
        coalesced/pipelined route. ``lane`` is the QoS priority lane: with
        QoS enabled, ops go on the wire in weighted lane order and only
        while their lane's congestion window has room — a background flood
        parks client-side instead of stuffing the server's inbox ahead of
        a checkpoint burst."""
        self._bump("puts")
        self._bump("put_bytes", len(value))
        lane = qos.lane_index(lane)
        fut = BBFuture(key)
        op = WriteOp(key, value, file, offset, fut, lane=lane)
        if coalesce is None:
            coalesce = len(value) < self.coalesce_threshold
        self._ensure_pump()
        try:
            target = self.owner(key)
        except RuntimeError as e:
            self._fail_op(op, BBWriteError(key, str(e)))
            return fut
        with self._op_lock:
            self._inflight.add(op)
            if coalesce:
                ckey = (target, lane)
                self._coalesce.setdefault(ckey, []).append(op)
                nb = self._coalesce_nbytes.get(ckey, 0) + len(value)
                self._coalesce_nbytes[ckey] = nb
                if nb >= self.batch_bytes:
                    self._flush_target_locked(ckey)
            elif self._laneq is None:
                self._issue_locked([op], target, batch=False)
            else:
                if self._tele:
                    op.parked_at = self._clock()
                    op.trace_ctx = telemetry.current_ctx()
                self._laneq.push(lane, [[op], target, False], len(value))
                self._dispatch_locked()
        return fut

    def flush_coalesced(self):
        """Ship every pending coalesce buffer (one put_batch per server)."""
        with self._op_lock:
            for ckey in list(self._coalesce):
                self._flush_target_locked(ckey)

    def outstanding(self) -> int:
        """Write ops submitted but not yet completed — includes ops still
        sitting in coalesce buffers, so a drain that returns with
        outstanding() > 0 can never be mistaken for success."""
        with self._op_lock:
            return len(self._inflight)

    def drain(self, timeout: float = 30.0) -> List[str]:
        """Flush coalesce buffers and wait until every in-flight op
        completes. On overall timeout the stragglers are abandoned (their
        futures fail). Returns the keys of ops that FAILED since the last
        drain; [] means full success."""
        self.flush_coalesced()
        deadline = self._clock() + timeout
        failed: List[WriteOp] = []
        while True:
            with self._op_lock:
                pending = list(self._inflight)
            if not pending:
                break
            if self._clock() > deadline:
                for op in pending:
                    self._abandon(op, "drain timeout")
                break
            time.sleep(self.drain_poll_interval)
        # every completed-with-error op since the last drain
        with self._op_lock:
            keys, self._failed = self._failed, []
        self.last_failed = keys
        return keys

    def sync_put_timeout(self) -> float:
        """Worst-case time for one op to succeed or fail through the
        pipeline: per-attempt liveness timeout plus failure-confirmation
        round-trips, across MAX_ATTEMPTS."""
        return (self.put_timeout + 1.5) * self.MAX_ATTEMPTS + 2.0

    # --- internals -------------------------------------------------------
    def _ensure_pump(self):
        if self._pump is not None and self._pump.is_alive():
            return
        with self._op_lock:
            if self._pump is not None and self._pump.is_alive():
                return
            self._stop.clear()
            self._pump = threading.Thread(
                target=self._ack_loop, daemon=True,
                name=f"{self.tname}-ackpump")
            self._pump.start()

    def _ack_loop(self):
        """Paper Fig 4 "thread 2": drain ACKs, re-issue on redirect, expire
        entries whose server has gone quiet and fail over (§IV-B2)."""
        next_scan = 0.0
        sink = self._acks
        while not self._stop.is_set():
            if not sink.items:
                sink.event.wait(self.ack_poll_interval)
            sink.event.clear()             # clear-then-drain: a concurrent
            while sink.items:              # append re-signals for next pass
                msg = sink.items.popleft()
                if self._tele:
                    # re-parent under the server's reply span so the ACK
                    # leg shows up in the same trace as the put it answers
                    with telemetry.msg_span("client." + msg.kind,
                                            self.tname, msg.payload):
                        self._on_ack(msg)
                else:
                    self._on_ack(msg)
            now = self._clock()
            if now >= next_scan:
                self._check_deadlines(now)
                next_scan = now + self.ack_scan_interval

    def _issue_locked(self, ops: List[WriteOp], target: str, batch: bool):
        """Fire ops at ``target`` as one message. Caller holds _op_lock."""
        if batch:
            self._bump("batches")
            self._bump("batched_puts", len(ops))
            payload = {"items": [{"key": o.key, "value": o.value,
                                  "file": o.file, "offset": o.offset}
                                 for o in ops],
                       "lane": ops[0].lane}
            msg_id = self.transport.request_async(
                self.ep, target, "put_batch", payload, sink=self._acks)
        else:
            op = ops[0]
            msg_id = self.transport.request_async(
                self.ep, target, "put",
                {"key": op.key, "value": op.value, "file": op.file,
                 "offset": op.offset, "lane": op.lane,
                 # after 2 redirects force acceptance (server spills to SSD)
                 # to avoid ping-pong on stale free-memory gossip
                 "redirectable": op.redirects < 2},
                sink=self._acks)
        if self._tele:
            now = self._clock()
            lane_name = qos.LANES[ops[0].lane]
            for op in ops:
                if op.parked_at:       # parked in the lane queue until now
                    wait = now - op.parked_at
                    self._m_lane_wait.observe(wait, label=lane_name)
                    # completed-span record under the submitter's trace —
                    # the health engine's "queue" segment (ISSUE 10)
                    telemetry.observe_span("client.lane_wait", self.tname,
                                           op.trace_ctx, op.parked_at,
                                           wait, lane=lane_name)
                    op.parked_at = 0.0
                    op.trace_ctx = None
                op.issued_at = now
        for op in ops:
            op.msg_id = msg_id
            if not op.counted:      # window accounting (re-issues stay held)
                op.counted = True
                self._lane_inflight[op.lane] += len(op.value)
        self._pending[msg_id] = _Inflight(
            ops, target, self._clock() + self.put_timeout, batch)

    def _flush_target_locked(self, ckey: tuple):
        ops = self._coalesce.pop(ckey, [])
        self._coalesce_nbytes.pop(ckey, None)
        if not ops:
            return
        target, lane = ckey
        if self._laneq is None:
            self._issue_locked(ops, target, batch=True)
        else:
            if self._tele:
                now = self._clock()
                ctx = telemetry.current_ctx()
                for op in ops:
                    op.parked_at = now
                    op.trace_ctx = ctx
            self._laneq.push(lane, [ops, target, True],
                             sum(len(o.value) for o in ops))
            self._dispatch_locked()

    def _can_issue(self, lane: int, nbytes: int) -> bool:
        """Congestion gate for one lane-queue head. An idle lane may always
        issue one entry (progress even when a single op exceeds the
        window); otherwise the lane's in-flight bytes must fit."""
        infl = self._lane_inflight[lane]
        return infl == 0 or infl + nbytes <= self._cwnd.window(lane)

    def _dispatch_locked(self):
        """Move queued entries onto the wire in weighted lane order, as far
        as the congestion windows allow. Caller holds _op_lock. Runs on
        every submit, every ACK (window space freed), and the pump's
        deadline scan — queued ops can never strand."""
        while True:
            ent = self._laneq.pop(self._can_issue)
            if ent is None:
                return
            ops, target, batch = ent
            if ops:                 # abandon may have emptied the entry
                self._issue_locked(ops, target, batch)

    def _uncount_locked(self, op: WriteOp):
        """Release the op's congestion-window hold. Caller holds _op_lock."""
        if op.counted:
            op.counted = False
            self._lane_inflight[op.lane] -= len(op.value)

    def _fail_op(self, op: WriteOp, exc: Exception):
        # record BEFORE completing the future: a blocking put() woken by the
        # exception consumes its key from _failed, so the key must already
        # be there or it would leak into the next drain cycle
        with self._op_lock:
            self._inflight.discard(op)
            self._uncount_locked(op)
            self._failed.append(op.key)
        if not op.future._set_exception(exc):
            self._consume_failed(op.key)    # op had already succeeded

    def _complete_op(self, op: WriteOp):
        with self._op_lock:
            self._inflight.discard(op)
            self._uncount_locked(op)
        op.future._set_result(True)

    def _abandon(self, op: WriteOp, reason: str):
        """Cancel an op wherever it currently is (coalesce buffer, lane
        queue, or wire) and fail its future. Late ACKs for it are ignored
        (first-win)."""
        with self._op_lock:
            for ckey, ops in list(self._coalesce.items()):
                if op in ops:
                    ops.remove(op)
                    self._coalesce_nbytes[ckey] = \
                        self._coalesce_nbytes.get(ckey, 0) - len(op.value)
                    if not ops:
                        del self._coalesce[ckey]
                        self._coalesce_nbytes.pop(ckey, None)
            if self._laneq is not None:
                # pull the op out of any queued entry; an emptied entry is
                # dropped whole (dispatch also skips empties defensively)
                for ent in self._laneq.entries():
                    if op in ent[0]:
                        ent[0].remove(op)
                self._laneq.discard(lambda ent: not ent[0])
            if op.msg_id is not None:
                ent = self._pending.get(op.msg_id)
                if ent is not None and op in ent.ops:
                    ent.ops.remove(op)
                    if not ent.ops:
                        del self._pending[op.msg_id]
                        self.transport.cancel_async(self.ep, op.msg_id)
        self._fail_op(op, BBWriteError(op.key, reason))

    def _on_ack(self, msg: Message):
        with self._op_lock:
            ent = self._pending.pop(msg.reply_to, None)
            if ent is None:
                return                      # late reply for a re-issued op
            # written here (pump), read by _check_deadlines — keep both
            # under _op_lock like the rest of the pipeline state
            self._last_reply[ent.target] = self._clock()
        # backpressure (ISSUE 5): every server reply piggybacks its store
        # occupancy; the congestion windows shrink background lanes first
        occ = msg.payload.get("occupancy") if msg.payload else None
        if occ is not None and self._cwnd is not None:
            self._cwnd.on_pressure(occ)
        if msg.kind in ("put_ack", "put_batch_ack"):
            # one lock round for the whole entry (batches carry many ops)
            with self._op_lock:
                self._inflight.difference_update(ent.ops)
                for op in ent.ops:
                    self._uncount_locked(op)
                if self._laneq is not None:
                    self._dispatch_locked()   # window space just freed
            if self._tele:
                now = self._clock()
                for op in ent.ops:
                    if op.issued_at:
                        self._m_dispatch.observe(now - op.issued_at,
                                                 label=qos.LANES[op.lane])
            for op in ent.ops:
                op.future._set_result(True)
            return
        if msg.kind == "redirect":
            self._bump("redirects")
            target = msg.payload["target"]
            telemetry.record(self.tname, "redirect", target=target,
                             n_ops=len(ent.ops))
            with self._lock:
                for op in ent.ops:
                    self._overrides[op.key] = target
            for op in ent.ops:
                op.redirects += 1
                op.attempts += 1
            with self._op_lock:
                # servers never redirect batches today, but route them
                # correctly if that changes
                self._issue_locked(ent.ops, target, batch=ent.batch)

    def _check_deadlines(self, now: float):
        # a deadline alone does not condemn a server: under pipelined load a
        # healthy target may simply have a deep inbox. Expire an entry only
        # when its server has ALSO acked nothing for a full put_timeout —
        # i.e. the timeout judges per-server liveness, not per-message queue
        # position. A dead server acks nothing, so real failures still fire.
        with self._op_lock:
            if self._laneq is not None:
                self._dispatch_locked()   # insurance: windows may have grown
            expired = [mid for mid, e in self._pending.items()
                       if e.deadline < now
                       and self._last_reply.get(e.target, -1e9)
                       + self.put_timeout < now]
            entries = []
            for mid in expired:
                entries.append(self._pending.pop(mid))
                self.transport.cancel_async(self.ep, mid)
        if entries:
            # failure confirmation blocks on RPCs for seconds — run it off
            # the pump thread so ACKs for healthy servers keep draining
            # (entries are already popped, so no double-processing)
            threading.Thread(
                target=lambda: [self._expire(e) for e in entries],
                daemon=True, name=f"{self.tname}-expire").start()

    def _expire(self, ent: _Inflight):
        """An in-flight message timed out: confirm the suspect's failure via
        its predecessor, then re-issue survivors to their failover owners
        (regrouping batches, since placement may split them)."""
        telemetry.record(self.tname, "put_timeout", target=ent.target,
                         n_ops=len(ent.ops))
        retryable = [op for op in ent.ops
                     if op.attempts + 1 < self.MAX_ATTEMPTS]
        exhausted = [op for op in ent.ops if op not in retryable]
        failover = None
        if retryable:
            failover = self._handle_timeout(retryable[0].key, ent.target)
        if failover is None:
            exhausted = ent.ops
            retryable = []
        for op in exhausted:
            self._fail_op(op, BBWriteError(
                op.key, f"no replicated ACK after {op.attempts + 1} attempts"
                        f" (last target {ent.target})"))
        if not retryable:
            return
        groups: Dict[str, List[WriteOp]] = {}
        for op in retryable:
            op.attempts += 1
            try:
                groups.setdefault(self.owner(op.key), []).append(op)
            except RuntimeError as e:
                self._fail_op(op, BBWriteError(op.key, str(e)))
        with self._op_lock:
            for target, ops in groups.items():
                if ent.batch and len(ops) > 1:
                    self._issue_locked(ops, target, batch=True)
                else:
                    for op in ops:
                        self._issue_locked([op], target, batch=False)

    def _handle_timeout(self, key: str, target: str) -> Optional[str]:
        """Paper §IV-B2: confirm failure via the suspect's predecessor, then
        let the manager broadcast; fail over to the replica successor.
        Returns the failover target, or None when no alive server remains."""
        self._bump("failovers")
        telemetry.record(self.tname, "failover", suspect=target, key=key)
        with self._lock:
            alive = [s for s in self.ring if s not in self.dead]
        pred = None
        if target in alive:
            i = alive.index(target)
            pred = alive[(i - 1) % len(alive)]
        if pred and pred != target:
            self.transport.request(self.ep, pred, "confirm_failure",
                                   {"suspect": target},
                                   timeout=self.control_timeout)
        with self._lock:
            self.dead.add(target)
            self._rebuild_placement()
            self._overrides = {k: v for k, v in self._overrides.items()
                               if v != target}
            if not any(s not in self.dead for s in self.ring):
                return None
        try:
            return self.owner(key)
        except RuntimeError:
            return None

    # ------------------------------------------------- legacy compat shims
    # One release of grace for pre-BBFileSystem callers. Everything below
    # delegates to submit()/drain(); nothing else in the client distinguishes
    # "sync" from "async" from "batched" writes.
    def put(self, key: str, value: bytes, *, file: Optional[str] = None,
            offset: int = 0) -> bool:
        """[compat] Blocking put: submit + wait on the future. True on a
        replicated ACK, False on failure. The caller observes the failure
        here, so it is consumed — it must not ALSO fail a later
        wait_acks()/drain() cycle of unrelated async ops."""
        fut = self.submit(key, value, file=file, offset=offset,
                          coalesce=False)
        try:
            fut.result(self.sync_put_timeout())
            return True
        except TimeoutError:
            # abandon so a wedged op cannot poison a later drain barrier
            self.abandon_by_future(fut)
            self._consume_failed(key)
            return False
        except BBWriteError:
            self._consume_failed(key)
            return False

    def _consume_failed(self, key: str):
        with self._op_lock:
            try:
                self._failed.remove(key)
            except ValueError:
                pass

    def cancel_parked(self, file: str):
        """Truncate support: complete-and-drop every op of ``file`` still
        parked client-side (lane queue or coalesce buffer). A parked op
        dispatched AFTER the truncate RPC would re-land stale bytes of the
        dead incarnation; completing it as success gives the caller the
        FIFO-equivalent outcome — applied, then truncated."""
        done: List[WriteOp] = []
        with self._op_lock:
            if self._laneq is not None:
                for ent in self._laneq.entries():
                    for op in [o for o in ent[0] if o.file == file]:
                        ent[0].remove(op)
                        self._inflight.discard(op)
                        self._uncount_locked(op)
                        done.append(op)
                self._laneq.discard(lambda ent: not ent[0])
            for ckey, ops in list(self._coalesce.items()):
                stale = [o for o in ops if o.file == file]
                for op in stale:
                    ops.remove(op)
                    self._coalesce_nbytes[ckey] = \
                        self._coalesce_nbytes.get(ckey, 0) - len(op.value)
                    self._inflight.discard(op)
                    done.append(op)
                if not ops:
                    del self._coalesce[ckey]
                    self._coalesce_nbytes.pop(ckey, None)
        for op in done:
            op.future._set_result(True)

    def abandon_by_future(self, fut) -> bool:
        """Cancel the in-flight op behind ``fut`` and consume its failure
        record (the caller observed the outcome through the future, so it
        must not leak into a later legacy drain cycle). Returns False if no
        such op is in flight."""
        with self._op_lock:
            op = next((o for o in self._inflight if o.future is fut), None)
        if op is None:
            return False
        self._abandon(op, "barrier timeout")
        self._consume_failed(op.key)
        return True

    def put_async(self, key: str, value: bytes, *, file: Optional[str] = None,
                  offset: int = 0, coalesce: Optional[bool] = None
                  ) -> BBFuture:
        """[compat] Pipelined put; completion is observed via wait_acks()
        (legacy) or the returned future (preferred)."""
        self._bump("async_puts")
        return self.submit(key, value, file=file, offset=offset,
                           coalesce=coalesce)

    def flush_batches(self):
        """[compat] Old name for flush_coalesced()."""
        self.flush_coalesced()

    def wait_acks(self, timeout: float = 30.0) -> bool:
        """[compat] Drain the pipeline; True only when every op submitted
        since the last drain achieved a replicated ACK. Unlike the pre-
        BBFuture version, a timeout can never report True while ops are
        still buffered or in flight: outstanding() is authoritative."""
        failed = self.drain(timeout)
        return not failed and self.outstanding() == 0

    def failed_keys(self) -> List[str]:
        """[compat] Keys that failed in the last drain/wait_acks cycle."""
        return list(self.last_failed)

    # ------------------------------------------------------------------- get
    def get(self, key: str) -> Optional[bytes]:
        """Read back a buffered value, trying primary then replicas. If every
        copy was drained-and-evicted, fall through transparently: the miss
        reply carries the chunk's (file, offset, length) residency record,
        and the bytes come back via the post-shuffle lookup table / PFS —
        callers never observe eviction."""
        self._bump("gets")
        try:
            replicas = self.replica_set(key)
        except RuntimeError:
            return None
        evicted = None
        for target in replicas:
            r = self.transport.request(self.ep, target, "get", {"key": key},
                                       timeout=self.read_timeout)
            if r is not None and r.payload.get("hit"):
                self._bump("bb_hits")
                return r.payload["value"]
            if r is not None and evicted is None:
                evicted = r.payload.get("evicted")
        if evicted is not None:
            file, offset, length = evicted
            data = self.read_file(file, offset, length)
            if data is not None:
                self._bump("evicted_reads")
                return data
        return None

    def file_info(self, file: str):
        try:
            replicas = self.replica_set(file)
        except RuntimeError:
            return None
        for target in replicas:
            r = self.transport.request(self.ep, target, "file_info",
                                       {"file": file},
                                       timeout=self.read_timeout)
            if r is not None and r.payload.get("size") is not None:
                return r.payload
        return None

    def _alive_servers(self) -> List[str]:
        self._drain_membership()
        with self._lock:
            return [s for s in self.ring if s not in self.dead]

    def file_chunks(self, file: str) -> Dict[int, tuple]:
        """Merged per-file chunk manifest across all alive servers:
        {offset: (key, length, holders)}. Primaries and replicas both
        report a chunk, so ``holders`` doubles as the replica set for
        direct fetches — placement-independent reads survive failover.
        A DIRTY copy outranks a CLEAN (staged) one at the same offset:
        staged chunks are re-ingests of the durable PFS copy, so a
        buffered write racing a stage epoch must win the merge and its
        holder is tried first."""
        merged: Dict[int, tuple] = {}
        clean_at: Dict[int, bool] = {}
        servers = self._alive_servers()
        replies = staging.parallel_map(
            lambda s: self.transport.request(self.ep, s, "file_chunks",
                                             {"file": file},
                                             timeout=self.read_timeout),
            servers, self.read_fanout)
        for s, r in zip(servers, replies):
            if r is None:
                continue
            for off, key, length, clean in r.payload["chunks"]:
                ent = merged.get(off)
                if ent is None:
                    merged[off] = (key, length, [s])
                    clean_at[off] = clean
                elif not clean and clean_at[off]:
                    # dirty beats staged: its key/length define the chunk
                    # and its holder goes to the front of the line
                    merged[off] = (key, length, [s] + ent[2])
                    clean_at[off] = False
                else:
                    ent[2].append(s)
        return merged

    def get_at(self, server: str, key: str) -> Optional[bytes]:
        """Fetch a value from one specific server (manifest-directed read —
        bypasses placement, which only knows where THIS client writes)."""
        self._bump("gets")
        r = self.transport.request(self.ep, server, "get", {"key": key},
                                   timeout=self.read_timeout)
        if r is not None and r.payload.get("hit"):
            self._bump("bb_hits")
            return r.payload["value"]
        return None

    def file_stat(self, file: str) -> dict:
        """Merged file metadata across alive servers: buffered extent,
        chunk count, post-flush size (lookup table), and physical residency
        (bytes per tier, replica copies included — it reports where bytes
        actually sit, so replication factors in)."""
        buffered, chunks, flushed, known = 0, 0, None, False
        residency = {"dram": 0, "ssd": 0, "pfs": 0}
        evicted_chunks = 0
        servers = self._alive_servers()
        replies = staging.parallel_map(
            lambda s: self.transport.request(self.ep, s, "file_stat",
                                             {"file": file},
                                             timeout=self.read_timeout),
            servers, self.read_fanout)
        for r in replies:
            if r is None:
                continue
            p = r.payload
            buffered = max(buffered, p["buffered"])
            chunks += p["chunks"]
            if p["flushed_size"] is not None:
                flushed = max(flushed or 0, p["flushed_size"])
            known = known or p["known"]
            for tier, n in p.get("residency", {}).items():
                residency[tier] = residency.get(tier, 0) + n
            evicted_chunks += p.get("evicted_chunks", 0)
        return {"buffered": buffered, "chunks": chunks,
                "flushed_size": flushed, "known": known,
                "residency": residency, "evicted_chunks": evicted_chunks}

    def read_file(self, file: str, offset: int, length: int
                  ) -> Optional[bytes]:
        """Post-flush read through the lookup table (paper §III-C): locate
        the domain owners for the range and fetch without touching the PFS.
        Domain fetches fan out concurrently (ISSUE 4) — a restart-sized
        range spans every server's domain, and serial round-trips would
        leave all but one server idle."""
        info = self.file_info(file)
        if info is None:
            return None
        spans = []
        for server, a, b in info["domains"]:
            lo, hi = max(offset, a), min(offset + length, b)
            if lo < hi:
                spans.append((server, lo, hi))

        def _fetch(span):
            server, lo, hi = span
            return self.transport.request(
                self.ep, server, "read_range",
                {"file": file, "offset": lo, "length": hi - lo},
                timeout=2 * self.read_timeout)

        replies = staging.parallel_map(_fetch, spans, self.read_fanout)
        out = bytearray(length)
        filled = 0
        for (server, lo, hi), r in zip(spans, replies):
            if r is None or not r.payload.get("complete"):
                return None     # never fabricate bytes: let callers fall back
            out[lo - offset:hi - offset] = r.payload["data"]
            filled += hi - lo
        if filled < length:     # range extends beyond every domain
            return None
        return bytes(out)
