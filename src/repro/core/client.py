"""Burst buffer client (paper §II, §III, §IV-B): the compute-node-side API.

put() is asynchronous and pipelined (paper Fig 4 thread-2 ACK management):
values are sent immediately, outstanding keys sit in an ACK ledger, and
``wait_acks`` drains it. The client handles:
  - placement (Ketama / ISO / rendezvous)
  - overload redirects from servers (paper §III-A)
  - timeout -> predecessor failure confirmation -> manager report (§IV-B2)
  - reads preferring the burst buffer, replicas on primary failure, and
    post-shuffle range reads via the servers' lookup tables (§III-C)
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional

from repro.core.hashing import IsoPlacement, KetamaRing, RendezvousHash
from repro.core.transport import Message, Transport


class BBClient:
    def __init__(self, name: str, transport: Transport, *,
                 client_index: int = 0,
                 placement: str = "iso",
                 replication: int = 2,
                 put_timeout: float = 3.0):
        self.tname = name
        self.transport = transport
        self.ep = transport.register(name)
        self.client_index = client_index
        self.placement_kind = placement
        self.replication = replication
        self.put_timeout = put_timeout
        self.ring: List[str] = []
        self.dead: set = set()
        self._placement = None
        self._overrides: Dict[str, str] = {}     # key -> redirected server
        self._lock = threading.Lock()
        self.stats = {"puts": 0, "put_bytes": 0, "redirects": 0,
                      "failovers": 0, "gets": 0, "bb_hits": 0}

    # ------------------------------------------------------------ membership
    def connect(self, timeout: float = 10.0):
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            r = self.transport.request(self.ep, "manager", "client_hello", {},
                                       timeout=1.0)
            if r is not None and r.kind == "ring":
                self._set_ring(r.payload["ring"],
                               set(r.payload.get("dead", [])))
                return
            time.sleep(0.05)
        raise TimeoutError("manager did not provide a ring")

    def _set_ring(self, ring: List[str], dead: Optional[set] = None):
        with self._lock:
            self.ring = list(ring)
            self.dead = set(dead or ())
            self._rebuild_placement()

    def _rebuild_placement(self):
        alive = [s for s in self.ring if s not in self.dead]
        if self.placement_kind == "ketama":
            self._placement = KetamaRing(alive)
        elif self.placement_kind == "rendezvous":
            self._placement = RendezvousHash(alive)
        else:
            self._placement = IsoPlacement(alive)

    def _drain_membership(self):
        """Apply any ring/ring_update notifications sitting in the inbox."""
        while True:
            msg = self.ep.recv(timeout=0)
            if msg is None:
                return
            if msg.kind == "ring":
                self._set_ring(msg.payload["ring"])
            elif msg.kind == "ring_update":
                with self._lock:
                    self.dead.update(msg.payload.get("dead", []))
                    for s in msg.payload.get("joined", []):
                        self.dead.discard(s)
                        if s not in self.ring:
                            self.ring.append(s)
                    self._rebuild_placement()

    def owner(self, key: str) -> str:
        self._drain_membership()
        with self._lock:
            if key in self._overrides:
                return self._overrides[key]
            if self.placement_kind == "iso":
                return self._placement.lookup_for_client(self.client_index)
            return self._placement.lookup(key)

    def replica_set(self, key: str) -> List[str]:
        """Primary + ring successors (replica holders)."""
        primary = self.owner(key)
        with self._lock:
            alive = [s for s in self.ring if s not in self.dead]
            if primary not in alive:
                alive.append(primary)
                alive.sort()
            i = alive.index(primary)
            return [alive[(i + j) % len(alive)]
                    for j in range(min(self.replication, len(alive)))]

    # ------------------------------------------------------------------- put
    def put(self, key: str, value: bytes, *, file: Optional[str] = None,
            offset: int = 0) -> bool:
        """Synchronous put with redirect + failure handling. Returns True on
        replicated ACK. (The async pipeline variant is put_async/wait_acks.)"""
        self.stats["puts"] += 1
        self.stats["put_bytes"] += len(value)
        target = self.owner(key)
        redirects = 0
        for attempt in range(6):
            r = self.transport.request(
                self.ep, target, "put",
                {"key": key, "value": value, "file": file, "offset": offset,
                 # after 2 redirects force acceptance (server spills to SSD)
                 # to avoid ping-pong on stale free-memory gossip
                 "redirectable": redirects < 2},
                timeout=self.put_timeout)
            if r is None:
                target = self._handle_timeout(key, target)
                continue
            if r.kind == "redirect":
                self.stats["redirects"] += 1
                redirects += 1
                target = r.payload["target"]
                with self._lock:
                    self._overrides[key] = target
                continue
            if r.kind == "put_ack":
                return True
        return False

    def _handle_timeout(self, key: str, target: str) -> str:
        """Paper §IV-B2: confirm failure via the suspect's predecessor, then
        let the manager broadcast; fail over to the replica successor."""
        self.stats["failovers"] += 1
        with self._lock:
            alive = [s for s in self.ring if s not in self.dead]
        pred = None
        if target in alive:
            i = alive.index(target)
            pred = alive[(i - 1) % len(alive)]
        if pred and pred != target:
            self.transport.request(self.ep, pred, "confirm_failure",
                                   {"suspect": target}, timeout=1.0)
        with self._lock:
            self.dead.add(target)
            self._rebuild_placement()
            self._overrides = {k: v for k, v in self._overrides.items()
                               if v != target}
        return self.owner(key)

    # ------------------------------------------------------------------- get
    def get(self, key: str) -> Optional[bytes]:
        """Read back a buffered value, trying primary then replicas."""
        self.stats["gets"] += 1
        for target in self.replica_set(key):
            r = self.transport.request(self.ep, target, "get", {"key": key},
                                       timeout=1.0)
            if r is not None and r.payload.get("hit"):
                self.stats["bb_hits"] += 1
                return r.payload["value"]
        return None

    def file_info(self, file: str):
        for target in self.replica_set(file):
            r = self.transport.request(self.ep, target, "file_info",
                                       {"file": file}, timeout=1.0)
            if r is not None and r.payload.get("size") is not None:
                return r.payload
        return None

    def read_file(self, file: str, offset: int, length: int
                  ) -> Optional[bytes]:
        """Post-flush read through the lookup table (paper §III-C): locate
        the domain owners for the range and fetch without touching the PFS."""
        info = self.file_info(file)
        if info is None:
            return None
        out = bytearray(length)
        for server, a, b in info["domains"]:
            lo, hi = max(offset, a), min(offset + length, b)
            if lo >= hi:
                continue
            r = self.transport.request(
                self.ep, server, "read_range",
                {"file": file, "offset": lo, "length": hi - lo}, timeout=2.0)
            if r is None:
                return None
            out[lo - offset:hi - offset] = r.payload["data"]
        return bytes(out)
