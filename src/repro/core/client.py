"""Burst buffer client (paper §II, §III, §IV-B): the compute-node-side API.

Three write paths:
  - put():        blocking — one replicated round-trip per key
  - put_async():  pipelined (paper Fig 4 thread-2 ACK management) — values
                  are sent immediately, outstanding msg-ids sit in an ACK
                  ledger, and ``wait_acks`` drains it out-of-band
  - coalesced:    put_async with small values buffers them per destination
                  and ships one ``put_batch`` message per server

The client handles:
  - placement (Ketama / ISO / rendezvous)
  - overload redirects from servers (paper §III-A)
  - timeout -> predecessor failure confirmation -> manager report (§IV-B2)
  - reads preferring the burst buffer, replicas on primary failure, and
    post-shuffle range reads via the servers' lookup tables (§III-C)
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Dict, List, Optional

from repro.core.hashing import IsoPlacement, KetamaRing, RendezvousHash
from repro.core.transport import Message, Transport


class BBClient:
    MAX_ATTEMPTS = 6

    def __init__(self, name: str, transport: Transport, *,
                 client_index: int = 0,
                 placement: str = "iso",
                 replication: int = 2,
                 put_timeout: float = 3.0,
                 batch_bytes: int = 1 << 20,
                 coalesce_threshold: int = 64 << 10):
        self.tname = name
        self.transport = transport
        self.ep = transport.register(name)
        self.client_index = client_index
        self.placement_kind = placement
        self.replication = replication
        self.put_timeout = put_timeout
        self.batch_bytes = batch_bytes
        self.coalesce_threshold = coalesce_threshold
        self.ring: List[str] = []
        self.dead: set = set()
        self._placement = None
        self._overrides: Dict[str, str] = {}     # key -> redirected server
        self._lock = threading.Lock()
        # --- ACK ledger (paper Fig 4 thread-2): outstanding async puts.
        # msg_id -> entry; replies funnel into one completion queue.
        self._ledger: Dict[int, dict] = {}
        self._acks: "queue.Queue[Message]" = queue.Queue()
        self._failed: List[str] = []             # keys that exhausted retries
        self.last_failed: List[str] = []         # snapshot of the last cycle
        self._last_reply: Dict[str, float] = {}  # server -> last-ack time
        # --- write coalescing: target -> list of pending small put items
        self._batch: Dict[str, List[dict]] = {}
        self._batch_nbytes: Dict[str, int] = {}
        self.stats = {"puts": 0, "put_bytes": 0, "redirects": 0,
                      "failovers": 0, "gets": 0, "bb_hits": 0,
                      "async_puts": 0, "batched_puts": 0, "batches": 0}

    # ------------------------------------------------------------ membership
    def connect(self, timeout: float = 10.0):
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            r = self.transport.request(self.ep, "manager", "client_hello", {},
                                       timeout=1.0)
            if r is not None and r.kind == "ring":
                self._set_ring(r.payload["ring"],
                               set(r.payload.get("dead", [])))
                return
            time.sleep(0.05)
        raise TimeoutError("manager did not provide a ring")

    def _set_ring(self, ring: List[str], dead: Optional[set] = None):
        with self._lock:
            self.ring = list(ring)
            self.dead = set(dead or ())
            self._rebuild_placement()

    def _rebuild_placement(self):
        alive = [s for s in self.ring if s not in self.dead]
        if self.placement_kind == "ketama":
            self._placement = KetamaRing(alive)
        elif self.placement_kind == "rendezvous":
            self._placement = RendezvousHash(alive)
        else:
            self._placement = IsoPlacement(alive)

    def _drain_membership(self):
        """Apply any ring/ring_update notifications sitting in the inbox."""
        while True:
            msg = self.ep.recv(timeout=0)
            if msg is None:
                return
            if msg.kind == "ring":
                self._set_ring(msg.payload["ring"])
            elif msg.kind == "ring_update":
                with self._lock:
                    self.dead.update(msg.payload.get("dead", []))
                    for s in msg.payload.get("joined", []):
                        self.dead.discard(s)
                        if s not in self.ring:
                            self.ring.append(s)
                    self._rebuild_placement()

    def owner(self, key: str) -> str:
        self._drain_membership()
        with self._lock:
            if key in self._overrides:
                return self._overrides[key]
            if not any(s not in self.dead for s in self.ring):
                raise RuntimeError("no alive burst-buffer servers")
            if self.placement_kind == "iso":
                return self._placement.lookup_for_client(self.client_index)
            return self._placement.lookup(key)

    def replica_set(self, key: str) -> List[str]:
        """Primary + ring successors (replica holders)."""
        primary = self.owner(key)
        with self._lock:
            alive = [s for s in self.ring if s not in self.dead]
            if primary not in alive:
                alive.append(primary)
                alive.sort()
            i = alive.index(primary)
            return [alive[(i + j) % len(alive)]
                    for j in range(min(self.replication, len(alive)))]

    # ------------------------------------------------------------------- put
    def put(self, key: str, value: bytes, *, file: Optional[str] = None,
            offset: int = 0) -> bool:
        """Synchronous put with redirect + failure handling. Returns True on
        replicated ACK. (The async pipeline variant is put_async/wait_acks.)"""
        self.stats["puts"] += 1
        self.stats["put_bytes"] += len(value)
        try:
            target = self.owner(key)
        except RuntimeError:
            return False
        redirects = 0
        for attempt in range(self.MAX_ATTEMPTS):
            r = self.transport.request(
                self.ep, target, "put",
                {"key": key, "value": value, "file": file, "offset": offset,
                 # after 2 redirects force acceptance (server spills to SSD)
                 # to avoid ping-pong on stale free-memory gossip
                 "redirectable": redirects < 2},
                timeout=self.put_timeout)
            if r is None:
                target = self._handle_timeout(key, target)
                if target is None:          # no alive servers left
                    return False
                continue
            if r.kind == "redirect":
                self.stats["redirects"] += 1
                redirects += 1
                target = r.payload["target"]
                with self._lock:
                    self._overrides[key] = target
                continue
            if r.kind == "put_ack":
                return True
        return False

    def _handle_timeout(self, key: str, target: str) -> Optional[str]:
        """Paper §IV-B2: confirm failure via the suspect's predecessor, then
        let the manager broadcast; fail over to the replica successor.
        Returns the failover target, or None when no alive server remains."""
        self.stats["failovers"] += 1
        with self._lock:
            alive = [s for s in self.ring if s not in self.dead]
        pred = None
        if target in alive:
            i = alive.index(target)
            pred = alive[(i - 1) % len(alive)]
        if pred and pred != target:
            self.transport.request(self.ep, pred, "confirm_failure",
                                   {"suspect": target}, timeout=1.0)
        with self._lock:
            self.dead.add(target)
            self._rebuild_placement()
            self._overrides = {k: v for k, v in self._overrides.items()
                               if v != target}
            if not any(s not in self.dead for s in self.ring):
                return None
        return self.owner(key)

    # ------------------------------------------------------- async put (Fig 4)
    def put_async(self, key: str, value: bytes, *, file: Optional[str] = None,
                  offset: int = 0, coalesce: Optional[bool] = None):
        """Pipelined put (paper Fig 4): fire the value at its owner and
        return immediately; the outstanding msg-id sits in the ACK ledger
        until ``wait_acks`` drains it. Small values (below
        ``coalesce_threshold``, or when ``coalesce=True``) are buffered and
        shipped as one ``put_batch`` per destination server, bounding
        per-message overhead for many-small-tensors checkpoint shapes."""
        self.stats["puts"] += 1
        self.stats["async_puts"] += 1
        self.stats["put_bytes"] += len(value)
        if coalesce is None:
            coalesce = len(value) < self.coalesce_threshold
        try:
            target = self.owner(key)
        except RuntimeError:
            self._failed.append(key)        # surfaced by wait_acks
            return
        if coalesce:
            self._enqueue_batch(target, {"key": key, "value": value,
                                         "file": file, "offset": offset})
        else:
            self._issue(key, value, file, offset, target,
                        redirects=0, attempts=0)

    def _issue(self, key: str, value: bytes, file: Optional[str],
               offset: int, target: str, redirects: int, attempts: int):
        msg_id = self.transport.request_async(
            self.ep, target, "put",
            {"key": key, "value": value, "file": file, "offset": offset,
             "redirectable": redirects < 2},
            sink=self._acks)
        self._ledger[msg_id] = {
            "key": key, "value": value, "file": file, "offset": offset,
            "target": target, "redirects": redirects, "attempts": attempts,
            "deadline": time.monotonic() + self.put_timeout, "batch": None}

    def _enqueue_batch(self, target: str, item: dict):
        self._batch.setdefault(target, []).append(item)
        nb = self._batch_nbytes.get(target, 0) + len(item["value"])
        self._batch_nbytes[target] = nb
        if nb >= self.batch_bytes:
            self._flush_one_batch(target)

    def flush_batches(self):
        """Ship every pending coalesced batch (one put_batch per server)."""
        for target in list(self._batch):
            self._flush_one_batch(target)

    def _flush_one_batch(self, target: str):
        items = self._batch.pop(target, [])
        self._batch_nbytes.pop(target, None)
        if items:
            self._issue_batch(items, target, attempts=0)

    def _issue_batch(self, items: List[dict], target: str, attempts: int):
        self.stats["batches"] += 1
        self.stats["batched_puts"] += len(items)
        msg_id = self.transport.request_async(
            self.ep, target, "put_batch", {"items": items}, sink=self._acks)
        self._ledger[msg_id] = {
            "batch": items, "target": target, "attempts": attempts,
            "deadline": time.monotonic() + self.put_timeout}

    def wait_acks(self, timeout: float = 30.0) -> bool:
        """Drain the ACK ledger (paper Fig 4 thread-2): process redirects by
        re-issuing to the announced server, and expired entries by confirming
        the suspect's failure through its predecessor and re-issuing to the
        failover target. Returns True once every outstanding put (including
        coalesced batches) is acknowledged; False on overall timeout or when
        a put exhausts its retries."""
        self.flush_batches()
        deadline = time.monotonic() + timeout
        next_scan = 0.0          # throttle O(ledger) deadline scans
        while self._ledger:
            now = time.monotonic()
            if now > deadline:
                return self._finish_wait(False)
            try:
                msg = self._acks.get(timeout=0.02)
            except queue.Empty:
                msg = None
            while msg is not None:
                self._on_ack(msg)
                try:
                    msg = self._acks.get_nowait()
                except queue.Empty:
                    msg = None
            now = time.monotonic()
            if now >= next_scan:
                self._check_put_deadlines(now)
                next_scan = now + 0.05
        return self._finish_wait(True)

    def _finish_wait(self, drained: bool) -> bool:
        """Close out a drain cycle. On overall timeout the still-outstanding
        entries are abandoned (cancelled and recorded as failed) so a failed
        cycle can't poison the next checkpoint's barrier; the snapshot keeps
        the failed keys inspectable via failed_keys()."""
        if not drained:
            for mid, e in list(self._ledger.items()):
                self.transport.cancel_async(self.ep, mid)
                items = e.get("batch")
                if items:
                    self._failed.extend(i["key"] for i in items)
                else:
                    self._failed.append(e["key"])
            self._ledger.clear()
        self.last_failed, self._failed = self._failed, []
        return drained and not self.last_failed

    def outstanding(self) -> int:
        return len(self._ledger) + sum(len(v) for v in self._batch.values())

    def failed_keys(self) -> List[str]:
        """Keys that exhausted retries in the last wait_acks cycle."""
        return list(self.last_failed)

    def _on_ack(self, msg: Message):
        entry = self._ledger.pop(msg.reply_to, None)
        if entry is None:
            return                          # late reply for a re-issued put
        self._last_reply[entry["target"]] = time.monotonic()
        if msg.kind in ("put_ack", "put_batch_ack"):
            return
        if msg.kind == "redirect":
            self.stats["redirects"] += 1
            target = msg.payload["target"]
            with self._lock:
                self._overrides[entry["key"]] = target
            self._issue(entry["key"], entry["value"], entry["file"],
                        entry["offset"], target,
                        entry["redirects"] + 1, entry["attempts"] + 1)

    def _check_put_deadlines(self, now: float):
        # a deadline alone does not condemn a server: under pipelined load a
        # healthy target may simply have a deep inbox. Expire an entry only
        # when its server has ALSO acked nothing for a full put_timeout —
        # i.e. the timeout judges per-server liveness, not per-message queue
        # position. A dead server acks nothing, so real failures still fire.
        expired = [mid for mid, e in self._ledger.items()
                   if e["deadline"] < now
                   and self._last_reply.get(e["target"], -1e9)
                   + self.put_timeout < now]
        for mid in expired:
            e = self._ledger.pop(mid)
            self.transport.cancel_async(self.ep, mid)
            items = e.get("batch")
            first_key = items[0]["key"] if items else e["key"]
            failover = None
            if e["attempts"] + 1 < self.MAX_ATTEMPTS:
                failover = self._handle_timeout(first_key, e["target"])
            if failover is None:        # retries exhausted or no servers left
                if items:
                    self._failed.extend(i["key"] for i in items)
                else:
                    self._failed.append(e["key"])
                continue
            if items:
                # regroup by post-failover owners (ketama may split the batch)
                groups: Dict[str, List[dict]] = {}
                for it in items:
                    groups.setdefault(self.owner(it["key"]), []).append(it)
                for tgt, its in groups.items():
                    self._issue_batch(its, tgt, e["attempts"] + 1)
            else:
                self._issue(e["key"], e["value"], e["file"], e["offset"],
                            self.owner(e["key"]), e["redirects"],
                            e["attempts"] + 1)

    # ------------------------------------------------------------------- get
    def get(self, key: str) -> Optional[bytes]:
        """Read back a buffered value, trying primary then replicas."""
        self.stats["gets"] += 1
        try:
            replicas = self.replica_set(key)
        except RuntimeError:
            return None
        for target in replicas:
            r = self.transport.request(self.ep, target, "get", {"key": key},
                                       timeout=1.0)
            if r is not None and r.payload.get("hit"):
                self.stats["bb_hits"] += 1
                return r.payload["value"]
        return None

    def file_info(self, file: str):
        try:
            replicas = self.replica_set(file)
        except RuntimeError:
            return None
        for target in replicas:
            r = self.transport.request(self.ep, target, "file_info",
                                       {"file": file}, timeout=1.0)
            if r is not None and r.payload.get("size") is not None:
                return r.payload
        return None

    def read_file(self, file: str, offset: int, length: int
                  ) -> Optional[bytes]:
        """Post-flush read through the lookup table (paper §III-C): locate
        the domain owners for the range and fetch without touching the PFS."""
        info = self.file_info(file)
        if info is None:
            return None
        out = bytearray(length)
        for server, a, b in info["domains"]:
            lo, hi = max(offset, a), min(offset + length, b)
            if lo >= hi:
                continue
            r = self.transport.request(
                self.ep, server, "read_range",
                {"file": file, "offset": lo, "length": hi - lo}, timeout=2.0)
            if r is None:
                return None
            out[lo - offset:hi - offset] = r.payload["data"]
        return bytes(out)
