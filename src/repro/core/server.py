"""Burst buffer server daemon (paper §II, §III, §IV).

One thread per server. Responsibilities:
  - store key-value pairs in the log-structured DRAM/SSD store (tiering.py)
  - chain replication along ring successors with ACKs back to the primary
    (paper Fig 4), pipelined: the primary ACKs the client once its own store
    plus R-1 successor ACKs have arrived
  - load-balanced buffering (paper §III-A): when DRAM is exhausted, query
    ring neighbours for free memory and redirect the client to the best one
  - Chord-style stabilization (paper §IV-A): periodic ping of PRE/SUC1/SUC2;
    on a dead successor, splice it out, adopt the next, inform the manager
  - two-phase I/O flush (paper §III-B): all-to-all metadata exchange, file
    domains, shuffle, one sequential PFS write per domain
  - post-shuffle lookup table (paper §III-C): (file -> global size), from
    which any server can compute which peer owns any byte range
"""
from __future__ import annotations

import os
import threading
import time
from typing import Dict, List, Optional

from repro.core import twophase
from repro.core.tiering import LogStore
from repro.core.transport import Message, Transport


def _merge_intervals(iv: List[List[int]]) -> List[List[int]]:
    out: List[List[int]] = []
    for lo, hi in sorted(iv):
        if out and lo <= out[-1][1]:
            out[-1][1] = max(out[-1][1], hi)
        else:
            out.append([lo, hi])
    return out


def _gaps(covered: List[List[int]], lo: int, hi: int) -> List[List[int]]:
    """Sub-intervals of [lo, hi) not covered by the (merged) interval list."""
    gaps = []
    pos = lo
    for a, b in covered:
        if a > pos:
            gaps.append([pos, min(a, hi)])
        pos = max(pos, b)
        if pos >= hi:
            break
    if pos < hi:
        gaps.append([pos, hi])
    return [g for g in gaps if g[0] < g[1]]


class BBServer(threading.Thread):
    def __init__(self, name: str, transport: Transport, *,
                 dram_capacity: int = 64 << 20,
                 ssd_dir: Optional[str] = None,
                 pfs_dir: str = "/tmp/pfs",
                 replication: int = 2,
                 stabilize_interval: float = 0.25):
        super().__init__(daemon=True, name=name)
        self.tname = name
        self.transport = transport
        self.ep = transport.register(name)
        self.store = LogStore(dram_capacity, ssd_dir, name=name.replace("/", "_"))
        self.pfs_dir = pfs_dir
        self.replication = replication
        self.stabilize_interval = stabilize_interval

        self.ring: List[str] = []            # manager-ordered server list
        self.alive: Dict[str, bool] = {}
        self.manager = "manager"
        self._stop = threading.Event()
        self._last_stab = 0.0

        # replication bookkeeping, keyed by (client, msg_id) so a stray or
        # colliding replica_ack can never satisfy an unrelated client's put:
        # (client, msg_id) -> [client, acks_needed, original_msg]
        self._pending_primary: Dict[tuple, List] = {}
        # segments buffered for flush: key -> Segment
        self._segments: Dict[str, twophase.Segment] = {}
        # per-file chunk manifest (BBFileSystem metadata path):
        # file -> {offset: (key, length)} — same facts as _segments, indexed
        # by file so open/stat/read never scan every buffered key
        self._files: Dict[str, Dict[int, tuple]] = {}
        # flush state per epoch
        self._flush: Dict[int, dict] = {}
        # post-shuffle lookup table: file -> global size (paper §III-C)
        self.lookup_table: Dict[str, int] = {}
        # domain data received from shuffle: (file, offset) -> bytes
        self._domain_data: Dict[str, Dict[int, bytes]] = {}
        self.stats = {"puts": 0, "batch_puts": 0, "redirects": 0, "spills": 0,
                      "flushes": 0, "stabilize_repairs": 0}
        # async stabilization state
        self._inflight_pings: Dict[int, tuple] = {}   # nonce -> (peer, deadline)
        self._ping_misses: Dict[str, int] = {}
        self._last_pong: Dict[str, float] = {}
        self._neighbor_free: Dict[str, int] = {}      # gossiped free DRAM
        self._pending_confirms: List[list] = []

    # ------------------------------------------------------------- ring math
    def _idx(self) -> int:
        return self.ring.index(self.tname)

    def successors(self, n: Optional[int] = None) -> List[str]:
        n = n if n is not None else self.replication
        if self.tname not in self.ring:
            return []
        i = self._idx()
        out = []
        for j in range(1, len(self.ring)):
            s = self.ring[(i + j) % len(self.ring)]
            if self.alive.get(s, True) and s != self.tname:
                out.append(s)
            if len(out) >= n:
                break
        return out

    def predecessor(self) -> Optional[str]:
        if self.tname not in self.ring:
            return None
        i = self._idx()
        for j in range(1, len(self.ring)):
            s = self.ring[(i - j) % len(self.ring)]
            if self.alive.get(s, True) and s != self.tname:
                return s
        return None

    def alive_ring(self) -> List[str]:
        return [s for s in self.ring if self.alive.get(s, True)]

    # ---------------------------------------------------------------- thread
    def run(self):
        while not self._stop.is_set():
            msg = self.ep.recv(timeout=0.02)
            now = time.monotonic()
            if msg is not None:
                try:
                    self._dispatch(msg)
                except Exception as e:   # pragma: no cover - defensive
                    self.transport.send(self.tname, self.manager, "server_error",
                                        {"server": self.tname, "error": repr(e)})
            if now - self._last_stab > self.stabilize_interval and self.ring:
                self._last_stab = now
                self._stabilize(now)
            self._check_ping_deadlines(now)
            self._check_confirm_deadlines(now)

    def stop(self):
        self._stop.set()

    # -------------------------------------------------------------- dispatch
    def _dispatch(self, msg: Message):
        handler = getattr(self, f"_on_{msg.kind}", None)
        if handler is None:
            return
        handler(msg)

    # ring bootstrap / updates -------------------------------------------
    def _on_ring(self, msg: Message):
        self.ring = list(msg.payload["ring"])
        self.alive = {s: True for s in self.ring}

    def _on_ring_update(self, msg: Message):
        dead = msg.payload.get("dead", [])
        joined = msg.payload.get("joined", [])
        for s in dead:
            self.alive[s] = False
        for s in joined:
            if s not in self.ring:
                # join at the announced position (paper Fig 3)
                pred = msg.payload.get("pred")
                if pred in self.ring:
                    self.ring.insert(self.ring.index(pred) + 1, s)
                else:
                    self.ring.append(s)
            self.alive[s] = True
        if dead:
            self._re_replicate()

    # put path -------------------------------------------------------------
    def _record_segment(self, key: str, file: Optional[str], offset: int,
                        length: int):
        """Track a buffered chunk in both flush-segment and per-file views."""
        if file is None:
            return
        old = self._segments.get(key)
        if old is not None:
            fmap = self._files.get(old.file)
            if fmap is not None and fmap.get(old.offset, (None, 0))[0] == key:
                del fmap[old.offset]
        self._segments[key] = twophase.Segment(file, offset, length)
        self._files.setdefault(file, {})[offset] = (key, length)

    def _drop_segment(self, key: str):
        seg = self._segments.pop(key, None)
        if seg is None:
            return
        fmap = self._files.get(seg.file)
        if fmap is not None and fmap.get(seg.offset, (None, 0))[0] == key:
            del fmap[seg.offset]
            if not fmap:
                del self._files[seg.file]

    def _on_put(self, msg: Message):
        p = msg.payload
        key, value = p["key"], p["value"]
        self.stats["puts"] += 1

        # load-balanced buffering: redirect if DRAM exhausted (paper §III-A)
        if p.get("redirectable", True) \
                and self.store.dram_free() < len(value):
            target = self._least_loaded_neighbor(len(value))
            if target is not None:
                self.stats["redirects"] += 1
                self.transport.reply(self.tname, msg, "redirect",
                                     {"key": key, "target": target})
                return

        tier = self.store.put(key, value)
        if tier == "ssd":
            self.stats["spills"] += 1
        self._record_segment(key, p.get("file"), p.get("offset", 0),
                             len(value))

        chain: List[str] = p.get("chain")
        if chain is None:
            chain = self.successors(self.replication - 1)
        if chain:
            nxt, rest = chain[0], chain[1:]
            self._pending_primary[(msg.src, msg.msg_id)] = \
                [msg.src, len(chain), msg]
            self.transport.send(self.tname, nxt, "replica_put", {
                "key": key, "value": value, "chain": rest,
                "primary": self.tname, "primary_msg": msg.msg_id,
                "client": msg.src,
                "file": p.get("file"), "offset": p.get("offset", 0)})
        else:
            self.transport.reply(self.tname, msg, "put_ack", {"key": key})

    def _on_put_batch(self, msg: Message):
        """Coalesced put (client write coalescing): store every segment in
        one message, replicate the whole batch down the chain, ACK once.
        Batches are never redirected — the store spills to SSD instead, so
        the per-batch cost stays a single round-trip."""
        items = msg.payload["items"]
        self.stats["puts"] += len(items)
        self.stats["batch_puts"] += 1
        for it in items:
            tier = self.store.put(it["key"], it["value"])
            if tier == "ssd":
                self.stats["spills"] += 1
            self._record_segment(it["key"], it.get("file"),
                                 it.get("offset", 0), len(it["value"]))
        chain = self.successors(self.replication - 1)
        if chain:
            nxt, rest = chain[0], chain[1:]
            self._pending_primary[(msg.src, msg.msg_id)] = \
                [msg.src, len(chain), msg]
            self.transport.send(self.tname, nxt, "replica_put_batch", {
                "items": items, "chain": rest, "primary": self.tname,
                "primary_msg": msg.msg_id, "client": msg.src})
        else:
            self.transport.reply(self.tname, msg, "put_batch_ack",
                                 {"count": len(items)})

    def _on_replica_put(self, msg: Message):
        p = msg.payload
        self.store.put(p["key"], p["value"])
        self._record_segment(p["key"], p.get("file"), p.get("offset", 0),
                             len(p["value"]))
        if p["chain"]:
            nxt, rest = p["chain"][0], p["chain"][1:]
            self.transport.send(self.tname, nxt, "replica_put",
                                {**p, "chain": rest})
        if p.get("primary_msg") is None:
            return              # re-replication copy: nobody is waiting
        self.transport.send(self.tname, p["primary"], "replica_ack",
                            {"primary_msg": p["primary_msg"],
                             "client": p.get("client"), "key": p["key"]})

    def _on_replica_put_batch(self, msg: Message):
        p = msg.payload
        for it in p["items"]:
            self.store.put(it["key"], it["value"])
            self._record_segment(it["key"], it.get("file"),
                                 it.get("offset", 0), len(it["value"]))
        if p["chain"]:
            nxt, rest = p["chain"][0], p["chain"][1:]
            self.transport.send(self.tname, nxt, "replica_put_batch",
                                {**p, "chain": rest})
        self.transport.send(self.tname, p["primary"], "replica_ack",
                            {"primary_msg": p["primary_msg"],
                             "client": p.get("client"),
                             "key": p["items"][0]["key"]})

    def _on_replica_ack(self, msg: Message):
        pm = msg.payload.get("primary_msg")
        if pm is None:
            return              # re-replication sentinel: not a client put
        entry = self._pending_primary.get((msg.payload.get("client"), pm))
        if entry is None:
            return
        entry[1] -= 1
        if entry[1] <= 0:
            client, _, orig = self._pending_primary.pop(
                (msg.payload.get("client"), pm))
            if orig.kind == "put_batch":
                self.transport.reply(self.tname, orig, "put_batch_ack",
                                     {"count": len(orig.payload["items"])})
            else:
                self.transport.reply(self.tname, orig, "put_ack",
                                     {"key": msg.payload["key"]})

    def _least_loaded_neighbor(self, need: int) -> Optional[str]:
        """Pick the neighbour with the most free DRAM (paper §III-A). Free-
        memory info is gossiped on every stabilization pong, so this is a
        local lookup — the server loop never blocks on an RPC."""
        best, best_free = None, max(self.store.dram_free(), need)
        for peer, free in self._neighbor_free.items():
            if peer != self.tname and self.alive.get(peer, False) \
                    and free > best_free:
                best, best_free = peer, free
        return best

    def _on_mem_query(self, msg: Message):
        self.transport.reply(self.tname, msg, "mem_info",
                             {"free": self.store.dram_free()})

    # get path -------------------------------------------------------------
    def _on_get(self, msg: Message):
        key = msg.payload["key"]
        val = self.store.get(key)
        if val is not None:
            self.transport.reply(self.tname, msg, "get_ack",
                                 {"key": key, "value": val, "hit": True})
            return
        self.transport.reply(self.tname, msg, "get_ack",
                             {"key": key, "value": None, "hit": False})

    def _on_read_range(self, msg: Message):
        """Serve a post-shuffle byte range of a flushed file (paper §III-C)."""
        p = msg.payload
        f, off, length = p["file"], p["offset"], p["length"]
        chunks = self._domain_data.get(f, {})
        buf = bytearray(length)
        covered = []                        # [lo, hi) intervals, file space
        for base, data in chunks.items():
            lo = max(off, base)
            hi = min(off + length, base + len(data))
            if lo < hi:
                buf[lo - off:hi - off] = data[lo - base:hi - base]
                covered.append([lo, hi])
        covered = _merge_intervals(covered)
        filled = sum(hi - lo for lo, hi in covered)
        if filled < length:
            # fill only the gaps from the PFS — buffered chunks are at least
            # as fresh as the durable copy and must not be clobbered
            path = os.path.join(self.pfs_dir, f)
            if os.path.exists(path):
                with open(path, "rb") as fh:
                    fh.seek(off)
                    pfs = fh.read(length)
                for lo, hi in _gaps(covered, off, off + len(pfs)):
                    buf[lo - off:hi - off] = pfs[lo - off:hi - off]
                    covered.append([lo, hi])
                covered = _merge_intervals(covered)
                filled = sum(hi - lo for lo, hi in covered)
        self.transport.reply(self.tname, msg, "range_ack",
                             {"data": bytes(buf), "complete": filled >= length})

    def _on_file_info(self, msg: Message):
        f = msg.payload["file"]
        size = self.lookup_table.get(f)
        doms = None
        if size is not None:
            doms = twophase.domains(size, self.alive_ring())
        self.transport.reply(self.tname, msg, "file_info_ack",
                             {"file": f, "size": size, "domains": doms})

    # file-session metadata (BBFileSystem) ---------------------------------
    def _file_stat_payload(self, f: str) -> dict:
        fmap = self._files.get(f, {})
        buffered = max((off + ln for off, (_, ln) in fmap.items()), default=0)
        return {"file": f, "buffered": buffered, "chunks": len(fmap),
                "flushed_size": self.lookup_table.get(f),
                "known": f in self._files or f in self.lookup_table}

    def _on_file_stat(self, msg: Message):
        """Per-file metadata: buffered extent + chunk count from the local
        manifest, durable size from the post-shuffle lookup table."""
        self.transport.reply(self.tname, msg, "file_stat_ack",
                             self._file_stat_payload(msg.payload["file"]))

    def _on_file_chunks(self, msg: Message):
        """The local chunk manifest for one file: [(offset, key, length)].
        Clients merge manifests across servers to assemble buffered reads
        without knowing the writer's striping."""
        fmap = self._files.get(msg.payload["file"], {})
        chunks = [[off, key, ln] for off, (key, ln) in fmap.items()]
        self.transport.reply(self.tname, msg, "file_chunks_ack",
                             {"file": msg.payload["file"], "chunks": chunks})

    def _on_file_truncate(self, msg: Message):
        """Open-for-write truncation: drop every buffered chunk of the file
        (primary and replica copies alike — the message is broadcast), its
        shuffle data, and its lookup-table entry, so a rewrite can never
        read back stale tail bytes from a longer previous incarnation."""
        f = msg.payload["file"]
        for off, (key, _ln) in self._files.pop(f, {}).items():
            self.store.delete(key)
            self._segments.pop(key, None)
        self.lookup_table.pop(f, None)
        self._domain_data.pop(f, None)
        self.transport.reply(self.tname, msg, "file_truncate_ack",
                             {"file": f})

    # stabilization --------------------------------------------------------
    # Fully asynchronous (the server loop never blocks): pings are fired and
    # tracked with deadlines; pongs piggyback free-DRAM gossip (paper §III-A
    # + §IV-A in one mechanism). Missing ``miss_limit`` consecutive pongs
    # marks the neighbour dead — splice, adopt next successor, tell manager.

    MISS_LIMIT = 3
    PING_TIMEOUT = 0.6

    def _stabilize(self, now: float):
        for s in self.successors(2):
            if any(peer == s for peer, _ in self._inflight_pings.values()):
                continue
            nonce = self._ping_nonce = getattr(self, "_ping_nonce", 0) + 1
            self._inflight_pings[nonce] = (s, now + self.PING_TIMEOUT)
            self.transport.send(self.tname, s, "ping",
                                {"nonce": nonce, "from": self.tname})

    def _check_ping_deadlines(self, now: float):
        expired = [n for n, (peer, dl) in self._inflight_pings.items()
                   if dl < now]
        for n in expired:
            peer, _ = self._inflight_pings.pop(n)
            self._ping_misses[peer] = self._ping_misses.get(peer, 0) + 1
            if self._ping_misses[peer] >= self.MISS_LIMIT \
                    and self.alive.get(peer, False):
                self._declare_dead(peer)

    def _declare_dead(self, peer: str):
        self.alive[peer] = False
        self.stats["stabilize_repairs"] += 1
        nxt = self.successors(1)
        if nxt:
            self.transport.send(self.tname, nxt[0], "neighbor_died",
                                {"dead": peer})
        self.transport.send(self.tname, self.manager, "failure_report",
                            {"dead": peer, "reporter": self.tname})
        self._re_replicate()

    def _on_ping(self, msg: Message):
        self.transport.send(self.tname, msg.src, "pong",
                            {"nonce": msg.payload["nonce"],
                             "free": self.store.dram_free()})

    def _on_pong(self, msg: Message):
        self._inflight_pings.pop(msg.payload["nonce"], None)
        self._ping_misses[msg.src] = 0
        self._last_pong[msg.src] = time.monotonic()
        self._neighbor_free[msg.src] = msg.payload["free"]
        # a pong from a node we thought dead -> it is back (partition healed)
        if not self.alive.get(msg.src, True):
            self.alive[msg.src] = True

    def _on_neighbor_died(self, msg: Message):
        dead = msg.payload["dead"]
        if self.alive.get(dead, True):
            self.alive[dead] = False
            self._re_replicate()

    def _on_confirm_failure(self, msg: Message):
        """Client-initiated confirmation via the predecessor (paper §IV-B2):
        fire a probe ping; reply when the pong arrives or the deadline
        passes (non-blocking state machine)."""
        suspect = msg.payload["suspect"]
        nonce = self._ping_nonce = getattr(self, "_ping_nonce", 0) + 1
        now = time.monotonic()
        self._pending_confirms.append([msg, suspect, now,
                                       now + self.PING_TIMEOUT])
        self.transport.send(self.tname, suspect, "ping",
                            {"nonce": nonce, "from": self.tname})

    def _check_confirm_deadlines(self, now: float):
        still = []
        for entry in self._pending_confirms:
            msg, suspect, started, deadline = entry
            if self._last_pong.get(suspect, -1.0) >= started:
                self.transport.reply(self.tname, msg, "failure_confirmed",
                                     {"suspect": suspect, "confirmed": False})
            elif deadline < now:
                if self.alive.get(suspect, True):
                    self._declare_dead(suspect)
                self.transport.reply(self.tname, msg, "failure_confirmed",
                                     {"suspect": suspect, "confirmed": True})
            else:
                still.append(entry)
        self._pending_confirms = still

    def _re_replicate(self):
        """Restore replication factor for keys this server holds after a
        membership change: re-forward to the current successor chain."""
        chain = self.successors(self.replication - 1)
        for key in self.store.keys():
            seg = self._segments.get(key)
            for peer in chain:
                # primary_msg None is the "no client is waiting" sentinel:
                # replicas store the copy but send no replica_ack, so these
                # copies can never satisfy a pending client put
                self.transport.send(self.tname, peer, "replica_put", {
                    "key": key, "value": self.store.get(key), "chain": [],
                    "primary": self.tname, "primary_msg": None,
                    "client": None,
                    "file": seg.file if seg else None,
                    "offset": seg.offset if seg else 0})

    # two-phase flush --------------------------------------------------------
    def _flush_state(self, epoch: int) -> dict:
        """Per-epoch flush state. The ring is snapshotted ONCE, when the
        epoch is first seen: shuffle planning and the PFS write must use the
        same membership view, otherwise servers that observe a death or join
        mid-flush compute different domain ownership and bytes get dropped
        or double-written."""
        return self._flush.setdefault(epoch, {
            "meta": {}, "done": set(),
            "ring": self.alive_ring(),
            "expected": set(self.alive_ring())})

    def _on_flush_begin(self, msg: Message):
        """Phase 1: broadcast my segment metadata to every live server."""
        epoch = msg.payload["epoch"]
        metas = [(s.file, s.offset, s.length, k)
                 for k, s in self._segments.items()]
        st = self._flush_state(epoch)
        for peer in st["ring"]:
            self.transport.send(self.tname, peer, "flush_meta",
                                {"epoch": epoch, "from": self.tname,
                                 "metas": metas})

    def _on_flush_meta(self, msg: Message):
        epoch = msg.payload["epoch"]
        st = self._flush_state(epoch)
        st["meta"][msg.payload["from"]] = msg.payload["metas"]
        if set(st["meta"]) >= st["expected"]:
            self._shuffle(epoch, st)

    def _shuffle(self, epoch: int, st: dict):
        """Phase 2: ship segments to domain owners (epoch ring snapshot)."""
        all_meta = {
            src: [twophase.Segment(f, o, l) for f, o, l, _ in metas]
            for src, metas in st["meta"].items()}
        mine = list(self._segments.values())
        sizes, doms, sends = twophase.plan_shuffle(
            mine, all_meta, st["ring"])
        self.lookup_table.update(sizes)
        key_of = {(s.file, s.offset): k for k, s in self._segments.items()}
        for owner, seg, file_off, local_off, length in sends:
            data = self.store.get(key_of[(seg.file, seg.offset)])
            piece = data[local_off:local_off + length]
            self.transport.send(self.tname, owner, "shuffle_data",
                                {"epoch": epoch, "file": seg.file,
                                 "offset": file_off, "data": piece})
        for peer in st["ring"]:
            self.transport.send(self.tname, peer, "shuffle_done",
                                {"epoch": epoch, "from": self.tname,
                                 "sizes": sizes})

    def _on_shuffle_data(self, msg: Message):
        p = msg.payload
        self._domain_data.setdefault(p["file"], {})[p["offset"]] = p["data"]

    def _on_shuffle_done(self, msg: Message):
        epoch = msg.payload["epoch"]
        st = self._flush_state(epoch)
        st["done"].add(msg.payload["from"])
        self.lookup_table.update(msg.payload["sizes"])
        if st["done"] >= st["expected"]:
            self._write_pfs(epoch, st)

    def _write_pfs(self, epoch: int, st: dict):
        """Phase 2b: one sequential write per owned file domain, with domain
        ownership computed from the epoch's ring snapshot (see _flush_state)."""
        os.makedirs(self.pfs_dir, exist_ok=True)
        written = 0
        for f, size in sorted(self.lookup_table.items()):
            doms = twophase.domains(size, st["ring"])
            my = [(a, b) for s, a, b in doms if s == self.tname]
            if not my:
                continue
            path = os.path.join(self.pfs_dir, f)
            with open(path, "r+b" if os.path.exists(path) else "w+b") as fh:
                for a, b in my:
                    chunks = self._domain_data.get(f, {})
                    buf = bytearray(b - a)
                    for base, data in sorted(chunks.items()):
                        lo, hi = max(a, base), min(b, base + len(data))
                        if lo < hi:
                            buf[lo - a:hi - a] = data[lo - base:hi - base]
                    fh.seek(a)
                    fh.write(bytes(buf))      # single sequential write
                    written += b - a
        self.stats["flushes"] += 1
        self._flush.pop(epoch, None)
        self.transport.send(self.tname, self.manager, "flush_done",
                            {"epoch": epoch, "server": self.tname,
                             "bytes": written})

    # checkpoint retention ---------------------------------------------------
    def _on_evict_epoch(self, msg: Message):
        prefix = msg.payload["prefix"]
        for key in list(self.store.keys()):
            if key.startswith(prefix):
                self.store.delete(key)
                self._drop_segment(key)
        self.store.compact()
        for f in list(self._domain_data):
            if f.startswith(prefix):
                del self._domain_data[f]
        for f in list(self._files):
            if f.startswith(prefix):
                del self._files[f]

    def _on_stats_query(self, msg: Message):
        self.transport.reply(self.tname, msg, "stats", {
            **self.stats, "dram_used": self.store.dram_used,
            "ssd_used": self.store.ssd_used,
            "keys": len(self.store.keys()),
            "lookup_files": len(self.lookup_table)})
