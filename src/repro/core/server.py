"""Burst buffer server daemon (paper §II, §III, §IV).

One thread per server. Responsibilities:
  - store key-value pairs in the log-structured DRAM/SSD store (tiering.py)
  - chain replication along ring successors with ACKs back to the primary
    (paper Fig 4), pipelined: the primary ACKs the client once its own store
    plus R-1 successor ACKs have arrived
  - load-balanced buffering (paper §III-A): when DRAM is exhausted, query
    ring neighbours for free memory and redirect the client to the best one
  - Chord-style stabilization (paper §IV-A): periodic ping of PRE/SUC1/SUC2;
    on a dead successor, splice it out, adopt the next, inform the manager
  - two-phase I/O flush (paper §III-B): all-to-all metadata exchange, file
    domains, shuffle, one sequential PFS write per domain
  - post-shuffle lookup table (paper §III-C): (file -> global size), from
    which any server can compute which peer owns any byte range
  - autonomous drain engine (ISSUE 3): watermark policy over LogStore
    occupancy requests manager-coordinated drain micro-epochs that push
    whole cold segments through the two-phase planner, then evict them
    (index tombstones) once every participant reported the epoch durable;
    a burst detector defers draining while ingest is hot and a token
    bucket caps drain bandwidth so flushing never competes with absorption
  - stage-in engine (ISSUE 4): the drain run in reverse — a manager-
    coordinated stage epoch re-ingests a PFS file into the buffer,
    partitioned by lookup-table domains so every server loads its own
    domain in parallel; staged bytes are marked CLEAN (durable copy
    exists), giving the drainer a free clean-evict fast path and staging
    an admission guard so it can never trigger a drain storm
"""
from __future__ import annotations

import os
import threading
import time
from typing import Callable, Dict, List, Optional

from repro.core import qos, staging, telemetry, twophase
from repro.core.drain import DrainConfig, DrainEngine
from repro.core.qos import QoSConfig
from repro.core.staging import StageConfig
from repro.core.tiering import LogStore
from repro.core.transport import Message, Transport


# interval math shared with the stage planner (one implementation)
_merge_intervals = staging.merge_intervals
_gaps = staging.gaps


class BBServer(threading.Thread):
    def __init__(self, name: str, transport: Transport, *,
                 dram_capacity: int = 64 << 20,
                 ssd_dir: Optional[str] = None,
                 ssd_capacity: Optional[int] = None,
                 segment_bytes: Optional[int] = None,
                 pfs_dir: str = "/tmp/pfs",
                 replication: int = 2,
                 stabilize_interval: float = 0.25,
                 poll_interval: float = 0.02,
                 drain: Optional[DrainConfig] = None,
                 stage: Optional[StageConfig] = None,
                 qos_cfg: Optional[QoSConfig] = None,
                 clock: Callable[[], float] = time.monotonic):
        super().__init__(daemon=True, name=name)
        self.tname = name
        self._clock = clock
        self.transport = transport
        self.ep = transport.register(name)
        self.store = LogStore(dram_capacity, ssd_dir,
                              name=name.replace("/", "_"),
                              ssd_capacity=ssd_capacity,
                              segment_bytes=segment_bytes,
                              clock=clock)
        self.pfs_dir = pfs_dir
        self.replication = replication
        self.stabilize_interval = stabilize_interval
        self.poll_interval = poll_interval
        self.drain_cfg = drain or DrainConfig()
        # QoS (ISSUE 5): lane-priority dequeue of buffered puts, plus ONE
        # background-bandwidth arbiter shared by the drain + stage engines
        self.qos_cfg = qos_cfg or QoSConfig()
        if self.qos_cfg.enabled:
            self.arbiter: Optional[qos.BandwidthArbiter] = \
                qos.BandwidthArbiter(self.qos_cfg,
                                     self.drain_cfg.bw_bytes_per_s)
            self._laneq: Optional[qos.LaneQueue] = qos.LaneQueue(
                self.qos_cfg.lane_weights, self.qos_cfg.quantum_bytes)
        else:
            self.arbiter = None
            self._laneq = None
        self.drainer = DrainEngine(self.drain_cfg, bucket=self.arbiter) \
            if self.drain_cfg.enabled else None
        self.stage_cfg = stage or StageConfig()

        self.ring: List[str] = []            # manager-ordered server list
        self.alive: Dict[str, bool] = {}
        self.manager = "manager"
        self._stop = threading.Event()
        self._last_stab = 0.0

        # replication bookkeeping, keyed by (client, msg_id) so a stray or
        # colliding replica_ack can never satisfy an unrelated client's put:
        # (client, msg_id) -> [client, acks_needed, original_msg]
        self._pending_primary: Dict[tuple, List] = {}
        # segments buffered for flush: key -> Segment
        self._segments: Dict[str, twophase.Segment] = {}
        # per-file chunk manifest (BBFileSystem metadata path):
        # file -> {offset: (key, length)} — same facts as _segments, indexed
        # by file so open/stat/read never scan every buffered key
        self._files: Dict[str, Dict[int, tuple]] = {}
        # flush state per epoch
        self._flush: Dict[int, dict] = {}
        # post-shuffle lookup table: file -> global size (paper §III-C)
        self.lookup_table: Dict[str, int] = {}
        # domain data received from shuffle: (file, offset) -> bytes
        self._domain_data: Dict[str, Dict[int, bytes]] = {}
        # drain-engine bookkeeping: evicted-chunk tombstone records (the
        # transparent read path needs (file, offset, length) to fall through
        # to the lookup table / PFS) and per-drain-epoch snapshots
        self._evicted: Dict[str, tuple] = {}     # key -> (file, off, len)
        self._evicted_files: Dict[str, Dict[int, tuple]] = {}
        self._drain_epochs: Dict[int, dict] = {}  # epoch -> keys/gens/bytes
        # stage-in epochs (ISSUE 4): epoch -> coverage metas + range state
        self._stage_epochs: Dict[int, dict] = {}
        # epochs already written or aborted: late flush_meta/shuffle_done
        # stragglers must not resurrect them through _flush_state's
        # auto-create (a zombie entry would wedge self._flush forever and
        # block the _domain_data reclamation gated on it)
        self._closed_epochs: set = set()
        self._last_pressure = 0.0
        self.stats = {"puts": 0, "batch_puts": 0, "redirects": 0, "spills": 0,
                      "flushes": 0, "stabilize_repairs": 0,
                      "drain_epochs": 0, "drained_bytes": 0, "evictions": 0,
                      "stage_epochs": 0, "staged_bytes": 0,
                      "clean_evictions": 0, "clean_evicted_bytes": 0,
                      "bypass_chunks": 0, "bypass_bytes": 0,
                      "recovered_keys": 0, "recovered_bytes": 0,
                      "puts_by_lane": [0] * len(qos.LANES)}
        # unknown-kind messages (protocol black-hole detector, ISSUE 6):
        # kind -> count; surfaced in drain_pressure and stats_query, and the
        # first occurrence of each kind is reported as a server_error
        self.unknown_kinds: Dict[str, int] = {}
        # telemetry (ISSUE 9): _tele is captured once — when telemetry is
        # disabled the factories hand back the shared no-op and the guarded
        # clock stamps below are skipped, so the per-message path is free
        self._tele = telemetry.enabled()
        self._m_lane_wait = telemetry.histogram("server.lane_wait_s")
        self._m_dispatch = telemetry.histogram("server.dispatch_s")
        self._m_occ = telemetry.ring("server.occupancy")
        telemetry.poll("server.ops", self._stats_snapshot, label=name)
        # async stabilization state
        self._inflight_pings: Dict[int, tuple] = {}   # nonce -> (peer, deadline)
        self._ping_misses: Dict[str, int] = {}
        self._last_pong: Dict[str, float] = {}
        self._neighbor_free: Dict[str, int] = {}      # gossiped free DRAM
        self._pending_confirms: List[list] = []

    # ------------------------------------------------------------- ring math
    def _idx(self) -> int:
        return self.ring.index(self.tname)

    def successors(self, n: Optional[int] = None) -> List[str]:
        n = n if n is not None else self.replication
        if self.tname not in self.ring:
            return []
        i = self._idx()
        out = []
        for j in range(1, len(self.ring)):
            s = self.ring[(i + j) % len(self.ring)]
            if self.alive.get(s, True) and s != self.tname:
                out.append(s)
            if len(out) >= n:
                break
        return out

    def predecessor(self) -> Optional[str]:
        if self.tname not in self.ring:
            return None
        i = self._idx()
        for j in range(1, len(self.ring)):
            s = self.ring[(i - j) % len(self.ring)]
            if self.alive.get(s, True) and s != self.tname:
                return s
        return None

    def alive_ring(self) -> List[str]:
        return [s for s in self.ring if self.alive.get(s, True)]

    # ---------------------------------------------------------------- thread
    def run(self):
        # Crash recovery (ISSUE 8): if the LogStore came up over a surviving
        # SSD log, rebuild the chunk manifests from the recovered keys
        # before touching the inbox — messages just queue up meanwhile, so
        # no read can observe a half-rebuilt manifest.
        self._recover_manifests()
        while not self._stop.is_set():
            # With QoS enabled, the inbox is drained in bursts: control
            # messages dispatch immediately (reads and pings stay responsive
            # under a put flood), while put/put_batch messages park in the
            # lane queue and are applied below in weighted priority order —
            # a checkpoint burst no longer waits behind every background put
            # that happened to arrive first.
            busy = self._laneq is not None and len(self._laneq) > 0
            msg = self.ep.recv(timeout=0.0 if busy else self.poll_interval)
            burst = self.qos_cfg.server_recv_burst
            while msg is not None:
                self._safe_dispatch(msg)
                burst -= 1
                if burst <= 0:
                    break
                msg = self.ep.recv(timeout=0)
            if self._laneq is not None:
                for _ in range(self.qos_cfg.server_ops_per_tick):
                    ent = self._laneq.pop()
                    if ent is None:
                        break
                    self._safe_dispatch(ent, queued=True)
            now = self._clock()
            if now - self._last_stab > self.stabilize_interval and self.ring:
                self._last_stab = now
                self._stabilize(now)
            self._check_ping_deadlines(now)
            self._check_confirm_deadlines(now)
            self._drain_tick(now)
            self._stage_tick(now)

    def _safe_dispatch(self, msg: Message, queued: bool = False):
        try:
            if not queued and self._qos_enqueue(msg):
                return
            if not self._tele:
                self._dispatch(msg)
                return
            lane_name = None
            if msg.kind in self._LANED_KINDS:
                lane = msg.payload.get("lane")
                lane_name = qos.LANES[qos.LANE_INTERACTIVE if lane is None
                                      else qos.lane_index(lane)]
                parked = getattr(msg, "_parked_at", 0.0)
                if parked:
                    wait = self._clock() - parked
                    self._m_lane_wait.observe(wait, label=lane_name)
                    # a parked message has no thread to hold a span open,
                    # so the wait is recorded as an already-completed span
                    # under the put's trace — the health engine's critical-
                    # path pass reads it as the "queue" segment (ISSUE 10)
                    telemetry.observe_span(
                        "server.lane_wait", self.tname,
                        telemetry.trace_from(msg.payload), parked, wait,
                        lane=lane_name)
            t0 = self._clock()
            with telemetry.msg_span("server." + msg.kind, self.tname,
                                    msg.payload):
                self._dispatch(msg)
            if lane_name is not None:
                self._m_dispatch.observe(self._clock() - t0, label=lane_name)
        except Exception as e:   # pragma: no cover - defensive
            self.transport.send(self.tname, self.manager, "server_error",
                                {"server": self.tname, "error": repr(e)})

    _LANED_KINDS = ("put", "put_batch", "replica_put", "replica_put_batch")

    def _qos_enqueue(self, msg: Message) -> bool:
        """Park puts — client-facing AND replica-chain — in the lane queue
        (everything else: reads, ACKs, control, dispatches immediately).
        Replica traffic carries the originating put's lane: a checkpoint
        chunk's ACK depends on its replica hop, so an unprioritized
        replica path would hand the background flood the priority back.
        FIFO order is preserved within a lane, so same-key rewrites from
        one stream stay ordered; cross-lane writes to one key were never
        ordered."""
        if self._laneq is None or msg.kind not in self._LANED_KINDS:
            return False
        p = msg.payload
        lane = p.get("lane")
        lane = qos.LANE_INTERACTIVE if lane is None else qos.lane_index(lane)
        if "items" in p:
            nbytes = sum(len(it["value"]) for it in p["items"])
        else:
            nbytes = len(p["value"])
        if self._tele:
            msg._parked_at = self._clock()
        self._laneq.push(lane, msg, nbytes)
        if msg.kind in ("put", "put_batch"):
            self.stats["puts_by_lane"][lane] += 1
        return True

    def stop(self):
        self._stop.set()

    # -------------------------------------------------------------- dispatch
    def _dispatch(self, msg: Message):
        handler = getattr(self, f"_on_{msg.kind}", None)
        if handler is None:
            # protocol black-hole detector (ISSUE 6): a typo'd or stale
            # kind must be distinguishable from server death — count it,
            # and tell the manager the first time each kind shows up
            n = self.unknown_kinds.get(msg.kind, 0) + 1
            self.unknown_kinds[msg.kind] = n
            if n == 1:
                telemetry.record(self.tname, "unknown_kind",
                                 kind=msg.kind, src=msg.src)
                self.transport.send(
                    self.tname, self.manager, "server_error",
                    {"server": self.tname,
                     "error": f"unknown message kind {msg.kind!r} "
                              f"from {msg.src}"})
            return
        handler(msg)

    def _recover_manifests(self):
        """Rebuild per-file chunk manifests from keys a LogStore recovery
        brought back (ISSUE 8). Chunk keys are ``{path}:{offset}``; anything
        else (no separator, non-numeric offset) is kept readable by key but
        cannot join a file manifest."""
        keys = self.store.recovered_keys
        if not keys:
            return
        lengths = self.store.items_bytes()
        nbytes = 0
        for key in keys:
            length = lengths.get(key)
            if length is None:
                continue
            file, sep, off = key.rpartition(":")
            if sep and file and off.isdigit():
                self._record_segment(key, file, int(off), length)
            nbytes += length
        self.stats["recovered_keys"] = len(keys)
        self.stats["recovered_bytes"] = nbytes

    # ring bootstrap / updates -------------------------------------------
    def _on_ring(self, msg: Message):
        self.ring = list(msg.payload["ring"])
        dead = set(msg.payload.get("dead", []))
        self.alive = {s: s not in dead for s in self.ring}
        # a manager journal replay re-seeds the lookup table through the
        # ring bootstrap, so range reads of flushed files survive a
        # whole-cluster restart (ISSUE 8)
        self._merge_lookup(msg.payload.get("lookup", {}))

    def _on_ring_update(self, msg: Message):
        dead = msg.payload.get("dead", [])
        joined = msg.payload.get("joined", [])
        for s in dead:
            self.alive[s] = False
        for s in joined:
            if s not in self.ring:
                # join at the announced position (paper Fig 3)
                pred = msg.payload.get("pred")
                if pred in self.ring:
                    self.ring.insert(self.ring.index(pred) + 1, s)
                else:
                    self.ring.append(s)
            self.alive[s] = True
        if dead:
            self._re_replicate()
            self._prune_flush_expected(set(dead))

    # put path -------------------------------------------------------------
    def _record_segment(self, key: str, file: Optional[str], offset: int,
                        length: int):
        """Track a buffered chunk in both flush-segment and per-file views.
        A live buffered chunk shadows any tombstone at its key (a rewrite
        of drained/bypassed bytes is fresher than the PFS copy), so the
        tombstone record is dropped here."""
        if file is None:
            return
        old = self._segments.get(key)
        if old is not None:
            fmap = self._files.get(old.file)
            if fmap is not None and fmap.get(old.offset, (None, 0))[0] == key:
                del fmap[old.offset]
        if key in self._evicted:
            self._evicted.pop(key, None)
            emap = self._evicted_files.get(file)
            if emap is not None and emap.get(offset, (None, 0))[0] == key:
                del emap[offset]
                if not emap:
                    del self._evicted_files[file]
        self._segments[key] = twophase.Segment(file, offset, length)
        self._files.setdefault(file, {})[offset] = (key, length)

    def _drop_segment(self, key: str):
        seg = self._segments.pop(key, None)
        if seg is None:
            return
        fmap = self._files.get(seg.file)
        if fmap is not None and fmap.get(seg.offset, (None, 0))[0] == key:
            del fmap[seg.offset]
            if not fmap:
                del self._files[seg.file]

    def _occupancy_frac(self) -> float:
        return self.store.occupancy()["fraction"]

    def _note_foreground(self, nbytes: int):
        """Feed the burst detector AND the background-bandwidth arbiter:
        foreground ingest is the signal that throttles drain/stage."""
        if self.drainer is not None:
            self.drainer.note_ingest(nbytes)
        if self.arbiter is not None:
            self.arbiter.note_foreground(nbytes)

    def _on_put(self, msg: Message):
        p = msg.payload
        key, value = p["key"], p["value"]
        self.stats["puts"] += 1
        if p.get("_stale"):        # truncated while parked: ack, don't store
            self.transport.reply(self.tname, msg, "put_ack",
                                 {"key": key,
                                  "occupancy": self._occupancy_frac()})
            return
        self._note_foreground(len(value))

        # load-balanced buffering: redirect if DRAM exhausted (paper §III-A)
        if p.get("redirectable", True) \
                and self.store.dram_free() < len(value):
            target = self._least_loaded_neighbor(len(value))
            if target is not None:
                self.stats["redirects"] += 1
                telemetry.record(self.tname, "redirect", key=key,
                                 target=target)
                self.transport.reply(self.tname, msg, "redirect",
                                     {"key": key, "target": target,
                                      "occupancy": self._occupancy_frac()})
                return

        tier = self.store.put(key, value)
        if tier == "ssd":
            self.stats["spills"] += 1
        self._record_segment(key, p.get("file"), p.get("offset", 0),
                             len(value))

        chain: List[str] = p.get("chain")
        if chain is None:
            chain = self.successors(self.replication - 1)
        if chain:
            nxt, rest = chain[0], chain[1:]
            self._pending_primary[(msg.src, msg.msg_id)] = \
                [msg.src, len(chain), msg]
            self.transport.send(self.tname, nxt, "replica_put", {
                "key": key, "value": value, "chain": rest,
                "primary": self.tname, "primary_msg": msg.msg_id,
                "client": msg.src, "lane": p.get("lane"),
                "file": p.get("file"), "offset": p.get("offset", 0)})
        else:
            self.transport.reply(self.tname, msg, "put_ack",
                                 {"key": key,
                                  "occupancy": self._occupancy_frac()})

    def _on_put_batch(self, msg: Message):
        """Coalesced put (client write coalescing): store every segment in
        one message, replicate the whole batch down the chain, ACK once.
        Batches are never redirected — the store spills to SSD instead, so
        the per-batch cost stays a single round-trip."""
        items = msg.payload["items"]
        self.stats["puts"] += len(items)
        self.stats["batch_puts"] += 1
        self._note_foreground(sum(len(it["value"]) for it in items
                                  if not it.get("_stale")))
        for it in items:
            if it.get("_stale"):   # truncated while parked: ack, don't store
                continue           # (the flag travels the replica chain too)
            tier = self.store.put(it["key"], it["value"])
            if tier == "ssd":
                self.stats["spills"] += 1
            self._record_segment(it["key"], it.get("file"),
                                 it.get("offset", 0), len(it["value"]))
        chain = self.successors(self.replication - 1)
        if chain:
            nxt, rest = chain[0], chain[1:]
            self._pending_primary[(msg.src, msg.msg_id)] = \
                [msg.src, len(chain), msg]
            self.transport.send(self.tname, nxt, "replica_put_batch", {
                "items": items, "chain": rest, "primary": self.tname,
                "primary_msg": msg.msg_id, "client": msg.src,
                "lane": msg.payload.get("lane")})
        else:
            self.transport.reply(self.tname, msg, "put_batch_ack",
                                 {"count": len(items),
                                  "occupancy": self._occupancy_frac()})

    def _on_replica_put(self, msg: Message):
        p = msg.payload
        if not p.get("_stale"):    # truncated while parked: protocol only
            self._note_foreground(len(p["value"]))
            self.store.put(p["key"], p["value"])
            self._record_segment(p["key"], p.get("file"),
                                 p.get("offset", 0), len(p["value"]))
        if p["chain"]:
            nxt, rest = p["chain"][0], p["chain"][1:]
            self.transport.send(self.tname, nxt, "replica_put",
                                {**p, "chain": rest})
        if p.get("primary_msg") is None:
            return              # re-replication copy: nobody is waiting
        self.transport.send(self.tname, p["primary"], "replica_ack",
                            {"primary_msg": p["primary_msg"],
                             "client": p.get("client"), "key": p["key"]})

    def _on_replica_put_batch(self, msg: Message):
        p = msg.payload
        self._note_foreground(sum(len(it["value"]) for it in p["items"]
                                  if not it.get("_stale")))
        for it in p["items"]:
            if it.get("_stale"):
                continue
            self.store.put(it["key"], it["value"])
            self._record_segment(it["key"], it.get("file"),
                                 it.get("offset", 0), len(it["value"]))
        if p["chain"]:
            nxt, rest = p["chain"][0], p["chain"][1:]
            self.transport.send(self.tname, nxt, "replica_put_batch",
                                {**p, "chain": rest})
        self.transport.send(self.tname, p["primary"], "replica_ack",
                            {"primary_msg": p["primary_msg"],
                             "client": p.get("client"),
                             "key": p["items"][0]["key"]})

    def _on_replica_ack(self, msg: Message):
        pm = msg.payload.get("primary_msg")
        if pm is None:
            return              # re-replication sentinel: not a client put
        entry = self._pending_primary.get((msg.payload.get("client"), pm))
        if entry is None:
            return
        entry[1] -= 1
        if entry[1] <= 0:
            client, _, orig = self._pending_primary.pop(
                (msg.payload.get("client"), pm))
            occ = self._occupancy_frac()
            if orig.kind == "put_batch":
                self.transport.reply(self.tname, orig, "put_batch_ack",
                                     {"count": len(orig.payload["items"]),
                                      "occupancy": occ})
            else:
                self.transport.reply(self.tname, orig, "put_ack",
                                     {"key": msg.payload["key"],
                                      "occupancy": occ})

    def _least_loaded_neighbor(self, need: int) -> Optional[str]:
        """Pick the neighbour with the most free DRAM (paper §III-A). Free-
        memory info is gossiped on every stabilization pong, so this is a
        local lookup — the server loop never blocks on an RPC."""
        best, best_free = None, max(self.store.dram_free(), need)
        for peer, free in self._neighbor_free.items():
            if peer != self.tname and self.alive.get(peer, False) \
                    and free > best_free:
                best, best_free = peer, free
        return best

    # get path -------------------------------------------------------------
    def _on_get(self, msg: Message):
        key = msg.payload["key"]
        val = self.store.get(key)
        if val is not None:
            self.transport.reply(self.tname, msg, "get_ack",
                                 {"key": key, "value": val, "hit": True})
            return
        miss = {"key": key, "value": None, "hit": False}
        ev = self._evicted.get(key)
        if ev is not None:
            # drained-and-evicted chunk: tell the client where the bytes
            # live (file, offset, length) so it can fall through to the
            # lookup-table range read / PFS — eviction stays invisible
            miss["evicted"] = list(ev)
        self.transport.reply(self.tname, msg, "get_ack", miss)

    def _on_read_range(self, msg: Message):
        """Serve a post-shuffle byte range of a flushed file (paper §III-C)."""
        p = msg.payload
        f, off, length = p["file"], p["offset"], p["length"]
        chunks = self._domain_data.get(f, {})
        buf = bytearray(length)
        covered = []                        # [lo, hi) intervals, file space
        for base, data in chunks.items():
            lo = max(off, base)
            hi = min(off + length, base + len(data))
            if lo < hi:
                buf[lo - off:hi - off] = data[lo - base:hi - base]
                covered.append([lo, hi])
        covered = _merge_intervals(covered)
        filled = sum(hi - lo for lo, hi in covered)
        if filled < length:
            # fill only the gaps from the PFS — buffered chunks are at least
            # as fresh as the durable copy and must not be clobbered
            path = os.path.join(self.pfs_dir, f)
            if os.path.exists(path):
                with open(path, "rb") as fh:
                    fh.seek(off)
                    pfs = fh.read(length)
                for lo, hi in _gaps(covered, off, off + len(pfs)):
                    buf[lo - off:hi - off] = pfs[lo - off:hi - off]
                    covered.append([lo, hi])
                covered = _merge_intervals(covered)
                filled = sum(hi - lo for lo, hi in covered)
        self.transport.reply(self.tname, msg, "range_ack",
                             {"data": bytes(buf), "complete": filled >= length})

    def _on_file_info(self, msg: Message):
        f = msg.payload["file"]
        size = self.lookup_table.get(f)
        doms = None
        if size is not None:
            doms = twophase.domains(size, self.alive_ring())
        self.transport.reply(self.tname, msg, "file_info_ack",
                             {"file": f, "size": size, "domains": doms})

    # file-session metadata (BBFileSystem) ---------------------------------
    def _file_stat_payload(self, f: str) -> dict:
        fmap = self._files.get(f, {})
        emap = self._evicted_files.get(f, {})
        buffered = max((off + ln for off, (_, ln) in fmap.items()), default=0)
        residency = {"dram": 0, "ssd": 0, "pfs": 0}
        for _off, (key, ln) in fmap.items():
            tier = self.store.tier_of(key)
            if tier in residency:
                residency[tier] += ln
        residency["pfs"] += sum(ln for _, ln in emap.values())
        return {"file": f, "buffered": buffered, "chunks": len(fmap),
                "flushed_size": self.lookup_table.get(f),
                "residency": residency, "evicted_chunks": len(emap),
                "known": f in self._files or f in self.lookup_table
                or f in self._evicted_files}

    def _on_file_stat(self, msg: Message):
        """Per-file metadata: buffered extent + chunk count from the local
        manifest, durable size from the post-shuffle lookup table."""
        self.transport.reply(self.tname, msg, "file_stat_ack",
                             self._file_stat_payload(msg.payload["file"]))

    def _on_file_chunks(self, msg: Message):
        """The local chunk manifest for one file: [(offset, key, length,
        clean)]. Clients merge manifests across servers to assemble
        buffered reads without knowing the writer's striping; the clean
        flag lets the merge prefer dirty copies — a buffered write is at
        least as fresh as any staged re-ingest of the PFS copy."""
        fmap = self._files.get(msg.payload["file"], {})
        chunks = [[off, key, ln, self.store.is_clean(key)]
                  for off, (key, ln) in fmap.items()]
        self.transport.reply(self.tname, msg, "file_chunks_ack",
                             {"file": msg.payload["file"], "chunks": chunks})

    def _on_file_truncate(self, msg: Message):
        """Open-for-write truncation: drop every buffered chunk of the file
        (primary and replica copies alike — the message is broadcast), its
        shuffle data, and its lookup-table entry, so a rewrite can never
        read back stale tail bytes from a longer previous incarnation.

        Puts of this file still PARKED in the lane queue are marked stale:
        pre-QoS the FIFO inbox guaranteed they applied before the truncate
        that followed them, but lane parking would apply them after it and
        resurrect the dead incarnation. A stale put is ACKed without being
        stored — byte-for-byte the FIFO outcome (applied, then truncated a
        moment later)."""
        f = msg.payload["file"]
        if self._laneq is not None:
            for queued in self._laneq.entries():
                p = queued.payload
                for it in p.get("items", (p,)):
                    if it.get("file") == f:
                        it["_stale"] = True
        for off, (key, _ln) in self._files.pop(f, {}).items():
            self.store.delete(key)
            self._segments.pop(key, None)
        for off, (key, _ln) in self._evicted_files.pop(f, {}).items():
            self.store.delete(key)      # clears the tombstone too
            self._evicted.pop(key, None)
        # a replay must not resurrect chunks of the truncated file
        self.store.sync()
        self.lookup_table.pop(f, None)
        self._domain_data.pop(f, None)
        self.transport.reply(self.tname, msg, "file_truncate_ack",
                             {"file": f})

    def _on_bypass_report(self, msg: Message):
        """A client wrote bytes of ``file`` straight to the PFS (QoS
        write-through bypass, ISSUE 5) — the bytes never touch the buffer,
        only their residency metadata lands here. Every server max-merges
        the file's lookup-table size so post-shuffle range reads cover the
        bypassed extent, and EVICTS any live buffered chunk the run fully
        covers: those chunks hold older bytes of the same range (the
        handle flushes its pending run before any buffered write, so a
        report can never chase a fresher put), and leaving them live would
        shadow the newer PFS copy forever. The tombstones point reads at
        the PFS like any drained chunk. A chunk only PARTIALLY covered by
        the run is left alone — its uncovered bytes exist nowhere else,
        and sub-chunk overlapping writes are documented-undefined.
        Each chunk-granular slice of the run carries its own placement
        owner, which records the slice as an eviction tombstone so direct
        KV gets of ANY ``{file}:{offset}`` inside the run fall through."""
        p = msg.payload
        f, off, ln = p["file"], p["offset"], p["length"]
        lo, hi = off, off + ln
        self._merge_lookup({f: p.get("size", hi)})
        for c_off, (key, c_ln) in list(self._files.get(f, {}).items()):
            if lo <= c_off and c_off + c_ln <= hi:
                # the PFS run covers this chunk end to end: the durable
                # copy supersedes it (mid-drain-epoch safe — the shuffle
                # skips evicted keys, drain_evict frees 0 on them)
                self.store.evict(key)
                self._evicted[key] = (f, c_off, c_ln)
                self._evicted_files.setdefault(f, {})[c_off] = (key, c_ln)
                self._drop_segment(key)
        # harden the tombstones NOW: here (unlike a drain evict) the PFS
        # copy is NEWER than the buffered bytes, so a replay resurrecting
        # them would serve stale data
        self.store.sync()
        for s_off, s_ln, owner in p.get("chunks", ()):
            if owner != self.tname:
                continue
            key = f"{f}:{s_off}"
            if key not in self.store and key not in self._segments:
                self._evicted[key] = (f, s_off, s_ln)
                self._evicted_files.setdefault(f, {})[s_off] = (key, s_ln)
            self.stats["bypass_chunks"] += 1
            self.stats["bypass_bytes"] += s_ln

    # stabilization --------------------------------------------------------
    # Fully asynchronous (the server loop never blocks): pings are fired and
    # tracked with deadlines; pongs piggyback free-DRAM gossip (paper §III-A
    # + §IV-A in one mechanism). Missing ``miss_limit`` consecutive pongs
    # marks the neighbour dead — splice, adopt next successor, tell manager.

    MISS_LIMIT = 3
    PING_TIMEOUT = 0.6

    def _stabilize(self, now: float):
        for s in self.successors(2):
            if any(peer == s for peer, _ in self._inflight_pings.values()):
                continue
            nonce = self._ping_nonce = getattr(self, "_ping_nonce", 0) + 1
            self._inflight_pings[nonce] = (s, now + self.PING_TIMEOUT)
            self.transport.send(self.tname, s, "ping",
                                {"nonce": nonce, "from": self.tname})

    def _check_ping_deadlines(self, now: float):
        expired = [n for n, (peer, dl) in self._inflight_pings.items()
                   if dl < now]
        for n in expired:
            peer, _ = self._inflight_pings.pop(n)
            self._ping_misses[peer] = self._ping_misses.get(peer, 0) + 1
            if self._ping_misses[peer] >= self.MISS_LIMIT \
                    and self.alive.get(peer, False):
                self._declare_dead(peer)

    def _declare_dead(self, peer: str):
        self.alive[peer] = False
        self.stats["stabilize_repairs"] += 1
        nxt = self.successors(1)
        if nxt:
            self.transport.send(self.tname, nxt[0], "neighbor_died",
                                {"dead": peer})
        self.transport.send(self.tname, self.manager, "failure_report",
                            {"dead": peer, "reporter": self.tname})
        self._re_replicate()
        self._prune_flush_expected({peer})

    def _on_ping(self, msg: Message):
        self.transport.send(self.tname, msg.src, "pong",
                            {"nonce": msg.payload["nonce"],
                             "free": self.store.dram_free()})

    def _on_pong(self, msg: Message):
        self._inflight_pings.pop(msg.payload["nonce"], None)
        self._ping_misses[msg.src] = 0
        self._last_pong[msg.src] = self._clock()
        self._neighbor_free[msg.src] = msg.payload["free"]
        # a pong from a node we thought dead -> it is back (partition healed)
        if not self.alive.get(msg.src, True):
            self.alive[msg.src] = True

    def _on_neighbor_died(self, msg: Message):
        dead = msg.payload["dead"]
        if self.alive.get(dead, True):
            self.alive[dead] = False
            self._re_replicate()
            self._prune_flush_expected({dead})

    def _on_confirm_failure(self, msg: Message):
        """Client-initiated confirmation via the predecessor (paper §IV-B2):
        fire a probe ping; reply when the pong arrives or the deadline
        passes (non-blocking state machine)."""
        suspect = msg.payload["suspect"]
        nonce = self._ping_nonce = getattr(self, "_ping_nonce", 0) + 1
        now = self._clock()
        self._pending_confirms.append([msg, suspect, now,
                                       now + self.PING_TIMEOUT])
        self.transport.send(self.tname, suspect, "ping",
                            {"nonce": nonce, "from": self.tname})

    def _check_confirm_deadlines(self, now: float):
        still = []
        for entry in self._pending_confirms:
            msg, suspect, started, deadline = entry
            if self._last_pong.get(suspect, -1.0) >= started:
                self.transport.reply(self.tname, msg, "failure_confirmed",
                                     {"suspect": suspect, "confirmed": False})
            elif deadline < now:
                if self.alive.get(suspect, True):
                    self._declare_dead(suspect)
                self.transport.reply(self.tname, msg, "failure_confirmed",
                                     {"suspect": suspect, "confirmed": True})
            else:
                still.append(entry)
        self._pending_confirms = still

    def _re_replicate(self):
        """Restore replication factor for keys this server holds after a
        membership change: re-forward to the current successor chain."""
        chain = self.successors(self.replication - 1)
        for key in self.store.keys():
            seg = self._segments.get(key)
            for peer in chain:
                # primary_msg None is the "no client is waiting" sentinel:
                # replicas store the copy but send no replica_ack, so these
                # copies can never satisfy a pending client put
                self.transport.send(self.tname, peer, "replica_put", {
                    "key": key, "value": self.store.get(key), "chain": [],
                    "primary": self.tname, "primary_msg": None,
                    "client": None, "lane": qos.LANE_DRAIN,
                    "file": seg.file if seg else None,
                    "offset": seg.offset if seg else 0})

    # two-phase flush --------------------------------------------------------
    def _flush_state(self, epoch: int) -> dict:
        """Per-epoch flush state. The ring is snapshotted ONCE, when the
        epoch is first seen: shuffle planning and the PFS write must use the
        same membership view, otherwise servers that observe a death or join
        mid-flush compute different domain ownership and bytes get dropped
        or double-written."""
        return self._flush.setdefault(epoch, {
            "meta": {}, "done": set(),
            "ring": self.alive_ring(),
            "expected": set(self.alive_ring()),
            # drain micro-epochs carry a cold SUBSET of segments; my_metas
            # snapshots this server's contribution at flush_begin so the
            # shuffle ships exactly what the epoch advertised
            "drain": False, "my_metas": None,
            # known file sizes broadcast with the metadata: subset planning
            # must pin domains to the files' true sizes (see plan_shuffle)
            "sizes": {}, "epoch_sizes": None,
            "shuffled": False, "written": False})

    def _close_epoch(self, epoch: int):
        self._flush.pop(epoch, None)
        self._closed_epochs.add(epoch)
        if len(self._closed_epochs) > 4096:      # bounded straggler memory
            self._closed_epochs.clear()

    def _merge_lookup(self, sizes: Dict[str, int]):
        """Lookup-table updates are max-merge: a drain micro-epoch that made
        only a cold prefix of a file durable must never shrink the recorded
        global size (truncation drops the entry instead)."""
        for f, sz in sizes.items():
            if sz > self.lookup_table.get(f, -1):
                self.lookup_table[f] = sz

    def _on_flush_begin(self, msg: Message):
        """Phase 1: broadcast my segment metadata to every live server.
        For a drain micro-epoch (payload drain=True) the contribution is the
        cold, file-attributed subset allowed by the token bucket; everyone
        else still participates in the exchange with empty metadata."""
        epoch = msg.payload["epoch"]
        if epoch in self._closed_epochs:
            return
        st = self._flush_state(epoch)
        st["drain"] = bool(msg.payload.get("drain"))
        if st["drain"]:
            # drain epochs are serialized by the manager, so any leftover
            # snapshot belongs to an epoch whose abort we never saw (e.g.
            # we were falsely declared dead mid-epoch): refund and drop it
            for stale in [e for e in self._drain_epochs if e != epoch]:
                dr = self._drain_epochs.pop(stale)
                if self.drainer is not None:
                    self.drainer.refund(dr["bytes"])
            keys: List[str] = []
            nbytes = 0
            if self.drainer is not None and self.drainer.draining:
                budget = min(self.drain_cfg.max_epoch_bytes,
                             self.drainer.peek())
                if budget > 0:
                    keys, nbytes = self._drain_select(budget)
                    self.drainer.take(nbytes)
            # gens snapshot covers EVERY local file-attributed key, not just
            # the contributed ones: the evict broadcast names keys drained by
            # any participant, and replicas of those keys live here too
            self._drain_epochs[epoch] = {
                "keys": keys, "bytes": nbytes,
                "gens": {k: self.store.gen_of(k) for k in self._segments}}
            segs = {k: self._segments[k] for k in keys
                    if k in self._segments}
        else:
            # clean (staged) chunks are byte-identical to their durable PFS
            # copy — re-shuffling and re-writing them would be pure waste
            segs = {k: s for k, s in self._segments.items()
                    if not self.store.is_clean(k)}
        st["my_metas"] = segs
        metas = [(s.file, s.offset, s.length, k) for k, s in segs.items()]
        sizes = {s.file: self.lookup_table[s.file] for s in segs.values()
                 if s.file in self.lookup_table}
        for peer in st["ring"]:
            self.transport.send(self.tname, peer, "flush_meta",
                                {"epoch": epoch, "from": self.tname,
                                 "metas": metas, "sizes": sizes})

    def _on_flush_meta(self, msg: Message):
        epoch = msg.payload["epoch"]
        if epoch in self._closed_epochs:
            return                       # straggler for an aborted/done epoch
        st = self._flush_state(epoch)
        st["meta"][msg.payload["from"]] = msg.payload["metas"]
        for f, sz in msg.payload.get("sizes", {}).items():
            if sz > st["sizes"].get(f, -1):
                st["sizes"][f] = sz
        if set(st["meta"]) >= st["expected"] and not st["shuffled"]:
            self._shuffle(epoch, st)

    def _on_flush_abort(self, msg: Message):
        """The manager aborted an epoch (server death / timeout mid-drain):
        drop the epoch state and refund the drain-bandwidth budget — nothing
        was evicted, the chunks stay buffered and re-drain from replicas in
        a later micro-epoch."""
        epoch = msg.payload["epoch"]
        self._close_epoch(epoch)
        dr = self._drain_epochs.pop(epoch, None)
        if dr is not None and self.drainer is not None:
            self.drainer.refund(dr["bytes"])

    def _shuffle(self, epoch: int, st: dict):
        """Phase 2: ship segments to domain owners (epoch ring snapshot)."""
        st["shuffled"] = True
        all_meta = {
            src: [twophase.Segment(f, o, l) for f, o, l, _ in metas]
            for src, metas in st["meta"].items()}
        segs = st["my_metas"]
        if segs is None:            # flush_begin never seen (late join)
            segs = {} if st["drain"] else dict(self._segments)
        sizes, doms, sends = twophase.plan_shuffle(
            list(segs.values()), all_meta, st["ring"],
            known_sizes=st["sizes"])
        st["epoch_sizes"] = dict(sizes)
        self._merge_lookup(sizes)
        key_of = {(s.file, s.offset): k for k, s in segs.items()}
        for owner, seg, file_off, local_off, length in sends:
            data = self.store.get(key_of[(seg.file, seg.offset)])
            if data is None:
                continue       # evicted mid-epoch: already durable on PFS
            piece = data[local_off:local_off + length]
            self.transport.send(self.tname, owner, "shuffle_data",
                                {"epoch": epoch, "file": seg.file,
                                 "offset": file_off, "data": piece})
        for peer in st["ring"]:
            self.transport.send(self.tname, peer, "shuffle_done",
                                {"epoch": epoch, "from": self.tname,
                                 "sizes": sizes})

    def _on_shuffle_data(self, msg: Message):
        p = msg.payload
        self._domain_data.setdefault(p["file"], {})[p["offset"]] = p["data"]

    def _on_shuffle_done(self, msg: Message):
        epoch = msg.payload["epoch"]
        if epoch in self._closed_epochs:
            return                       # straggler for an aborted/done epoch
        st = self._flush_state(epoch)
        st["done"].add(msg.payload["from"])
        self._merge_lookup(msg.payload["sizes"])
        if st["epoch_sizes"] is None:
            st["epoch_sizes"] = {}
        for f, sz in msg.payload["sizes"].items():
            if sz > st["epoch_sizes"].get(f, -1):
                st["epoch_sizes"][f] = sz
        if st["done"] >= st["expected"] and not st["written"]:
            st["written"] = True
            self._write_pfs(epoch, st)

    def _write_pfs(self, epoch: int, st: dict):
        """Phase 2b: sequential writes of owned, COVERED ranges only, with
        domain ownership computed from the epoch's ring snapshot.

        Only files touched by this epoch are written, and within an owned
        domain only the byte runs actually present in the shuffle buffer.
        An earlier version zero-filled each owned domain end-to-end across
        every file in the lookup table — once chunks can be evicted (the
        drain engine, checkpoint retention) that clobbers durable PFS bytes
        with zeros on the next flush. The file is still grown to its full
        size by the tail-domain owner so PFS reads never come up short."""
        os.makedirs(self.pfs_dir, exist_ok=True)
        written = 0
        for f in sorted(st["epoch_sizes"] or {}):
            # epoch_sizes is identical on every participant (max-merge of
            # the same shuffle_done broadcasts), so domain ownership agrees
            size = st["epoch_sizes"][f]
            doms = twophase.domains(size, st["ring"])
            my = [(a, b) for s, a, b in doms if s == self.tname]
            if not my:
                continue
            chunks = self._domain_data.get(f, {})
            path = os.path.join(self.pfs_dir, f)
            with open(path, "r+b" if os.path.exists(path) else "w+b") as fh:
                for a, b in my:
                    runs = []
                    for base, data in chunks.items():
                        lo, hi = max(a, base), min(b, base + len(data))
                        if lo < hi:
                            runs.append([lo, hi])
                    for lo, hi in _merge_intervals(runs):
                        buf = bytearray(hi - lo)
                        for base, data in sorted(chunks.items()):
                            l2 = max(lo, base)
                            h2 = min(hi, base + len(data))
                            if l2 < h2:
                                buf[l2 - lo:h2 - lo] = \
                                    data[l2 - base:h2 - base]
                        fh.seek(lo)
                        fh.write(bytes(buf))  # sequential covered run
                        written += hi - lo
                if my[-1][1] == size:
                    fh.seek(0, os.SEEK_END)
                    if fh.tell() < size:
                        fh.truncate(size)     # tail owner fixes the length
        self.stats["flushes"] += 1
        dr = self._drain_epochs.get(epoch)
        self._close_epoch(epoch)
        self.transport.send(self.tname, self.manager, "flush_done",
                            {"epoch": epoch, "server": self.tname,
                             "bytes": written,
                             "sizes": dict(st["epoch_sizes"] or {}),
                             "drained": dr["keys"] if dr else []})

    # autonomous drain engine (ISSUE 3) --------------------------------------
    def _drain_tick(self, now: float):
        """Watermark check, run from the server loop: report pressure to the
        manager on a fixed cadence, and request a drain micro-epoch when the
        engine's hysteresis + burst detector + token bucket all agree."""
        eng = self.drainer
        if eng is None or not self.ring or self.tname not in self.ring:
            return
        occ = self.store.occupancy()
        if now - self._last_pressure >= self.drain_cfg.pressure_interval:
            self._last_pressure = now
            self._m_occ.note(occ["fraction"], label=self.tname)
            self.transport.send(self.tname, self.manager, "drain_pressure",
                                {"server": self.tname, **occ,
                                 "draining": eng.draining,
                                 "unknown_kinds": sum(
                                     self.unknown_kinds.values()),
                                 "ingest_bps": eng.ingest_rate(now)})
        if not self._segments:
            return                  # nothing file-attributed: nothing to drain
        if not eng.update(occ["fraction"], now):
            return
        # clean-evict fast path (ISSUE 4): staged bytes already have a
        # durable PFS copy, so under pressure they are dropped first —
        # locally, for free, with no flush epoch and no token-bucket debit
        if self._clean_evict():
            return
        if eng.peek(now) <= 0:
            return
        keys, nbytes = self._drain_select(self.drain_cfg.max_epoch_bytes)
        if not keys:
            # bare-KV pressure: rate-limit the (full-scan) reprobe so a
            # permanently-undrainable store doesn't burn the server loop
            eng.note_scan(now)
            return
        eng.note_requested(now)
        # root the drain-epoch trace here: the request is the first causal
        # event of the epoch, so every downstream hop (manager planning,
        # flush fan-out, evict confirms) parents back to this span
        with telemetry.span("server.drain_request", self.tname,
                            drainable=nbytes):
            self.transport.send(self.tname, self.manager, "drain_request",
                                {"server": self.tname,
                                 "occupancy": occ["fraction"],
                                 "drainable": nbytes})

    def _drain_select(self, budget: int):
        """Cold, sealed, FILE-ATTRIBUTED chunks in age order up to ``budget``
        bytes (always at least one chunk). Bare KV keys cannot travel the
        two-phase planner and are skipped; clean (staged) keys never need a
        drain epoch — the clean-evict fast path drops them for free."""
        out: List[str] = []
        total = 0
        for key, length in self.store.cold_keys(self.drain_cfg.min_idle_s,
                                                clean=False):
            if key not in self._segments:
                continue
            if out and total + length > budget:
                break
            out.append(key)
            total += length
        return out, total

    def _clean_evict(self, skip_file: Optional[str] = None) -> int:
        """Evict cold CLEAN chunks (stage-in re-ingests): they are durable
        on the PFS by construction, so no flush epoch, no coordination, no
        bandwidth debit — tombstone, remember the residency for transparent
        read fallthrough, compact. ``skip_file`` protects the file an
        in-progress stage is loading from being cannibalized by its own
        admission guard. Returns bytes freed."""
        freed = 0
        for key, length in self.store.cold_keys(clean=True):
            seg = self._segments.get(key)
            if seg is not None and seg.file == skip_file:
                continue
            n = self.store.evict(key)
            if n == 0:
                continue
            freed += n
            self.stats["clean_evictions"] += 1
            if seg is not None:
                self._evicted[key] = (seg.file, seg.offset, seg.length)
                self._evicted_files.setdefault(
                    seg.file, {})[seg.offset] = (key, seg.length)
            self._drop_segment(key)
        if freed:
            self.store.compact()
            self.stats["clean_evicted_bytes"] += freed
        return freed

    def _on_drain_evict(self, msg: Message):
        """The manager confirmed a drain micro-epoch fully durable: evict the
        named chunks (all copies — primary and replica alike). A key whose
        write generation moved since the epoch's snapshot was rewritten
        mid-drain and is SKIPPED: the PFS holds the old bytes, the buffer
        holds the new ones, and evicting would lose the rewrite."""
        epoch = msg.payload["epoch"]
        dr = self._drain_epochs.pop(epoch, None)
        gens = dr["gens"] if dr else {}
        freed = 0
        touched: set = set()
        for key in msg.payload["keys"]:
            gen = gens.get(key)
            if gen is None or self.store.gen_of(key) != gen:
                continue
            seg = self._segments.get(key)
            n = self.store.evict(key)
            if n == 0:
                continue
            freed += n
            self.stats["evictions"] += 1
            if seg is not None:
                self._evicted[key] = (seg.file, seg.offset, seg.length)
                self._evicted_files.setdefault(
                    seg.file, {})[seg.offset] = (key, seg.length)
                touched.add(seg.file)
            self._drop_segment(key)
        if freed:
            self.store.compact()
            self.stats["drained_bytes"] += freed
            self.stats["drain_epochs"] += 1
            telemetry.record(self.tname, "drain_evict", epoch=epoch,
                             freed=freed, keys=len(msg.payload["keys"]))
        # the shuffle receive-buffers for drained files are durable on the
        # PFS now — dropping them is part of the space this engine reclaims.
        # Never while another epoch is mid-flight and may still need them.
        if not self._flush:
            for f in touched:
                self._domain_data.pop(f, None)

    def _prune_flush_expected(self, dead: set):
        """A mid-epoch death must not wedge the epoch forever: drop the dead
        from every in-flight epoch's expected set and advance epochs that
        are now complete. (Drain micro-epochs are additionally ABORTED by
        the manager on any death — eviction must never proceed off a plan a
        dead owner cannot finish writing.)"""
        for epoch in list(self._flush):
            st = self._flush.get(epoch)
            if st is None or not (st["expected"] & dead):
                continue
            st["expected"] -= dead
            if set(st["meta"]) >= st["expected"] and not st["shuffled"]:
                self._shuffle(epoch, st)
            st = self._flush.get(epoch)
            if st is not None and st["done"] >= st["expected"] \
                    and not st["written"]:
                st["written"] = True
                self._write_pfs(epoch, st)

    # stage-in engine (ISSUE 4) ----------------------------------------------
    def _stage_state(self, epoch: int) -> dict:
        """Per-epoch stage state; the ring is snapshotted from the manager's
        stage_begin so every participant computes the same domains (exactly
        the flush-epoch rule, in reverse)."""
        return self._stage_epochs.setdefault(epoch, {
            "file": None, "lo": 0, "hi": -1, "ring": [], "expected": set(),
            "meta": {}, "size": 0, "begun": False, "staged": False})

    def _close_stage(self, epoch: int):
        self._stage_epochs.pop(epoch, None)
        self._closed_epochs.add(epoch)
        if len(self._closed_epochs) > 4096:
            self._closed_epochs.clear()

    def _on_stage_begin(self, msg: Message):
        """Phase 1 of a stage epoch: broadcast my live buffered coverage of
        the file to every participant. Bytes ANYONE still buffers are at
        least as fresh as the durable PFS copy — staging over them could
        resurrect stale bytes, so the coverage union defines what must NOT
        be re-ingested."""
        p = msg.payload
        epoch = p["epoch"]
        if epoch in self._closed_epochs:
            return
        st = self._stage_state(epoch)
        st["file"], st["lo"], st["hi"] = p["file"], p["lo"], p["hi"]
        st["ring"] = list(p["ring"])
        st["expected"] = set(p["ring"])
        st["begun"] = True
        fmap = self._files.get(p["file"], {})
        covered = staging.merge_intervals(
            [[off, off + ln] for off, (_k, ln) in fmap.items()])
        size = max(self.lookup_table.get(p["file"], 0),
                   max((off + ln for off, (_k, ln) in fmap.items()),
                       default=0))
        path = os.path.join(self.pfs_dir, p["file"])
        if os.path.exists(path):
            size = max(size, os.path.getsize(path))
        for peer in st["ring"]:
            self.transport.send(self.tname, peer, "stage_meta",
                                {"epoch": epoch, "from": self.tname,
                                 "covered": covered, "size": size})
        self._maybe_stage(epoch, st)

    def _on_stage_meta(self, msg: Message):
        epoch = msg.payload["epoch"]
        if epoch in self._closed_epochs:
            return
        st = self._stage_state(epoch)
        st["meta"][msg.payload["from"]] = msg.payload["covered"]
        st["size"] = max(st["size"], msg.payload["size"])
        self._maybe_stage(epoch, st)

    def _on_stage_abort(self, msg: Message):
        """The manager aborted the epoch (death / timeout mid-stage). Drop
        the state; slices already re-ingested are CLEAN copies of durable
        bytes, so nothing needs undoing and reads stay correct either way."""
        self._close_stage(msg.payload["epoch"])

    def _maybe_stage(self, epoch: int, st: dict):
        if st["begun"] and set(st["meta"]) >= st["expected"] \
                and not st["staged"]:
            st["staged"] = True
            self._plan_stage(epoch, st)

    def _plan_stage(self, epoch: int, st: dict):
        """Phase 2 setup: plan MY lookup-table domain's uncovered slices.
        The re-ingest itself runs incrementally from ``_stage_tick`` (at
        most ``tick_bytes`` per server-loop pass) so a large stage cannot
        stall ping/pong long enough for peers to declare this server dead
        mid-epoch."""
        f, size = st["file"], st["size"]
        lo = max(0, st["lo"])
        hi = size if st["hi"] < 0 else min(st["hi"], size)
        path = os.path.join(self.pfs_dir, f)
        plan: List = []
        if size > 0:
            self._merge_lookup({f: size})
        if size > 0 and lo < hi and os.path.exists(path):
            doms = twophase.domains(size, st["ring"])
            mine = [(a, b) for s, a, b in doms if s == self.tname]
            covered = [iv for metas in st["meta"].values() for iv in metas]
            plan = staging.plan_stage(mine, (lo, hi), covered,
                                      self.stage_cfg.slice_bytes)
        st["plan"] = list(plan)
        st["path"] = path
        st["bytes"] = 0
        if not st["plan"]:
            self._finish_stage(epoch, st)

    def _stage_tick(self, now: float):
        """Re-ingest up to ``tick_bytes`` of the in-flight stage plan, then
        return to the message loop (every participant stages its own domain
        in parallel — this is what makes a cold restart a cluster-wide bulk
        load instead of one client's serial miss loop)."""
        for epoch, st in list(self._stage_epochs.items()):
            plan = st.get("plan")
            if not plan:
                continue
            f = st["file"]
            budget = self.stage_cfg.tick_bytes
            if self.arbiter is not None:
                # unified background budget (ISSUE 5): stage slices debit
                # the same per-server bucket as drain micro-epochs, and the
                # bucket refills slower while foreground ingest is hot — a
                # stage can no longer compete with an active burst
                budget = min(budget, self.arbiter.peek(now))
                if budget <= 0:
                    continue    # wait for a refill — the plan keeps its
                    #             remaining slices for a later tick, and
                    #             reads stay exact via the PFS fallback
            consumed = 0
            while plan and budget > 0:
                if not self._stage_admit(f):
                    plan.clear()    # buffer under real pressure: stop, the
                    break           # rest stays readable via PFS fallback
                off, ln = plan.pop(0)
                with open(st["path"], "rb") as fh:
                    fh.seek(off)
                    data = fh.read(ln)
                if len(data) < ln:
                    plan.clear()    # PFS copy shorter than advertised
                    break
                if self._ingest_clean(f, off, data):
                    st["bytes"] += len(data)
                budget -= ln
                consumed += ln
            if consumed and self.arbiter is not None:
                self.arbiter.take(consumed, now)
            if not plan:
                self._finish_stage(epoch, st)

    def _finish_stage(self, epoch: int, st: dict):
        staged = st.get("bytes", 0)
        self._close_stage(epoch)
        if staged:
            self.stats["stage_epochs"] += 1
            self.stats["staged_bytes"] += staged
        self.transport.send(self.tname, self.manager, "stage_done",
                            {"epoch": epoch, "server": self.tname,
                             "bytes": staged})

    def _stage_admit(self, file: str) -> bool:
        """Admission guard: staging must never push the store into a drain
        storm. At the high watermark, clean-evict older staged bytes first
        (free, no epoch); if occupancy is STILL at the watermark, refuse
        further slices — dirty data is never displaced to make room for
        bytes that already have a durable copy."""
        occ = self.store.occupancy()["fraction"]
        if occ < self.drain_cfg.high_watermark:
            return True
        self._clean_evict(skip_file=file)
        return self.store.occupancy()["fraction"] \
            < self.drain_cfg.high_watermark

    def _ingest_clean(self, file: str, offset: int, data: bytes) -> bool:
        """Store one staged slice as a CLEAN chunk under the ordinary
        ``{file}:{offset}`` key namespace (manifest-directed reads find it
        like any buffered chunk), clearing any tombstone it re-covers.

        A write that landed AFTER the epoch's coverage snapshot is fresher
        than the PFS copy, so the slice is SKIPPED when its key is live or
        any live local chunk overlaps its range — staging over it would
        resurrect stale bytes and, worse, mark them clean (evictable with
        no flush). Returns whether the slice was ingested."""
        key = f"{file}:{offset}"
        if key in self.store:
            return False
        fmap = self._files.get(file)
        if fmap:
            lo, hi = offset, offset + len(data)
            for off, (_k, ln) in fmap.items():
                if off < hi and lo < off + ln:
                    return False
        self.store.put(key, data, clean=True)
        # the offset is resident again: clear a matching tombstone record
        self._evicted.pop(key, None)
        emap = self._evicted_files.get(file)
        if emap is not None and emap.get(offset, (None, 0))[0] == key:
            del emap[offset]
            if not emap:
                del self._evicted_files[file]
        self._record_segment(key, file, offset, len(data))
        return True

    # checkpoint retention ---------------------------------------------------
    def _on_evict_epoch(self, msg: Message):
        """Durable eviction by prefix (checkpoint retention): keys with file
        attribution become tombstones — reads fall through to the lookup
        table / PFS — while bare KV keys are deleted outright."""
        prefix = msg.payload["prefix"]
        for key in list(self.store.keys()):
            if not key.startswith(prefix):
                continue
            seg = self._segments.get(key)
            if seg is not None:
                self.store.evict(key)
                self._evicted[key] = (seg.file, seg.offset, seg.length)
                self._evicted_files.setdefault(
                    seg.file, {})[seg.offset] = (key, seg.length)
                self.stats["evictions"] += 1
            else:
                self.store.delete(key)
            self._drop_segment(key)
        self.store.compact()
        for f in list(self._domain_data):
            if f.startswith(prefix):
                del self._domain_data[f]
        for f in list(self._files):
            if f.startswith(prefix):
                del self._files[f]

    def _stats_payload(self) -> dict:
        occ = self.store.occupancy()
        payload = {
            **self.stats, "dram_used": self.store.dram_used,
            "ssd_used": self.store.ssd_used,
            "keys": len(self.store.keys()),
            "lookup_files": len(self.lookup_table),
            "occupancy": occ["fraction"],
            "evicted_keys": len(self._evicted),
            "unknown_kinds": dict(self.unknown_kinds)}
        if self.drainer is not None:
            payload["drain"] = self.drainer.snapshot()
        if self.arbiter is not None:
            payload["arbiter"] = dict(self.arbiter.stats)
        if self._laneq is not None:
            payload["queued_puts"] = len(self._laneq)
        return payload

    def _stats_snapshot(self) -> dict:
        """Telemetry poll callback (ISSUE 9): the stats dict is mutated only
        by this server's own thread with GIL-atomic updates, so a shallow
        copy — plus the one nested list — is coherent without a lock."""
        snap = dict(self.stats)
        snap["puts_by_lane"] = list(self.stats["puts_by_lane"])
        if self.drainer is not None:
            snap["drain"] = self.drainer.snapshot()
        if self._laneq is not None:
            # lane-queue depth rides along for the health engine's
            # queue-growth watchdog and queue_depth SLO (ISSUE 10)
            snap["queued_puts"] = len(self._laneq)
        return snap

    def _on_stats_query(self, msg: Message):
        self.transport.reply(self.tname, msg, "stats", self._stats_payload())

    def _on_metrics_query(self, msg: Message):
        """Telemetry scrape (ISSUE 9): the stats payload, plus the full
        registry snapshot when the caller asks for instruments (remote
        scrapers; BurstBufferSystem.scrape() reads the in-process registry
        directly and asks each server only for its stats)."""
        payload = {"server": self.tname, "stats": self._stats_payload()}
        if msg.payload.get("instruments"):
            payload["instruments"] = telemetry.snapshot()
        self.transport.reply(self.tname, msg, "metrics", payload)
