"""Burst buffer manager (paper §II, §IV-A): singleton that initializes the
server ring, distributes membership to servers and clients, brokers failure
reports and joins, keeps the file-session namespace registry (paths opened
through BBFileSystem, with their last synced sizes), and coordinates the
autonomous drain engine's micro-epochs: servers report occupancy pressure
and request drains; the manager serializes one drain micro-epoch at a time
through the two-phase protocol, broadcasts the eviction once EVERY
participant reported its PFS writes done, and aborts the epoch (nothing is
evicted, nothing is lost) on any mid-epoch server death or timeout.

It also coordinates the stage-in engine (ISSUE 4, the drain in reverse):
a client's stage_request starts ONE stage epoch at a time — serialized
against drain micro-epochs AND application flushes, so the two engines can
never thrash the same segments — broadcasting stage_begin to the ring
snapshot; the epoch completes when every participant reports stage_done,
and aborts (harmlessly: staged bytes are clean copies of durable data) on
death or timeout. Clients poll stage_status for the outcome.
Collocated with a server on a real deployment.

Crash recovery (ISSUE 8): the manager keeps an append-only JSON-lines
journal of its durable state — the fs namespace registry, the global lookup
table (file -> flushed size, learned from flush_done reports), and the
drain/stage epoch counters — each record fsynced before the triggering
request is acked. A restarted manager replays the journal before its first
message (truncating a torn tail at the first unparsable line), so manager
death is a failover, not a metadata outage: stat/list answer for files
synced before the crash, range reads find their lookup sizes (re-seeded to
servers and through ring bootstrap), and re-allocated epoch ids can never
collide with pre-crash ones."""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Callable, Dict, List, Optional, Set

from repro.core import locktrack, telemetry
from repro.core.health import HealthConfig, HealthEngine
from repro.core.transport import Message, Transport

# drain micro-epochs and stage epochs live in their own id spaces so they
# can never collide with application-chosen flush epochs (or each other)
DRAIN_EPOCH_BASE = 1 << 30
STAGE_EPOCH_BASE = 2 << 30


class BBManager(threading.Thread):
    def __init__(self, transport: Transport, expected_servers: int,
                 name: str = "manager",
                 drain_epoch_timeout: float = 12.0,
                 poll_interval: float = 0.05,
                 flush_poll_interval: float = 0.01,
                 drain_serialize_poll: float = 0.005,
                 journal_path: Optional[str] = None,
                 health_cfg: Optional[HealthConfig] = None,
                 clock: Callable[[], float] = time.monotonic):
        super().__init__(daemon=True, name=name)
        self.tname = name
        self._clock = clock
        self.poll_interval = poll_interval
        self.flush_poll_interval = flush_poll_interval
        self.drain_serialize_poll = drain_serialize_poll
        self.transport = transport
        self.ep = transport.register(name)
        self.expected = expected_servers
        self.ring: List[str] = []
        self.dead: Set[str] = set()
        self.clients: Set[str] = set()
        self.flush_done: Dict[int, Set[str]] = {}
        self.flush_bytes: Dict[int, int] = {}
        self.flush_ledger_cap = 256     # completed/aborted epochs retained
        self._registered: Set[str] = set()
        self._stop = threading.Event()
        self.ring_ready = threading.Event()
        self.errors: List[dict] = []
        # file-session namespace (BBFileSystem): path -> metadata
        self.namespace: Dict[str, dict] = {}
        # global lookup table (file -> flushed size), max-merged from
        # flush_done reports; journaled and re-seeded to servers via ring
        # messages so range reads survive a whole-cluster restart (ISSUE 8)
        self.lookup: Dict[str, int] = {}
        self.journal_path = journal_path
        self._journal_fh = None
        # drain coordination: per-server pressure reports + one in-flight
        # micro-epoch at a time (overlapping epochs share server-side
        # shuffle buffers; serializing them keeps eviction decisions sound)
        self.drain_epoch_timeout = drain_epoch_timeout
        self.pressure: Dict[str, dict] = {}
        self.drain_stats = {"epochs": 0, "aborts": 0,
                            "evicted_keys": 0, "drained_bytes": 0}
        self._drain: Optional[dict] = None
        self._next_drain_epoch = DRAIN_EPOCH_BASE
        self._flush_lock = locktrack.lock("BBManager._flush_lock")
        self._user_flushes: Dict[int, float] = {}   # epoch -> begin time
        # participant snapshot per user flush epoch, taken at begin_flush:
        # completion is judged against it, never against an empty ring
        # (ISSUE 8 satellite — set() >= set() was vacuously True)
        self._flush_expected: Dict[int, Set[str]] = {}
        # stage-in coordination (ISSUE 4): one stage epoch at a time,
        # serialized against drain micro-epochs; finished epochs keep a
        # bounded result record for stage_status polling
        self.stage_stats = {"epochs": 0, "aborts": 0, "staged_bytes": 0}
        self._stage: Optional[dict] = None
        self._next_stage_epoch = STAGE_EPOCH_BASE
        self._stage_results: Dict[int, dict] = {}
        # telemetry (ISSUE 9): epoch-duration histograms + abort-cause
        # counter; _tele captured once so the disabled path stays free
        self._tele = telemetry.enabled()
        self._m_drain_s = telemetry.histogram("manager.drain_epoch_s")
        self._m_stage_s = telemetry.histogram("manager.stage_epoch_s")
        self._m_aborts = telemetry.counter("manager.epoch_aborts")
        telemetry.poll("manager.ops", self._ops_snapshot)
        # health engine (ISSUE 10): constructed only when telemetry is on —
        # with it off the run loop pays one ``is not None`` check and the
        # report is a static "disabled" stub
        self.health_cfg = health_cfg or HealthConfig()
        self._health: Optional[HealthEngine] = \
            HealthEngine(self.health_cfg, clock=clock) if self._tele else None
        self._health_last = 0.0

    # ------------------------------------------------------------------ api
    def alive_ring(self) -> List[str]:
        return [s for s in self.ring if s not in self.dead]

    def wait_ring(self, timeout: float = 10.0) -> bool:
        return self.ring_ready.wait(timeout)

    def flush_complete(self, epoch: int) -> bool:
        """True once every PARTICIPANT — the alive ring snapshotted at
        begin_flush — reported flush_done, excusing mid-epoch deaths. The
        empty set is never a quorum: before any server registers, or after
        the whole snapshot died, this is False (the old comparison against
        the live ring made ``set() >= set()`` vacuously True). Reads the
        snapshot without _flush_lock — _on_flush_done calls in holding it,
        and dict reads are atomic under the GIL."""
        expected = self._flush_expected.get(epoch)
        if expected is None:
            expected = set(self.alive_ring())
        live = expected - self.dead
        return bool(live) and self.flush_done.get(epoch, set()) >= live

    def wait_flush(self, epoch: int, timeout: float = 30.0) -> bool:
        deadline = self._clock() + timeout
        while self._clock() < deadline:
            if self.flush_complete(epoch):
                return True
            time.sleep(self.flush_poll_interval)
        return False

    def stop(self):
        self._stop.set()

    # --------------------------------------------------------------- thread
    def run(self):
        # replay the journal before the first message: handlers must never
        # observe (or journal over) a half-recovered namespace
        self._replay_journal()
        while not self._stop.is_set():
            msg = self.ep.recv(timeout=self.poll_interval)
            now = self._clock()
            if self._drain is not None \
                    and now - self._drain["started"] > self.drain_epoch_timeout:
                self._abort_drain("timeout")
            if self._stage is not None \
                    and now - self._stage["started"] > self.drain_epoch_timeout:
                self._abort_stage("timeout")
            self._sweep_stale_flushes(now)
            if self._health is not None and \
                    now - self._health_last >= self.health_cfg.interval_s:
                self._health_last = now
                self._evaluate_health(now)
            if msg is None:
                continue
            handler = getattr(self, f"_on_{msg.kind}", None)
            if handler is not None:
                if self._tele:
                    with telemetry.msg_span("manager." + msg.kind,
                                            self.tname, msg.payload):
                        handler(msg)
                else:
                    handler(msg)
        # close in the owning thread, after the last handler could write
        fh, self._journal_fh = self._journal_fh, None
        if fh is not None:
            fh.close()

    # ------------------------------------------------- recovery journal
    def _journal(self, rec: dict):
        """Append one journal record, durable before return: the ack a
        handler sends after this is a promise the metadata survives."""
        if not self.journal_path:
            return
        if self._journal_fh is None:
            self._journal_fh = open(self.journal_path, "ab")
        self._journal_fh.write(json.dumps(rec, sort_keys=True).encode()
                               + b"\n")
        self._journal_fh.flush()
        os.fsync(self._journal_fh.fileno())

    def _journal_ns(self, path: str):
        ent = self.namespace.get(path)
        if ent is not None:
            self._journal({"op": "ns", "path": path,
                           "size": ent["size"], "synced": ent["synced"]})

    def _replay_journal(self):
        """Rebuild namespace/lookup/epoch counters from the journal. Stops
        at the first unparsable or incomplete line (a torn tail from a
        mid-append crash) and truncates it away so the append-only
        invariant holds for the new incarnation."""
        if not self.journal_path or not os.path.exists(self.journal_path):
            return
        good = 0
        with open(self.journal_path, "rb") as fh:
            for line in fh:
                if not line.endswith(b"\n"):
                    break
                try:
                    self._apply_journal(json.loads(line))
                except (ValueError, KeyError, TypeError):
                    break
                good += len(line)
        if good < os.path.getsize(self.journal_path):
            with open(self.journal_path, "r+b") as fh:
                fh.truncate(good)
                fh.flush()
                os.fsync(fh.fileno())

    def _apply_journal(self, rec: dict):
        op = rec["op"]
        if op == "ns":
            self.namespace[rec["path"]] = {
                "size": int(rec["size"]), "synced": bool(rec["synced"]),
                "opened_by": set()}   # sessions do not survive a restart
        elif op == "ns_del":
            self.namespace.pop(rec["path"], None)
        elif op == "lookup":
            for f, sz in rec["sizes"].items():
                if int(sz) > self.lookup.get(f, -1):
                    self.lookup[f] = int(sz)
        elif op == "lookup_del":
            self.lookup.pop(rec["path"], None)
        elif op == "epoch":
            # re-allocated ids must never collide with pre-crash ones
            if "drain" in rec:
                self._next_drain_epoch = max(self._next_drain_epoch,
                                             int(rec["drain"]) + 1)
            if "stage" in rec:
                self._next_stage_epoch = max(self._next_stage_epoch,
                                             int(rec["stage"]) + 1)
        # unknown ops from a newer incarnation are ignored, not fatal

    def _sweep_stale_flushes(self, now: float):
        """A user epoch wedged past any plausible completion must not
        block drain micro-epochs forever."""
        stale = now - 4 * self.drain_epoch_timeout
        with self._flush_lock:
            for e in [e for e, t in self._user_flushes.items() if t < stale]:
                self._user_flushes.pop(e, None)

    # ------------------------------------------------------------- handlers
    def _on_register(self, msg: Message):
        """Servers register at startup; once all expected have arrived, the
        manager arranges the ring (sorted ids) and distributes it."""
        self._registered.add(msg.src)
        if len(self._registered) >= self.expected and not self.ring:
            self.ring = sorted(self._registered)
            self._broadcast_ring()
            self.ring_ready.set()

    def _on_client_hello(self, msg: Message):
        self.clients.add(msg.src)
        if self.ring:
            self.transport.reply(self.tname, msg, "ring",
                                 {"ring": self.ring,
                                  "dead": sorted(self.dead)})

    def _broadcast_ring(self):
        # the lookup table rides along so a recovered manager re-seeds
        # flushed-file sizes into every server at ring formation (ISSUE 8)
        for dst in list(self.ring) + sorted(self.clients):
            self.transport.send(self.tname, dst, "ring",
                                {"ring": self.ring,
                                 "dead": sorted(self.dead),
                                 "lookup": dict(self.lookup)})

    def _on_failure_report(self, msg: Message):
        dead = msg.payload["dead"]
        if dead in self.dead or dead not in self.ring:
            return
        self.dead.add(dead)
        telemetry.record(self.tname, "server_dead", server=dead,
                         reported_by=msg.src)
        # a death mid-drain invalidates the epoch's domain plan (the dead
        # server's owned domains may never reach the PFS) — abort before
        # anything can be evicted; the chunks re-drain from replicas later.
        # A death mid-stage just aborts the bulk load: staged bytes are
        # clean copies of durable data, reads stay correct via fallback.
        self._abort_drain(f"server failure: {dead}")
        self._abort_stage(f"server failure: {dead}")
        for dst in self.alive_ring() + sorted(self.clients):
            self.transport.send(self.tname, dst, "ring_update",
                                {"dead": [dead]})

    def _on_join_request(self, msg: Message):
        """Paper Fig 3: a joining server names its predecessor; the manager
        inserts it and triggers stabilization via a ring_update."""
        server = msg.payload["server"]
        pred = msg.payload.get("pred")
        if server in self.ring and server not in self.dead:
            return
        if server in self.dead:
            self.dead.discard(server)
        elif pred in self.ring:
            self.ring.insert(self.ring.index(pred) + 1, server)
        else:
            self.ring.append(server)
        for dst in self.alive_ring() + sorted(self.clients):
            self.transport.send(self.tname, dst, "ring_update",
                                {"joined": [server], "pred": pred})
        # the joiner itself gets the authoritative membership + lookup
        # table directly — a crash-restarted server rejoins with an empty
        # lookup and must relearn flushed-file sizes for range reads
        self.transport.send(self.tname, server, "ring",
                            {"ring": self.ring, "dead": sorted(self.dead),
                             "lookup": dict(self.lookup)})

    def _on_flush_done(self, msg: Message):
        epoch = msg.payload["epoch"]
        self.flush_done.setdefault(epoch, set()).add(msg.payload["server"])
        self.flush_bytes[epoch] = self.flush_bytes.get(epoch, 0) \
            + msg.payload.get("bytes", 0)
        # learn flushed-file sizes (max-merge, like the servers' own
        # lookup tables) and journal only what actually grew
        grown = {f: int(sz)
                 for f, sz in msg.payload.get("sizes", {}).items()
                 if int(sz) > self.lookup.get(f, -1)}
        if grown:
            self.lookup.update(grown)
            self._journal({"op": "lookup", "sizes": grown})
        # completion ledgers are bounded FIFO caches: epochs that aborted
        # (their flush_done never reaches quorum) would otherwise leak an
        # entry forever
        while len(self.flush_done) > self.flush_ledger_cap:
            e = next(iter(self.flush_done))
            self.flush_done.pop(e, None)
            self.flush_bytes.pop(e, None)
        with self._flush_lock:
            if epoch in self._user_flushes and self.flush_complete(epoch):
                del self._user_flushes[epoch]
        d = self._drain
        if d is not None and epoch == d["epoch"]:
            d["done"].add(msg.payload["server"])
            d["drained"].update(msg.payload.get("drained", []))
            d["bytes"] += msg.payload.get("bytes", 0)
            # strict completion: EVERY snapshot participant must report its
            # PFS writes durable before eviction may be broadcast (a death
            # mid-epoch goes through _abort_drain instead)
            if d["done"] >= d["expected"]:
                self._drain = None
                self.drain_stats["epochs"] += 1
                self.drain_stats["evicted_keys"] += len(d["drained"])
                self.drain_stats["drained_bytes"] += d["bytes"]
                if self._tele:
                    self._m_drain_s.observe(self._clock() - d["started"])
                telemetry.record(self.tname, "drain_complete", epoch=epoch,
                                 keys=len(d["drained"]), nbytes=d["bytes"])
                keys = sorted(d["drained"])
                for s in self.alive_ring():
                    self.transport.send(self.tname, s, "drain_evict",
                                        {"epoch": epoch, "keys": keys})

    def _on_server_error(self, msg: Message):
        self.errors.append(msg.payload)

    # autonomous drain coordination (ISSUE 3) ------------------------------
    def _on_drain_pressure(self, msg: Message):
        self.pressure[msg.payload.get("server", msg.src)] = msg.payload

    def _on_drain_request(self, msg: Message):
        """A pressured server asked for a drain micro-epoch. One at a time,
        and never while an application flush epoch is in flight — the two-
        phase state (shuffle buffers, lookup sizes) is shared per server."""
        with self._flush_lock:
            busy = bool(self._user_flushes)
        if self._drain is not None or self._stage is not None or busy \
                or not self.ring:
            return
        epoch = self._next_drain_epoch
        self._next_drain_epoch += 1
        self._journal({"op": "epoch", "drain": epoch})
        self._drain = {"epoch": epoch, "started": self._clock(),
                       "expected": set(self.alive_ring()), "done": set(),
                       "drained": set(), "bytes": 0,
                       "requested_by": msg.payload.get("server")}
        telemetry.record(self.tname, "drain_begin", epoch=epoch,
                         requested_by=msg.payload.get("server"))
        for s in self.alive_ring():
            self.transport.send(self.tname, s, "flush_begin",
                                {"epoch": epoch, "drain": True})

    def _abort_drain(self, reason: str):
        d, self._drain = self._drain, None
        if d is None:
            return
        self.drain_stats["aborts"] += 1
        # cause label keeps the cardinality bounded: "server failure: s2"
        # collapses to "drain/server failure"
        self._m_aborts.inc(label="drain/" + reason.split(":")[0])
        telemetry.record(self.tname, "drain_abort", epoch=d["epoch"],
                         reason=reason)
        # notify every epoch PARTICIPANT, not just the currently-alive ring:
        # a falsely-dead server is still running and must refund its token
        # budget and drop its epoch snapshot (really-dead ones black-hole)
        for s in sorted(set(self.alive_ring()) | d["expected"]):
            self.transport.send(self.tname, s, "flush_abort",
                                {"epoch": d["epoch"], "reason": reason})

    # health engine (ISSUE 10) ---------------------------------------------
    def _evaluate_health(self, now: float):
        """One SLO/watchdog/attribution pass on the run-loop cadence. The
        engine must never take the manager down: an evaluation error is
        flight-recorded and the stale report stands until the next tick."""
        reg = telemetry.registry()
        if reg is None:
            return
        inflight = {}
        d, st = self._drain, self._stage
        if d is not None:
            inflight["drain"] = {"epoch": d["epoch"],
                                 "started": d["started"]}
        if st is not None:
            inflight["stage"] = {"epoch": st["epoch"],
                                 "started": st["started"]}
        try:
            self._health.evaluate(reg.snapshot(), inflight=inflight,
                                  tracer=reg.tracer, now=now)
        except Exception as e:      # pragma: no cover - defensive
            telemetry.record("health", "evaluate_error", error=repr(e))

    def health_report(self) -> dict:
        """The latest health verdict (``health_query`` payload). A static
        stub when telemetry (and therefore the engine) is disabled."""
        if self._health is None:
            return {"status": "disabled", "evals": 0, "t": 0.0, "slos": [],
                    "watchdogs": [], "bottlenecks": {"ops": {}, "top": None}}
        return self._health.report()

    def _on_health_query(self, msg: Message):
        self.transport.reply(self.tname, msg, "health",
                             dict(self.health_report()))

    def _ops_snapshot(self) -> dict:
        """Telemetry poll callback (ISSUE 9): epoch counters + membership
        summary. Own-thread-mutated dicts of GIL-atomic ints — copies are
        coherent without a lock."""
        return {"drain": dict(self.drain_stats),
                "stage": dict(self.stage_stats),
                "dead": sorted(self.dead), "errors": len(self.errors)}

    def pressure_report(self) -> dict:
        """Cluster pressure view: per-server occupancy reports plus drain
        and stage progress counters, and the QoS summary the congestion
        windows act on (ISSUE 5)."""
        d, st = self._drain, self._stage
        return {"servers": dict(self.pressure),
                "drain": dict(self.drain_stats),
                "stage": dict(self.stage_stats),
                "qos": self.qos_summary(),
                "health": self.health_report(),
                "inflight_epoch": d["epoch"] if d is not None else None,
                "inflight_stage": st["epoch"] if st is not None else None}

    def qos_summary(self) -> dict:
        """Cluster-level congestion view from the per-server pressure
        reports: occupancy spread and aggregate foreground ingest rate —
        what an operator (or the quickstart demo) reads to see whether the
        control plane is throttling background lanes."""
        occ = [p.get("fraction", 0.0) for p in self.pressure.values()]
        rates = [p.get("ingest_bps", 0.0) for p in self.pressure.values()]
        return {"servers_reporting": len(occ),
                "max_occupancy": max(occ, default=0.0),
                "mean_occupancy": sum(occ) / len(occ) if occ else 0.0,
                "aggregate_ingest_bps": sum(rates),
                "draining": sum(1 for p in self.pressure.values()
                                if p.get("draining"))}

    # stage-in coordination (ISSUE 4) --------------------------------------
    def _on_stage_request(self, msg: Message):
        """A client asked to bulk-load a PFS file (or byte range) back into
        the buffer. One stage epoch at a time, never while a drain micro-
        epoch or an application flush is in flight — the two engines would
        otherwise thrash the same segments (stage admitting bytes the drain
        is busy flushing, drain evicting bytes the stage just loaded)."""
        with self._flush_lock:
            busy = bool(self._user_flushes)
        if self._stage is not None or self._drain is not None or busy \
                or not self.ring:
            self.transport.reply(self.tname, msg, "stage_ack",
                                 {"accepted": False})
            return
        epoch = self._next_stage_epoch
        self._next_stage_epoch += 1
        self._journal({"op": "epoch", "stage": epoch})
        ring = self.alive_ring()
        self._stage = {"epoch": epoch, "path": msg.payload["path"],
                       "started": self._clock(),
                       "expected": set(ring), "done": set(), "bytes": 0}
        telemetry.record(self.tname, "stage_begin", epoch=epoch,
                         path=msg.payload["path"])
        for s in ring:
            self.transport.send(self.tname, s, "stage_begin",
                                {"epoch": epoch,
                                 "file": msg.payload["path"],
                                 "lo": msg.payload.get("lo", 0),
                                 "hi": msg.payload.get("hi", -1),
                                 "ring": ring})
        self.transport.reply(self.tname, msg, "stage_ack",
                             {"accepted": True, "epoch": epoch})

    def _on_stage_done(self, msg: Message):
        st = self._stage
        epoch = msg.payload["epoch"]
        if st is None or epoch != st["epoch"]:
            return                   # straggler for an aborted epoch
        st["done"].add(msg.payload["server"])
        st["bytes"] += msg.payload.get("bytes", 0)
        if st["done"] >= st["expected"]:
            self._stage = None
            self.stage_stats["epochs"] += 1
            self.stage_stats["staged_bytes"] += st["bytes"]
            if self._tele:
                self._m_stage_s.observe(self._clock() - st["started"])
            telemetry.record(self.tname, "stage_complete", epoch=epoch,
                             nbytes=st["bytes"])
            self._record_stage(epoch, "done", st["bytes"])

    def _abort_stage(self, reason: str):
        st, self._stage = self._stage, None
        if st is None:
            return
        self.stage_stats["aborts"] += 1
        self._m_aborts.inc(label="stage/" + reason.split(":")[0])
        telemetry.record(self.tname, "stage_abort", epoch=st["epoch"],
                         reason=reason)
        self._record_stage(st["epoch"], "aborted", st["bytes"])
        for s in sorted(set(self.alive_ring()) | st["expected"]):
            self.transport.send(self.tname, s, "stage_abort",
                                {"epoch": st["epoch"], "reason": reason})

    def _record_stage(self, epoch: int, state: str, nbytes: int):
        self._stage_results[epoch] = {"state": state, "bytes": nbytes}
        while len(self._stage_results) > 1024:   # bounded poll history
            self._stage_results.pop(next(iter(self._stage_results)))

    def _on_stage_status(self, msg: Message):
        epoch = msg.payload["epoch"]
        st = self._stage
        if st is not None and st["epoch"] == epoch:
            out = {"state": "inflight", "bytes": st["bytes"]}
        else:
            out = self._stage_results.get(epoch, {"state": "unknown",
                                                  "bytes": 0})
        self.transport.reply(self.tname, msg, "stage_status_ack",
                             {"epoch": epoch, **out})

    # file-session namespace (BBFileSystem) --------------------------------
    def _on_fs_open(self, msg: Message):
        """Register a path on open-for-write; idempotent. "w" resets the
        recorded size (truncate semantics); ``existed`` reports the state
        BEFORE this open so the client knows to truncate stale chunks."""
        path = msg.payload["path"]
        # any prior open-for-write counts as existing — even an unsynced
        # (crashed) incarnation may have landed chunks that must truncate
        existed = path in self.namespace
        ent = self.namespace.setdefault(
            path, {"size": 0, "synced": False, "opened_by": set()})
        ent["opened_by"].add(msg.src)
        if msg.payload.get("mode") == "w":
            ent["size"] = 0
            ent["synced"] = False
        self._journal_ns(path)
        self.transport.reply(self.tname, msg, "fs_open_ack",
                             {"path": path, "existed": existed,
                              "size": ent["size"]})

    def _on_fs_sync(self, msg: Message):
        """A sync barrier completed: record the session's high-water size."""
        path = msg.payload["path"]
        ent = self.namespace.setdefault(
            path, {"size": 0, "synced": False, "opened_by": set()})
        ent["size"] = max(ent["size"], msg.payload.get("size", 0))
        ent["synced"] = True
        # journaled BEFORE the ack: once the app's sync() returns, the
        # path's existence and size survive a manager crash
        self._journal_ns(path)
        self.transport.reply(self.tname, msg, "fs_sync_ack", {"path": path})

    def _on_fs_stat(self, msg: Message):
        """Namespace view of a path: the only source that knows about
        zero-byte synced files (no chunks, no PFS copy)."""
        ent = self.namespace.get(msg.payload["path"])
        self.transport.reply(self.tname, msg, "fs_stat_ack",
                             {"known": ent is not None and ent["synced"],
                              "size": ent["size"] if ent else 0})

    def _on_fs_list(self, msg: Message):
        # synced entries only, matching _on_fs_stat's "known" rule — an
        # opened-but-never-synced path must not appear to exist
        prefix = msg.payload.get("prefix", "")
        self.transport.reply(
            self.tname, msg, "fs_list_ack",
            {"paths": sorted(p for p, e in self.namespace.items()
                             if p.startswith(prefix) and e["synced"])})

    def _on_fs_truncate(self, msg: Message):
        path = msg.payload["path"]
        ent = self.namespace.get(path)
        if ent is not None:
            ent["size"] = 0
            ent["synced"] = False
            self._journal_ns(path)
        if path in self.lookup:
            self.lookup.pop(path, None)
            self._journal({"op": "lookup_del", "path": path})
        self.transport.reply(self.tname, msg, "fs_truncate_ack",
                             {"path": path})

    def _on_fs_unlink(self, msg: Message):
        """Drop a path from the namespace and its buffered chunks on every
        server. Uses the exact-match file_truncate message, NOT prefix
        eviction — unlinking "run" must not destroy "run_info.txt"."""
        path = msg.payload["path"]
        if self.namespace.pop(path, None) is not None:
            self._journal({"op": "ns_del", "path": path})
        if path in self.lookup:
            self.lookup.pop(path, None)
            self._journal({"op": "lookup_del", "path": path})
        for s in self.alive_ring():
            self.transport.send(self.tname, s, "file_truncate",
                                {"file": path})
        self.transport.reply(self.tname, msg, "fs_unlink_ack", {"path": path})

    def begin_flush(self, epoch: int):
        """Start an application flush epoch. Serialized against drain
        micro-epochs: overlapping epochs would share server-side shuffle
        buffers and lookup sizes, so wait (bounded) for an in-flight drain
        to finish or abort before broadcasting."""
        if epoch >= DRAIN_EPOCH_BASE:
            raise ValueError(
                f"user flush epoch {epoch} collides with the reserved "
                f"drain/stage id space (must be < {DRAIN_EPOCH_BASE})")
        deadline = self._clock() + self.drain_epoch_timeout
        while self._drain is not None and self._clock() < deadline:
            time.sleep(self.drain_serialize_poll)
        with self._flush_lock:
            self._user_flushes[epoch] = self._clock()
            # participant snapshot for flush_complete(); bounded FIFO like
            # the done/bytes ledgers (aborted epochs never clean up)
            self._flush_expected[epoch] = set(self.alive_ring())
            while len(self._flush_expected) > self.flush_ledger_cap:
                self._flush_expected.pop(next(iter(self._flush_expected)))
        for s in self.alive_ring():
            self.transport.send(self.tname, s, "flush_begin", {"epoch": epoch})

    def evict(self, prefix: str):
        for s in self.alive_ring():
            self.transport.send(self.tname, s, "evict_epoch",
                                {"prefix": prefix})
