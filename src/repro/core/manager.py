"""Burst buffer manager (paper §II, §IV-A): singleton that initializes the
server ring, distributes membership to servers and clients, and brokers
failure reports and joins. Collocated with a server on a real deployment."""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Set

from repro.core.transport import Message, Transport


class BBManager(threading.Thread):
    def __init__(self, transport: Transport, expected_servers: int,
                 name: str = "manager"):
        super().__init__(daemon=True, name=name)
        self.tname = name
        self.transport = transport
        self.ep = transport.register(name)
        self.expected = expected_servers
        self.ring: List[str] = []
        self.dead: Set[str] = set()
        self.clients: Set[str] = set()
        self.flush_done: Dict[int, Set[str]] = {}
        self.flush_bytes: Dict[int, int] = {}
        self._registered: Set[str] = set()
        self._stop = threading.Event()
        self.ring_ready = threading.Event()
        self.errors: List[dict] = []

    # ------------------------------------------------------------------ api
    def alive_ring(self) -> List[str]:
        return [s for s in self.ring if s not in self.dead]

    def wait_ring(self, timeout: float = 10.0) -> bool:
        return self.ring_ready.wait(timeout)

    def flush_complete(self, epoch: int) -> bool:
        return self.flush_done.get(epoch, set()) >= set(self.alive_ring())

    def wait_flush(self, epoch: int, timeout: float = 30.0) -> bool:
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if self.flush_complete(epoch):
                return True
            time.sleep(0.01)
        return False

    def stop(self):
        self._stop.set()

    # --------------------------------------------------------------- thread
    def run(self):
        while not self._stop.is_set():
            msg = self.ep.recv(timeout=0.05)
            if msg is None:
                continue
            handler = getattr(self, f"_on_{msg.kind}", None)
            if handler is not None:
                handler(msg)

    # ------------------------------------------------------------- handlers
    def _on_register(self, msg: Message):
        """Servers register at startup; once all expected have arrived, the
        manager arranges the ring (sorted ids) and distributes it."""
        self._registered.add(msg.src)
        if len(self._registered) >= self.expected and not self.ring:
            self.ring = sorted(self._registered)
            self._broadcast_ring()
            self.ring_ready.set()

    def _on_client_hello(self, msg: Message):
        self.clients.add(msg.src)
        if self.ring:
            self.transport.reply(self.tname, msg, "ring",
                                 {"ring": self.ring,
                                  "dead": sorted(self.dead)})

    def _broadcast_ring(self):
        for dst in list(self.ring) + sorted(self.clients):
            self.transport.send(self.tname, dst, "ring", {"ring": self.ring})

    def _on_failure_report(self, msg: Message):
        dead = msg.payload["dead"]
        if dead in self.dead or dead not in self.ring:
            return
        self.dead.add(dead)
        for dst in self.alive_ring() + sorted(self.clients):
            self.transport.send(self.tname, dst, "ring_update",
                                {"dead": [dead]})

    def _on_join_request(self, msg: Message):
        """Paper Fig 3: a joining server names its predecessor; the manager
        inserts it and triggers stabilization via a ring_update."""
        server = msg.payload["server"]
        pred = msg.payload.get("pred")
        if server in self.ring and server not in self.dead:
            return
        if server in self.dead:
            self.dead.discard(server)
        elif pred in self.ring:
            self.ring.insert(self.ring.index(pred) + 1, server)
        else:
            self.ring.append(server)
        for dst in self.alive_ring() + sorted(self.clients):
            self.transport.send(self.tname, dst, "ring_update",
                                {"joined": [server], "pred": pred})

    def _on_flush_done(self, msg: Message):
        epoch = msg.payload["epoch"]
        self.flush_done.setdefault(epoch, set()).add(msg.payload["server"])
        self.flush_bytes[epoch] = self.flush_bytes.get(epoch, 0) \
            + msg.payload.get("bytes", 0)

    def _on_server_error(self, msg: Message):
        self.errors.append(msg.payload)

    def begin_flush(self, epoch: int):
        for s in self.alive_ring():
            self.transport.send(self.tname, s, "flush_begin", {"epoch": epoch})

    def evict(self, prefix: str):
        for s in self.alive_ring():
            self.transport.send(self.tname, s, "evict_epoch",
                                {"prefix": prefix})
