"""Unified telemetry: metrics registry, causal tracing, flight recorder.

ISSUE 9. Three concerns, one substrate, all off by default:

- **Metrics registry**: named counters / gauges / fixed-bucket latency
  histograms / bounded time-series rings, declared up front in ``CATALOG``
  (``docs/METRICS.md`` is rendered from it and drift-checked by
  ``scripts/ci.sh --lint``). Components bind instruments once at
  construction; with telemetry disabled every factory returns the shared
  ``NOOP`` singleton, so the hot paths pay a single no-op method call at
  most. Existing ad-hoc stats dicts (``client.stats``,
  ``manager.drain_stats``, ``bypass_stats``, per-server ``stats_query``
  payloads) are absorbed without touching their owners' locking: the
  owner registers a *poll* callback that snapshots the dict under its own
  lock, and the registry calls it — holding no registry lock — only when
  someone actually scrapes.

- **Causal tracing**: a thread-local span stack plus a trace context
  (``[trace_id, parent_span_id]``) that ``Transport.send/request/reply``
  piggybacks on dict payloads under the ``TRACE_KEY`` key. Handlers never
  read that key themselves — dispatch loops wrap handler calls in
  ``msg_span``, which re-parents the receive-side span under the sender's
  span, so one logical op (a put, a pread, a drain micro-epoch, a
  checkpoint save) becomes a span tree across client -> server -> replica
  -> manager. Only explicitly-opened roots are traced: an untraced
  message costs one dict ``.get``. ``export_chrome`` emits Chrome
  trace-event JSON loadable in Perfetto / ``chrome://tracing``.

- **Flight recorder**: a bounded per-component ring of recent structured
  events (epoch begin/abort/complete, evictions, redirects, timeouts,
  failovers, server death). ``tests/conftest.py`` dumps it to
  ``$BB_FLIGHT_ARTIFACT`` on any test failure, next to the lock-order
  artifact, so a red test ships its own post-mortem.

Clock-injected throughout (bbcheck rule 4): the registry owns one
monotonic clock and every timestamp routes through it, so tests can drive
telemetry time deterministically.
"""
from __future__ import annotations

import bisect
import collections
import itertools
import json
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from . import locktrack

# The key Transport injects into dict payloads to carry the trace context.
# tools/bbcheck's schema pass knows it as transport-injected; handlers must
# go through msg_span()/trace_from() instead of reading it directly.
TRACE_KEY = "_trace"

# Every instrument the system may bind, alphabetical by name:
# (name, type, unit, owner component, description). docs/METRICS.md is
# rendered from this tuple (tools/bbcheck --emit-metrics); binding a name
# that is not declared here raises, which is what keeps the doc honest.
CATALOG: Tuple[Tuple[str, str, str, str, str], ...] = (
    ("ckpt.restore_s", "histogram", "seconds", "checkpoint",
     "Wall time of one CheckpointManager.restore() call."),
    ("ckpt.save_s", "histogram", "seconds", "checkpoint",
     "Wall time of one CheckpointManager.save() ingest (the async PFS "
     "flush is timed separately under the same trace)."),
    ("client.dispatch_s", "histogram", "seconds", "client",
     "Write-op wire dispatch to replicated-ACK completion, keyed by QoS "
     "lane."),
    ("client.lane_wait_s", "histogram", "seconds", "client",
     "Time a write op parks in the client WDRR lane queue before "
     "dispatch, keyed by QoS lane."),
    ("client.ops", "poll", "count", "client",
     "Per-client op counters (BBClient.stats), one label per client."),
    ("fs.bypass", "poll", "count", "filesystem",
     "Write-through bypass counters (BBFileSystem.bypass_stats)."),
    ("health.anomalies", "counter", "count", "health",
     "Stall-watchdog anomalies raised by the health engine, keyed by "
     "anomaly kind (epoch_stall / silent_server / queue_growth)."),
    ("health.eval_s", "histogram", "seconds", "health",
     "Wall time of one HealthEngine.evaluate() pass over a registry "
     "snapshot."),
    ("manager.drain_epoch_s", "histogram", "seconds", "manager",
     "Drain micro-epoch duration, drain_request arrival to the last "
     "flush_done."),
    ("manager.epoch_aborts", "counter", "count", "manager",
     "Aborted drain/stage epochs, keyed by phase/cause."),
    ("manager.ops", "poll", "count", "manager",
     "Manager epoch counters (drain_stats + stage_stats)."),
    ("manager.stage_epoch_s", "histogram", "seconds", "manager",
     "Stage-in epoch duration, stage_request arrival to stage_done."),
    ("qos.occupancy_ewma", "gauge", "fraction", "qos",
     "Congestion-window occupancy EWMA (CongestionWindows), labeled by "
     "owning client."),
    ("server.dispatch_s", "histogram", "seconds", "server",
     "Handler service time for laned kinds (put / put_batch / "
     "replica_put / replica_put_batch), keyed by lane."),
    ("server.lane_wait_s", "histogram", "seconds", "server",
     "Time a laned message parks in the server WDRR queue before "
     "dispatch, keyed by lane."),
    ("server.occupancy", "ring", "fraction", "server",
     "Sampled storage-occupancy fraction at the drain pressure cadence, "
     "labeled by server."),
    ("server.ops", "poll", "count", "server",
     "Per-server op counters (BBServer.stats), one label per server."),
    ("store.compact_s", "histogram", "seconds", "tiering",
     "Wall time of one LogStore.compact() pass including its fsync."),
    ("store.crc_failures", "counter", "count", "tiering",
     "Log records dropped at recovery because the stored CRC did not "
     "match the payload, labeled by store."),
    ("store.fsync_s", "histogram", "seconds", "tiering",
     "Record-log fsync latency, keyed by caller (spill / sync / "
     "compact)."),
    ("store.spill_s", "histogram", "seconds", "tiering",
     "Wall time of one DRAM->SSD spill batch including its barrier "
     "fsync."),
    ("transport.msgs", "counter", "count", "transport",
     "Messages accepted by Transport.send/request, keyed by kind."),
    ("transport.src_msgs", "counter", "count", "transport",
     "Messages accepted by Transport.send/request, keyed by the sending "
     "endpoint — the health engine's silent-server watchdog reads this "
     "to spot a server whose send counter stops advancing while peers' "
     "advance."),
)

_CATALOG_BY_NAME = {spec[0]: spec for spec in CATALOG}


class _Noop:
    """Shared do-nothing instrument *and* span: every recording method is
    a pass and it is its own context manager, so disabled call sites cost
    one attribute lookup and nothing else."""

    __slots__ = ()

    def inc(self, n: int = 1, label: Optional[str] = None):
        pass

    def add(self, n: int, label: Optional[str] = None):
        pass

    def set(self, value: float, label: Optional[str] = None):
        pass

    def observe(self, value: float, label: Optional[str] = None):
        pass

    def note(self, value: float, label: Optional[str] = None):
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NOOP = _Noop()


# ------------------------------------------------------------- instruments
class Counter:
    """Monotonic counter, one integer cell per label."""

    def __init__(self, name: str, clock: Callable[[], float]):
        self.name = name
        self._lock = locktrack.lock("Counter._lock")
        self._vals: Dict[str, float] = {}

    def inc(self, n: int = 1, label: Optional[str] = None):
        with self._lock:
            key = label or ""
            self._vals[key] = self._vals.get(key, 0) + n

    add = inc

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            return dict(self._vals)


class Gauge:
    """Last-write-wins point-in-time value per label."""

    def __init__(self, name: str, clock: Callable[[], float]):
        self.name = name
        self._lock = locktrack.lock("Gauge._lock")
        self._vals: Dict[str, float] = {}

    def set(self, value: float, label: Optional[str] = None):
        with self._lock:
            self._vals[label or ""] = float(value)

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            return dict(self._vals)


class Histogram:
    """Fixed-bucket latency histogram per label.

    Geometric bounds, half-decade steps from 10us to 10s plus an overflow
    bucket — wide enough for an fsync and a drain epoch on one scale."""

    BOUNDS = (1e-5, 3.16e-5, 1e-4, 3.16e-4, 1e-3, 3.16e-3, 1e-2, 3.16e-2,
              0.1, 0.316, 1.0, 3.16, 10.0)

    def __init__(self, name: str, clock: Callable[[], float]):
        self.name = name
        self._lock = locktrack.lock("Histogram._lock")
        self._series: Dict[str, dict] = {}

    def observe(self, value: float, label: Optional[str] = None):
        idx = bisect.bisect_right(self.BOUNDS, value)
        with self._lock:
            st = self._series.get(label or "")
            if st is None:
                st = self._series[label or ""] = {
                    "count": 0, "sum": 0.0, "min": value, "max": value,
                    "buckets": [0] * (len(self.BOUNDS) + 1)}
            st["count"] += 1
            st["sum"] += value
            if value < st["min"]:
                st["min"] = value
            if value > st["max"]:
                st["max"] = value
            st["buckets"][idx] += 1

    def snapshot(self) -> dict:
        with self._lock:
            series = {k: {**v, "buckets": list(v["buckets"])}
                      for k, v in self._series.items()}
        return {"bounds": list(self.BOUNDS), "series": series}


class Ring:
    """Bounded time series: (t, label, value) samples, oldest dropped."""

    MAXLEN = 512

    def __init__(self, name: str, clock: Callable[[], float]):
        self.name = name
        self._clock = clock
        self._lock = locktrack.lock("Ring._lock")
        self._dq: collections.deque = collections.deque(maxlen=self.MAXLEN)

    def note(self, value: float, label: Optional[str] = None):
        with self._lock:
            self._dq.append((self._clock(), label or "", float(value)))

    def snapshot(self) -> List[list]:
        with self._lock:
            return [list(t) for t in self._dq]


_TYPES = {"counter": Counter, "gauge": Gauge, "histogram": Histogram,
          "ring": Ring}
_SNAPSHOT_KEYS = {"counter": "counters", "gauge": "gauges",
                  "histogram": "histograms", "ring": "rings"}


# ----------------------------------------------------------------- tracing
class _SpanStack(threading.local):
    def __init__(self):
        self.stack: List["Span"] = []


_SPANS = _SpanStack()


class Span:
    """One timed node of a trace tree; a context manager. While entered it
    sits on this thread's span stack, so any Transport send issued inside
    it carries ``[trace_id, span_id]`` to the receiver."""

    __slots__ = ("_tracer", "name", "component", "trace_id", "span_id",
                 "parent_id", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, component: str,
                 trace_id: int, parent_id: int, args: dict):
        self._tracer = tracer
        self.name = name
        self.component = component
        self.trace_id = trace_id
        self.span_id = next(tracer._ids)
        self.parent_id = parent_id
        self.args = args
        self._t0 = 0.0

    def __enter__(self) -> "Span":
        self._t0 = self._tracer._clock()
        _SPANS.stack.append(self)
        return self

    def __exit__(self, *exc):
        st = _SPANS.stack
        if st and st[-1] is self:
            st.pop()
        else:                               # defensive: misnested exit
            try:
                st.remove(self)
            except ValueError:
                pass
        self._tracer._finish(self, self._tracer._clock())
        return False


class Tracer:
    """Bounded ring of completed spans + the span/trace id allocator."""

    MAXLEN = 65536

    def __init__(self, clock: Callable[[], float]):
        self._clock = clock
        self._ids = itertools.count(1)
        self._lock = locktrack.lock("Tracer._lock")
        self._events: collections.deque = collections.deque(
            maxlen=self.MAXLEN)
        # lifetime count of finished spans — the deque drops its oldest
        # entries, so incremental consumers (the health engine's critical-
        # path pass) diff this to know how many tail events are new
        self._count = 0

    def current_ctx(self) -> Optional[List[int]]:
        st = _SPANS.stack
        if not st:
            return None
        top = st[-1]
        return [top.trace_id, top.span_id]

    def root(self, name: str, component: str, **args) -> Span:
        return Span(self, name, component, next(self._ids), 0, args)

    def span(self, name: str, component: str, ctx=None, **args):
        """Child span: parented by an explicit message context if one
        rode in, else by this thread's current span; with neither, the
        work stays untraced (roots are only opened explicitly)."""
        if isinstance(ctx, (list, tuple)) and len(ctx) == 2:
            return Span(self, name, component, ctx[0], ctx[1], args)
        cur = _SPANS.stack
        if not cur:
            return NOOP
        top = cur[-1]
        return Span(self, name, component, top.trace_id, top.span_id, args)

    def _finish(self, span: Span, t1: float):
        with self._lock:
            self._count += 1
            self._events.append((span.trace_id, span.span_id,
                                 span.parent_id, span.name, span.component,
                                 span._t0, t1 - span._t0, span.args))

    def observe(self, name: str, component: str, ctx, t0: float,
                dur: float, **args):
        """Record an externally-timed, already-completed span parented by
        an explicit trace context — for wait intervals measured outside a
        ``with`` block (a message parked in a lane queue has no thread
        executing it, so nothing could hold a live span open)."""
        if not (isinstance(ctx, (list, tuple)) and len(ctx) == 2):
            return
        with self._lock:
            self._count += 1
            self._events.append((ctx[0], next(self._ids), ctx[1], name,
                                 component, t0, dur, args))

    def events(self) -> List[tuple]:
        with self._lock:
            return list(self._events)

    def events_total(self) -> int:
        """Finished spans over this tracer's lifetime (not bounded by the
        ring) — the watermark for incremental event consumers."""
        with self._lock:
            return self._count

    def chrome_events(self) -> List[dict]:
        """Chrome trace-event JSON: one complete ('X') event per span plus
        thread_name metadata mapping tids back to components."""
        tids: Dict[str, int] = {}
        out: List[dict] = []
        for trace, span_id, parent, name, comp, t0, dur, args in \
                self.events():
            tid = tids.setdefault(comp, len(tids) + 1)
            out.append({"name": name, "cat": comp, "ph": "X", "pid": 1,
                        "tid": tid, "ts": t0 * 1e6, "dur": dur * 1e6,
                        "args": {"trace": trace, "span": span_id,
                                 "parent": parent, **args}})
        for comp, tid in sorted(tids.items()):
            out.append({"name": "thread_name", "ph": "M", "pid": 1,
                        "tid": tid, "args": {"name": comp}})
        return out


# --------------------------------------------------------- flight recorder
class FlightRecorder:
    """Bounded per-component ring of recent structured events, dumped to a
    JSON artifact on crash or test failure (conftest wires the latter)."""

    PER_COMPONENT = 512

    def __init__(self, clock: Callable[[], float]):
        self._clock = clock
        self._lock = locktrack.lock("FlightRecorder._lock")
        self._by_component: Dict[str, collections.deque] = {}

    def record(self, component: str, event: str, **fields):
        t = self._clock()
        with self._lock:
            dq = self._by_component.get(component)
            if dq is None:
                dq = self._by_component[component] = collections.deque(
                    maxlen=self.PER_COMPONENT)
            dq.append({"t": t, "event": event, **fields})

    def snapshot(self) -> Dict[str, List[dict]]:
        with self._lock:
            return {c: list(dq)
                    for c, dq in sorted(self._by_component.items())}

    def dump(self, path: str, **extra) -> str:
        doc = {"flight": self.snapshot(), **extra}
        with open(path, "w") as fh:
            json.dump(doc, fh, indent=2, sort_keys=True, default=repr)
        return path


# ---------------------------------------------------------------- registry
class Registry:
    """One clock, one instrument table, one tracer, one flight recorder.

    Instruments are created lazily on first bind and validated against
    CATALOG; poll callbacks are keyed by (name, label) so re-constructed
    components (every test builds a fresh system) replace rather than
    accumulate."""

    def __init__(self, clock: Callable[[], float] = time.monotonic):
        self._clock = clock
        self._lock = locktrack.lock("Registry._lock")
        self._instruments: Dict[str, Any] = {}
        self._pollers: Dict[Tuple[str, str], Callable[[], dict]] = {}
        self.tracer = Tracer(clock)
        self.flight = FlightRecorder(clock)

    def _get(self, name: str, kind: str):
        spec = _CATALOG_BY_NAME.get(name)
        if spec is None or spec[1] != kind:
            raise ValueError(
                f"unknown {kind} instrument {name!r} — declare it in "
                f"telemetry.CATALOG (docs/METRICS.md is rendered from it)")
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = self._instruments[name] = _TYPES[kind](
                    name, self._clock)
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, "counter")

    def gauge(self, name: str) -> Gauge:
        return self._get(name, "gauge")

    def histogram(self, name: str) -> Histogram:
        return self._get(name, "histogram")

    def ring(self, name: str) -> Ring:
        return self._get(name, "ring")

    def poll(self, name: str, fn: Callable[[], dict], label: str = ""):
        spec = _CATALOG_BY_NAME.get(name)
        if spec is None or spec[1] != "poll":
            raise ValueError(
                f"unknown poll instrument {name!r} — declare it in "
                f"telemetry.CATALOG (docs/METRICS.md is rendered from it)")
        with self._lock:
            self._pollers[(name, label)] = fn

    def snapshot(self) -> dict:
        """Full registry dump. Poll callbacks run with no registry lock
        held — they take their owner's lock, never the reverse, which is
        what keeps the lock-order graph acyclic."""
        with self._lock:
            instruments = dict(self._instruments)
            pollers = dict(self._pollers)
        out: Dict[str, dict] = {"counters": {}, "gauges": {},
                                "histograms": {}, "rings": {}, "polls": {}}
        for name, inst in sorted(instruments.items()):
            out[_SNAPSHOT_KEYS[_CATALOG_BY_NAME[name][1]]][name] = \
                inst.snapshot()
        for (name, label), fn in sorted(pollers.items()):
            try:
                val = fn()
            except Exception:       # owner mid-teardown: skip, don't fail
                continue
            out["polls"].setdefault(name, {})[label] = val
        return out


# ------------------------------------------------------------- module API
# Mirrors locktrack: a module-level singleton the factories consult, so
# components bind real instruments only when a harness (conftest, bbstat,
# an operator) opted in before constructing the system.
_registry: Optional[Registry] = None


def enable(clock: Callable[[], float] = time.monotonic) -> Registry:
    """Idempotent: returns the existing registry if already enabled."""
    global _registry
    if _registry is None:
        _registry = Registry(clock)
    return _registry


def disable():
    global _registry
    _registry = None


def enabled() -> bool:
    return _registry is not None


def registry() -> Optional[Registry]:
    return _registry


def counter(name: str):
    reg = _registry
    return NOOP if reg is None else reg.counter(name)


def gauge(name: str):
    reg = _registry
    return NOOP if reg is None else reg.gauge(name)


def histogram(name: str):
    reg = _registry
    return NOOP if reg is None else reg.histogram(name)


def ring(name: str):
    reg = _registry
    return NOOP if reg is None else reg.ring(name)


def poll(name: str, fn: Callable[[], dict], label: str = ""):
    reg = _registry
    if reg is not None:
        reg.poll(name, fn, label)


def snapshot() -> dict:
    reg = _registry
    return {} if reg is None else reg.snapshot()


def record(component: str, event: str, **fields):
    reg = _registry
    if reg is not None:
        reg.flight.record(component, event, **fields)


def span(name: str, component: str = "app", **args):
    """Open a span: child of this thread's current span if one is active,
    else a brand-new trace root."""
    reg = _registry
    if reg is None:
        return NOOP
    ctx = reg.tracer.current_ctx()
    if ctx is not None:
        return reg.tracer.span(name, component, ctx=ctx, **args)
    return reg.tracer.root(name, component, **args)


def child_span(name: str, component: str, **args):
    """Open a span ONLY if this thread already has one active — untraced
    work stays untraced (``span()`` would open a brand-new root). For
    instrumenting interior segments (an fsync inside a put) without
    rooting a trace per call."""
    reg = _registry
    if reg is None:
        return NOOP
    return reg.tracer.span(name, component, **args)


def observe_span(name: str, component: str, ctx, t0: float, dur: float,
                 **args):
    """Record an externally-timed completed span under an explicit
    ``[trace_id, parent_span_id]`` context (no-op when ctx is None — the
    op was untraced). See ``Tracer.observe``."""
    reg = _registry
    if reg is not None:
        reg.tracer.observe(name, component, ctx, t0, dur, **args)


def current_ctx() -> Optional[List[int]]:
    """This thread's current ``[trace_id, span_id]``, or None. For stamping
    a trace context onto work that will complete on another thread."""
    reg = _registry
    return None if reg is None else reg.tracer.current_ctx()


def msg_span(name: str, component: str, payload):
    """Receive-side span for one handled message, parented by the trace
    context the sender's Transport injected. The ONLY sanctioned reader of
    TRACE_KEY outside transport.py — handlers never subscript it."""
    reg = _registry
    if reg is None:
        return NOOP
    ctx = payload.get(TRACE_KEY) if isinstance(payload, dict) else None
    return reg.tracer.span(name, component, ctx=ctx)


def trace_from(payload) -> Optional[List[int]]:
    """The raw [trace_id, parent_span_id] context riding a payload."""
    if isinstance(payload, dict):
        ctx = payload.get(TRACE_KEY)
        if isinstance(ctx, (list, tuple)) and len(ctx) == 2:
            return list(ctx)
    return None


def trace_inject(payload):
    """Called by Transport on every send: piggyback the current trace
    context on dict payloads. No active span (the steady state) means no
    key and near-zero cost."""
    reg = _registry
    if reg is None or not isinstance(payload, dict):
        return payload
    ctx = reg.tracer.current_ctx()
    if ctx is not None:
        payload[TRACE_KEY] = ctx
    return payload


def export_chrome(path: Optional[str] = None):
    """Completed spans as Chrome trace-event JSON (Perfetto-loadable).
    Returns the event list, or writes ``{"traceEvents": [...]}`` to
    ``path`` and returns the path."""
    reg = _registry
    events = [] if reg is None else reg.tracer.chrome_events()
    if path is None:
        return events
    with open(path, "w") as fh:
        json.dump({"traceEvents": events}, fh, indent=1, default=repr)
    return path


def dump_flight(path: str, **extra) -> str:
    """Flight-recorder artifact, written even when telemetry is disabled
    (an empty artifact still tells the post-mortem reader that much)."""
    reg = _registry
    if reg is None:
        with open(path, "w") as fh:
            json.dump({"flight": {}, **extra}, fh, indent=2,
                      sort_keys=True)
        return path
    return reg.flight.dump(path, **extra)
