"""Two-phase I/O (paper §III-B): file-domain partitioning + segment splitting.

Pure functions — the protocol driver lives in server.py. Each shared file is
logically partitioned into n contiguous domains (n = number of servers);
every server ships its buffered segments to the domain owners; owners then
issue ONE sequential write per file to the PFS, eliminating the lock
contention of interleaved writers (ROMIO-style collective buffering).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class Segment:
    file: str
    offset: int
    length: int


def file_sizes(metas: Sequence[Segment]) -> Dict[str, int]:
    sizes: Dict[str, int] = {}
    for m in metas:
        sizes[m.file] = max(sizes.get(m.file, 0), m.offset + m.length)
    return sizes


def domains(size: int, servers: Sequence[str]) -> List[Tuple[str, int, int]]:
    """Partition [0, size) into len(servers) contiguous domains.
    Returns [(server, start, end)]; the remainder goes to the last domain.
    Domain boundaries are aligned to 1 MiB (the Lustre default stripe size in
    the paper's testbed) so each owner's PFS write is stripe-aligned."""
    n = len(servers)
    align = 1 << 20
    base = size // n
    base -= base % align
    out = []
    start = 0
    for i, s in enumerate(servers):
        end = size if i == n - 1 else min(size, start + base)
        out.append((s, start, end))
        start = end
    return out


def owner_of(offset: int, doms: List[Tuple[str, int, int]]) -> str:
    for s, a, b in doms:
        if a <= offset < b:
            return s
    return doms[-1][0]


def split_segment(seg: Segment, doms: List[Tuple[str, int, int]]
                  ) -> List[Tuple[str, int, int, int]]:
    """Split a segment across domain boundaries.
    Returns [(owner, file_offset, local_offset, length)] pieces."""
    pieces = []
    pos = seg.offset
    end = seg.offset + seg.length
    for s, a, b in doms:
        if b <= pos or a >= end or a == b:
            continue
        lo = max(pos, a)
        hi = min(end, b)
        pieces.append((s, lo, lo - seg.offset, hi - lo))
    return pieces


def plan_shuffle(my_segments: Sequence[Segment],
                 all_meta: Dict[str, List[Segment]],
                 servers: Sequence[str],
                 known_sizes: Optional[Dict[str, int]] = None):
    """Given this server's buffered segments and everyone's metadata, compute
    (sizes, per-file domain lists, outgoing pieces).

    ``known_sizes`` enables segment-subset planning (drain micro-epochs):
    when an epoch carries only a cold subset of a file's chunks, the subset's
    own extent may end short of the file's true size, and domains computed
    from it would disagree with the layout every earlier epoch wrote to the
    PFS. Passing the already-known global size per file (the lookup table)
    pins the domain partition to max(subset extent, known size), so owners
    agree across full flushes and partial drains alike. Every participant
    must pass the same map — the protocol driver broadcasts the known sizes
    with the epoch metadata to guarantee that."""
    merged: List[Segment] = [m for metas in all_meta.values() for m in metas]
    sizes = file_sizes(merged)
    if known_sizes:
        for f in sizes:
            if f in known_sizes:
                sizes[f] = max(sizes[f], known_sizes[f])
    doms = {f: domains(sz, servers) for f, sz in sizes.items()}
    sends = []
    for seg in my_segments:
        for owner, file_off, local_off, length in split_segment(
                seg, doms[seg.file]):
            sends.append((owner, seg, file_off, local_off, length))
    return sizes, doms, sends
