"""Data placement: Ketama consistent hashing and ISO (isolated) placement.

The paper (§V) implements both and finds ISO — each client's traffic pinned
to a single server — scales best for burst-buffer ingestion because it
localizes traffic per server (no cross-server interference). Ketama spreads
each client's key-value pairs over all servers, balancing capacity at the
cost of fan-out. Rendezvous (HRW) hashing is included as a beyond-paper
third option (better minimal-remap behaviour without virtual-node tables).
"""
from __future__ import annotations

import bisect
import hashlib
from typing import List, Sequence


def _md5_u32(data: str) -> int:
    return int.from_bytes(hashlib.md5(data.encode()).digest()[:4], "little")


class KetamaRing:
    """libketama-style ring: 160 virtual points per server, MD5 hash space."""

    def __init__(self, servers: Sequence[str], vnodes: int = 160):
        self.vnodes = vnodes
        self._points: List[int] = []
        self._owners: List[str] = []
        self._servers: List[str] = []
        for s in servers:
            self.add_server(s)

    def add_server(self, server: str):
        if server in self._servers:
            return
        self._servers.append(server)
        for v in range(self.vnodes):
            h = _md5_u32(f"{server}#{v}")
            i = bisect.bisect(self._points, h)
            self._points.insert(i, h)
            self._owners.insert(i, server)

    def remove_server(self, server: str):
        if server not in self._servers:
            return
        self._servers.remove(server)
        keep = [(p, o) for p, o in zip(self._points, self._owners)
                if o != server]
        self._points = [p for p, _ in keep]
        self._owners = [o for _, o in keep]

    @property
    def servers(self) -> List[str]:
        return list(self._servers)

    def lookup(self, key: str) -> str:
        if not self._points:
            raise RuntimeError("empty ring")
        h = _md5_u32(key)
        i = bisect.bisect(self._points, h)
        if i == len(self._points):
            i = 0
        return self._owners[i]

    def successors(self, key: str, n: int) -> List[str]:
        """n distinct servers following the key's point (replica set)."""
        if not self._points:
            raise RuntimeError("empty ring")
        h = _md5_u32(key)
        i = bisect.bisect(self._points, h)
        out: List[str] = []
        for j in range(len(self._points)):
            owner = self._owners[(i + j) % len(self._points)]
            if owner not in out:
                out.append(owner)
                if len(out) == n:
                    break
        return out


class IsoPlacement:
    """Isolated placement: client c -> servers[c mod n] for ALL its keys."""

    def __init__(self, servers: Sequence[str]):
        self._servers = list(servers)

    @property
    def servers(self) -> List[str]:
        return list(self._servers)

    def add_server(self, server: str):
        if server not in self._servers:
            self._servers.append(server)

    def remove_server(self, server: str):
        if server in self._servers:
            self._servers.remove(server)

    def lookup_for_client(self, client_index: int) -> str:
        return self._servers[client_index % len(self._servers)]


class RendezvousHash:
    """Highest-random-weight hashing (beyond-paper placement option)."""

    def __init__(self, servers: Sequence[str]):
        self._servers = list(servers)

    @property
    def servers(self) -> List[str]:
        return list(self._servers)

    def add_server(self, server: str):
        if server not in self._servers:
            self._servers.append(server)

    def remove_server(self, server: str):
        if server in self._servers:
            self._servers.remove(server)

    def lookup(self, key: str) -> str:
        return max(self._servers, key=lambda s: _md5_u32(f"{s}|{key}"))

    def successors(self, key: str, n: int) -> List[str]:
        ranked = sorted(self._servers, key=lambda s: _md5_u32(f"{s}|{key}"),
                        reverse=True)
        return ranked[:n]
