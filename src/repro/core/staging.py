"""Stage-in engine: manager-coordinated PFS -> BB prefetch (ISSUE 4).

The drain engine (drain.py) moves cold bytes DOWN the tiers; this module is
the same machinery run in reverse. Production burst buffers are
bidirectional staging areas — Romanus et al. (arXiv:1509.05492) name
stage-in/stage-out coupling a core capability — and after the drain engine
evicts a checkpoint, a restart that reads it back one miss at a time through
a single client serializes exactly the I/O the buffer exists to absorb.

Three cooperating pieces, split the same way drain.py splits from server.py:

  - pure planning (THIS module): domain-partitioned stage plans — given the
    union of everyone's buffered coverage, which byte ranges of MY lookup-
    table domain must be re-ingested from the PFS, sliced for sequential
    reads; a sequential-access detector that turns read() patterns into
    read-ahead windows; and a bounded thread fan-out helper shared by the
    parallel read paths.
  - the protocol driver (server.py / manager.py): stage_request ->
    stage_begin broadcast -> all-to-all stage_meta coverage exchange ->
    each server re-ingests its own domain in parallel -> stage_done.
    The manager runs ONE stage epoch at a time, serialized against drain
    micro-epochs, so the two engines can never thrash the same segments.
  - the API surface (filesystem.py): fs.stage(path) and
    BBFile(..., prefetch=...).

Staged bytes are marked CLEAN in the LogStore: they have a durable PFS copy
by construction, so the drain engine can drop them for free (tombstone, no
flush epoch) — the clean-evict fast path that keeps staging from triggering
a drain storm.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple


@dataclass
class StageConfig:
    enabled: bool = True
    slice_bytes: int = 1 << 20      # PFS read / clean-ingest granularity
    tick_bytes: int = 8 << 20       # max re-ingest per server-loop tick: the
    #                                 loop must keep answering pings mid-stage
    prefetch_window: int = 8 << 20  # read-ahead stage-in window per trigger
    prefetch_min_run: int = 2       # sequential reads before read-ahead fires
    stage_timeout_s: float = 30.0   # fs.stage(wait=True) default deadline
    request_retry_interval: float = 0.01   # stage_request retry cadence
    status_poll_interval: float = 0.005    # stage_status poll cadence


# ----------------------------------------------------------- interval math

def merge_intervals(iv: Sequence[Sequence[int]]) -> List[List[int]]:
    out: List[List[int]] = []
    for lo, hi in sorted(list(p) for p in iv):
        if out and lo <= out[-1][1]:
            out[-1][1] = max(out[-1][1], hi)
        else:
            out.append([lo, hi])
    return out


def gaps(covered: Sequence[Sequence[int]], lo: int, hi: int
         ) -> List[List[int]]:
    """Sub-intervals of [lo, hi) not covered by the (merged) interval list."""
    out = []
    pos = lo
    for a, b in covered:
        if a > pos:
            out.append([pos, min(a, hi)])
        pos = max(pos, b)
        if pos >= hi:
            break
    if pos < hi:
        out.append([pos, hi])
    return [g for g in out if g[0] < g[1]]


def plan_stage(my_domains: Sequence[Tuple[int, int]],
               requested: Tuple[int, int],
               covered: Sequence[Sequence[int]],
               slice_bytes: int) -> List[Tuple[int, int]]:
    """The stage plan for one server: (offset, length) slices of the PFS
    file this server must re-ingest.

    ``my_domains`` are this server's lookup-table domains of the file,
    ``requested`` the [lo, hi) byte range being staged, and ``covered`` the
    UNION of every participant's live buffered coverage — bytes someone
    already holds are at least as fresh as the PFS copy and must never be
    re-ingested over (a staged chunk shadowing a buffered rewrite would
    resurrect stale bytes). Gaps are sliced to ``slice_bytes`` so each
    ingest is one bounded sequential PFS read."""
    merged = merge_intervals(covered)
    lo, hi = requested
    plan: List[Tuple[int, int]] = []
    for a, b in my_domains:
        a, b = max(a, lo), min(b, hi)
        if a >= b:
            continue
        for g_lo, g_hi in gaps(merged, a, b):
            pos = g_lo
            while pos < g_hi:
                ln = min(slice_bytes, g_hi - pos)
                plan.append((pos, ln))
                pos += ln
    return plan


# --------------------------------------------------------------- read-ahead

class ReadAhead:
    """Sequential-access detector behind BBFile prefetching (pure; no I/O).

    observe(offset, length, size) is called on every positional read; once
    ``prefetch_min_run`` consecutive reads form a forward-sequential run it
    returns the next (lo, hi) window to stage in, advancing a high-water
    mark so overlapping windows are never requested twice and the next
    window is only issued once the reader is within half a window of the
    mark (staging must track the reader, not sprint ahead of it). A seek
    breaks the run (restart workloads read manifests out of order first,
    then stream the payload — only the stream should trigger)."""

    def __init__(self, cfg: StageConfig):
        self.cfg = cfg
        self._next: Optional[int] = None    # expected offset of the next read
        self._run = 0
        self._staged_to = 0                 # high-water mark of issued windows
        self.stats = {"triggers": 0, "sequential_runs": 0}

    def observe(self, offset: int, length: int, size: int
                ) -> Optional[Tuple[int, int]]:
        if length <= 0:
            return None
        if offset == self._next:
            self._run += 1
            if self._run == self.cfg.prefetch_min_run:
                self.stats["sequential_runs"] += 1
        else:
            self._run = 1
        self._next = offset + length
        if self._run < self.cfg.prefetch_min_run:
            return None
        if self._staged_to - self._next > self.cfg.prefetch_window // 2:
            return None                 # plenty staged ahead of the reader
        lo = max(self._next, self._staged_to)
        hi = min(size, lo + self.cfg.prefetch_window)
        if lo >= hi:
            return None
        self._staged_to = hi
        self.stats["triggers"] += 1
        return (lo, hi)


# ------------------------------------------------------------- thread fan-out

def parallel_map(fn: Callable, items: Sequence, workers: int) -> List:
    """Run ``fn`` over ``items`` with up to ``workers`` threads; results in
    input order. Shared by the parallel read paths (manifest chunk fetches,
    per-domain range reads) — blocking transport.request calls from several
    threads overlap their round-trips instead of hammering one server at a
    time. The first exception is re-raised in the caller. Inline for a
    single item or a single worker: fan-out must cost nothing when it
    cannot help."""
    items = list(items)
    if not items:
        return []
    if workers <= 1 or len(items) == 1:
        return [fn(it) for it in items]
    results: List = [None] * len(items)
    errors: List[BaseException] = []
    cursor = [0]
    lock = threading.Lock()

    def _worker():
        while True:
            with lock:
                if errors or cursor[0] >= len(items):
                    return
                i = cursor[0]
                cursor[0] += 1
            try:
                results[i] = fn(items[i])
            except BaseException as e:      # surfaced to the caller
                with lock:
                    errors.append(e)
                return

    threads = [threading.Thread(target=_worker, daemon=True,
                                name=f"fanout-{i}")
               for i in range(min(workers, len(items)))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        raise errors[0]
    return results
