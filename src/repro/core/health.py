"""Cluster health and diagnosis engine (ISSUE 10).

PR 9 gave the system eyes (metrics registry, causal tracer, flight
recorder); this module is the part that *interprets* those signals. Three
passes, one ``HealthEngine.evaluate()`` call, driven on the manager's
clock-injected run-loop cadence (``HealthConfig.interval_s`` via
``BBConfig.health``):

- **SLO rules** (``SLO_RULES``, declared up front like
  ``telemetry.CATALOG``): burn-rate style windows over the existing
  latency histograms — each evaluation diffs the per-bucket counts
  against the previous snapshot and computes the p99 of *this window's*
  samples, so a fresh fsync slowdown flags within one cadence instead of
  being averaged away by an hour of healthy history — plus occupancy and
  queue-depth checks. Every rule yields ``ok | warn | critical`` with the
  offending numbers attached.

- **Stall watchdogs**: wedged state machines that no latency histogram
  can see, because the stalled operation never completes and therefore
  never observes a sample. A drain/stage epoch open longer than
  ``stall_factor ×`` its own histogram p99; a server whose
  ``transport.src_msgs`` counter stops advancing while peers' advance; a
  server lane queue whose depth grows monotonically across N
  evaluations. New anomalies are recorded into the flight recorder
  (component ``health``) and counted in ``health.anomalies``.

- **Critical-path attribution** over completed ``Tracer`` span trees:
  each root span (a put, a ``ckpt.save``, a drain epoch) is decomposed
  into queue-wait / service / network / fsync segments from the span
  names PR 9 emits (``*.lane_wait`` → queue, ``store.fsync`` → fsync,
  un-instrumented gaps → network, everything else → service), using
  per-span self time (duration minus direct children). Per-op-kind
  aggregates answer "what dominates this op?" — e.g. *fsync is 61% of
  ckpt.save*.

The report surfaces through ``BBManager.pressure_report()["health"]``,
the ``health_query`` protocol message, ``BurstBufferSystem.health()``,
and the ``tools/bbtop.py`` dashboard. Everything here is clock-injected
(bbcheck rule 4) and holds no locks while evaluating — the registry
snapshot it consumes is already a coherent copy.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from . import telemetry

_RANK = {"ok": 0, "warn": 1, "critical": 2}


def worst(verdicts) -> str:
    """The most severe of a set of verdicts (``ok`` when empty)."""
    out = "ok"
    for v in verdicts:
        if _RANK.get(v, 0) > _RANK[out]:
            out = v
    return out


def quantile(bounds, buckets, count, q) -> float:
    """Approximate quantile from histogram bucket counts: linear within
    the winning bucket, upper bound for the overflow bucket. Same math as
    ``tools/bbstat`` — shared here so SLO verdicts and the CLI agree."""
    target = count * q
    seen = 0
    for i, n in enumerate(buckets):
        if not n:
            continue
        if seen + n >= target:
            if i >= len(bounds):
                return bounds[-1]
            lo = bounds[i - 1] if i else 0.0
            frac = (target - seen) / n
            return lo + (bounds[i] - lo) * frac
        seen += n
    return bounds[-1] if bounds else 0.0


# Every SLO the engine evaluates, alphabetical by rule name (mirrors
# telemetry.CATALOG's declare-up-front discipline; docs/OBSERVABILITY.md
# lists these):  (name, kind, instrument, label, warn, critical, summary).
#
# kinds:
#   latency_p99  p99 of the instrument's *current window* (bucket deltas
#                since the previous evaluation; cumulative on the first),
#                per label — ``label=None`` checks every label and reports
#                the worst offender, thresholds in seconds
#   ring_last    most recent sample per label of a ring instrument
#   poll_max     ``instrument:key`` — the named integer from each label's
#                poll snapshot, worst label reported
SLO_RULES: Tuple[Tuple[str, str, str, Optional[str], float, float, str],
                 ...] = (
    ("ckpt_lane_wait_p99", "latency_p99", "client.lane_wait_s",
     "checkpoint", 0.1, 1.0,
     "checkpoint-lane client queueing must stay bounded under floods"),
    ("ckpt_restore_p99", "latency_p99", "ckpt.restore_s", None, 2.0, 8.0,
     "checkpoint restore wall time"),
    ("ckpt_save_p99", "latency_p99", "ckpt.save_s", None, 2.0, 8.0,
     "checkpoint save ingest wall time"),
    ("drain_epoch_p99", "latency_p99", "manager.drain_epoch_s", None,
     4.0, 10.0,
     "drain micro-epochs approaching the abort timeout"),
    ("fsync_p99", "latency_p99", "store.fsync_s", None, 0.25, 1.0,
     "record-log fsync latency (spill / sync / compact)"),
    ("occupancy", "ring_last", "server.occupancy", None, 0.9, 0.98,
     "server storage occupancy near eviction pressure"),
    ("queue_depth", "poll_max", "server.ops:queued_puts", None,
     512.0, 4096.0,
     "server lane-queue backlog"),
    ("server_lane_wait_p99", "latency_p99", "server.lane_wait_s",
     "checkpoint", 0.1, 1.0,
     "checkpoint-lane server queueing must stay bounded under floods"),
)

# histogram that sizes the "how long should an epoch take" baseline for
# the epoch-stall watchdog, per inflight phase
_PHASE_HIST = {"drain": "manager.drain_epoch_s",
               "stage": "manager.stage_epoch_s"}


@dataclass
class HealthConfig:
    """Knobs for the evaluator. ``interval_s`` is the manager run-loop
    cadence; the watchdog counts are in units of evaluations, so their
    wall-clock reaction time scales with it."""
    interval_s: float = 0.25       # manager evaluation cadence
    stall_factor: float = 4.0      # epoch stalled at factor x histogram p99
    stall_floor_s: float = 2.0     # ...but never earlier than this
    silent_evals: int = 4          # evals without sends while peers advance
    queue_growth_evals: int = 4    # consecutive strictly-growing depths
    trace_ring: int = 256          # per-op-kind duration samples for p99
    max_pending_traces: int = 1024  # unfinalized span-tree buffer bound


def _segment(name: str) -> str:
    """Map a span name onto a critical-path segment."""
    if "lane_wait" in name:
        return "queue"
    if name.startswith("store.fsync"):
        return "fsync"
    return "service"


class HealthEngine:
    """Stateful evaluator: feed it registry snapshots (plus the manager's
    inflight-epoch view and the tracer) on a fixed cadence; read the last
    report any time. All mutation happens inside ``evaluate()`` — its
    single caller is the manager run loop — and the report is replaced
    wholesale, so cross-thread readers (``pressure_report``, the
    ``health_query`` handler, bbtop) see a coherent dict without a lock.
    """

    def __init__(self, cfg: Optional[HealthConfig] = None,
                 clock: Callable[[], float] = time.monotonic,
                 rules=SLO_RULES):
        self.cfg = cfg or HealthConfig()
        self.rules = rules
        self._clock = clock
        self._evals = 0
        # burn-rate windows: (instrument, label) -> (count, buckets) at
        # the previous evaluation
        self._prev_hist: Dict[Tuple[str, str], Tuple[int, List[int]]] = {}
        # silent-server watchdog: src -> [last_total, stalled_evals]
        self._progress: Dict[str, List[float]] = {}
        # queue-growth watchdog: server -> [last_depth, growing_evals]
        self._qgrowth: Dict[str, List[float]] = {}
        # anomaly keys currently firing (flight-record only transitions)
        self._active: set = set()
        # critical-path state: buffered span trees + per-op aggregates
        self._traces: Dict[int, dict] = {}
        self._events_seen = 0
        self._agg: Dict[str, dict] = {}
        self._report: dict = {
            "status": "ok", "evals": 0, "t": 0.0, "slos": [],
            "watchdogs": [], "bottlenecks": {"ops": {}, "top": None}}
        self._m_anom = telemetry.counter("health.anomalies")
        self._m_eval = telemetry.histogram("health.eval_s")

    # ------------------------------------------------------------------ api
    def report(self) -> dict:
        """The most recent evaluation's report (cheap, lock-free)."""
        return self._report

    def evaluate(self, snapshot: dict, inflight: Optional[dict] = None,
                 tracer=None, now: Optional[float] = None) -> dict:
        """One full pass: SLO rules + watchdogs + critical-path ingest.

        ``snapshot`` is a ``Registry.snapshot()`` dict; ``inflight`` is the
        manager's view of open epochs (``{"drain": {"epoch", "started"},
        "stage": {...}}``); ``tracer`` is the live ``Tracer`` (or None to
        skip attribution — e.g. when rendering a saved snapshot)."""
        now = self._clock() if now is None else now
        t0 = self._clock()
        self._evals += 1
        slos = [self._eval_rule(rule, snapshot) for rule in self.rules]
        watchdogs = self._watchdogs(snapshot, inflight or {}, now)
        if tracer is not None:
            self._ingest(tracer)
        bottlenecks = self._bottlenecks()
        status = worst([s["verdict"] for s in slos]
                       + [w["verdict"] for w in watchdogs])
        self._report = {"status": status, "evals": self._evals, "t": now,
                        "slos": slos, "watchdogs": watchdogs,
                        "bottlenecks": bottlenecks}
        self._m_eval.observe(self._clock() - t0)
        return self._report

    # ------------------------------------------------------------ SLO rules
    def _eval_rule(self, rule, snapshot: dict) -> dict:
        name, kind, instrument, label, warn, critical, summary = rule
        if kind == "latency_p99":
            candidates = self._windowed_p99s(instrument, label, snapshot)
        elif kind == "ring_last":
            candidates = self._ring_lasts(instrument, snapshot)
        else:                                   # poll_max
            candidates = self._poll_values(instrument, snapshot)
        out = {"rule": name, "kind": kind, "instrument": instrument,
               "verdict": "ok", "value": None, "label": None,
               "warn": warn, "critical": critical, "summary": summary}
        for lb, value, extra in candidates:
            verdict = "critical" if value >= critical else \
                "warn" if value >= warn else "ok"
            if _RANK[verdict] > _RANK[out["verdict"]] or (
                    out["value"] is None) or (
                    _RANK[verdict] == _RANK[out["verdict"]]
                    and value > out["value"]):
                out.update({"verdict": verdict, "value": value,
                            "label": lb, **extra})
        return out

    def _windowed_p99s(self, instrument: str, label: Optional[str],
                       snapshot: dict):
        """Per-label p99 of the samples observed since the previous
        evaluation (cumulative on the first sight of a series). Labels
        with no new samples this window yield nothing — an idle series is
        not evidence of health or sickness."""
        hist = snapshot.get("histograms", {}).get(instrument)
        if not hist:
            return []
        bounds = hist.get("bounds", [])
        out = []
        for lb, st in sorted(hist.get("series", {}).items()):
            if label is not None and lb != label:
                continue
            key = (instrument, lb)
            prev = self._prev_hist.get(key)
            buckets, count = st["buckets"], st["count"]
            if prev is not None and prev[0] <= count:
                dcount = count - prev[0]
                dbuckets = [c - p for c, p in zip(buckets, prev[1])]
            else:                   # first sight (or a registry reset)
                dcount, dbuckets = count, buckets
            self._prev_hist[key] = (count, list(buckets))
            if dcount <= 0:
                continue
            out.append((lb, quantile(bounds, dbuckets, dcount, 0.99),
                        {"window_count": dcount}))
        return out

    def _ring_lasts(self, instrument: str, snapshot: dict):
        last: Dict[str, float] = {}
        for _t, lb, value in snapshot.get("rings", {}).get(instrument, []):
            last[lb] = value        # samples are time-ordered
        return [(lb, v, {}) for lb, v in sorted(last.items())]

    def _poll_values(self, instrument: str, snapshot: dict):
        inst, _, field = instrument.partition(":")
        out = []
        for lb, snap in sorted(
                snapshot.get("polls", {}).get(inst, {}).items()):
            v = snap.get(field) if isinstance(snap, dict) else None
            if isinstance(v, (int, float)):
                out.append((lb, float(v), {}))
        return out

    # ------------------------------------------------------------ watchdogs
    def _watchdogs(self, snapshot: dict, inflight: dict,
                   now: float) -> List[dict]:
        anomalies = []
        anomalies.extend(self._wd_epoch_stall(snapshot, inflight, now))
        anomalies.extend(self._wd_silent_server(snapshot))
        anomalies.extend(self._wd_queue_growth(snapshot))
        # flight-record (and count) only the *transitions* into anomaly, so
        # a wedge held across many evaluations is one event, not a flood
        firing = set()
        for a in anomalies:
            key = (a["kind"], a.get("server") or a.get("phase"))
            firing.add(key)
            if key not in self._active:
                self._m_anom.inc(label=a["kind"])
                telemetry.record("health", a["kind"],
                                 **{k: v for k, v in a.items()
                                    if k != "kind"})
        self._active = firing
        return anomalies

    def _wd_epoch_stall(self, snapshot: dict, inflight: dict, now: float):
        """An open drain/stage epoch older than ``stall_factor ×`` its own
        completion-time p99 (with a floor while the histogram is young) is
        wedged: completions observe the histogram, so a stuck epoch never
        raises the baseline it is judged against."""
        out = []
        for phase, hist_name in sorted(_PHASE_HIST.items()):
            info = inflight.get(phase)
            if not info:
                continue
            age = now - info.get("started", now)
            hist = snapshot.get("histograms", {}).get(hist_name, {})
            limit = self.cfg.stall_floor_s
            series = hist.get("series", {}).get("")
            if series and series["count"]:
                p99 = quantile(hist.get("bounds", []), series["buckets"],
                               series["count"], 0.99)
                limit = max(limit, self.cfg.stall_factor * p99)
            if age > limit:
                out.append({"kind": "epoch_stall", "verdict": "critical",
                            "phase": phase, "epoch": info.get("epoch"),
                            "age_s": age, "limit_s": limit})
        return out

    def _wd_silent_server(self, snapshot: dict):
        """A server whose ``transport.src_msgs`` counter froze for
        ``silent_evals`` evaluations while at least one peer's advanced.
        Idle clusters are exempt: with nobody advancing there is no
        evidence of asymmetry (servers heartbeat pressure reports and
        stabilization pings, so a healthy loaded cluster always sends)."""
        totals = {src: total for src, total in snapshot.get(
            "counters", {}).get("transport.src_msgs", {}).items()
            if src.startswith("server")}
        # advancement is judged against the previous evaluation only —
        # first-sight servers have no baseline yet and just record one
        peers_advanced = any(
            src in self._progress and total > self._progress[src][0]
            for src, total in totals.items())
        out = []
        for src, total in sorted(totals.items()):
            st = self._progress.get(src)
            if st is None:
                self._progress[src] = [total, 0]
                continue
            if total > st[0]:
                st[0], st[1] = total, 0
            elif peers_advanced:
                st[1] += 1
            if st[1] >= self.cfg.silent_evals:
                out.append({"kind": "silent_server", "verdict": "critical",
                            "server": src, "msgs": total,
                            "stalled_evals": st[1]})
        return out

    def _wd_queue_growth(self, snapshot: dict):
        """A lane queue whose depth grew strictly monotonically across
        ``queue_growth_evals`` evaluations: arrival rate has outrun
        service rate for the whole observation window, which ends in the
        queue-depth SLO going critical if nothing intervenes."""
        out = []
        for server, snap in sorted(snapshot.get("polls", {}).get(
                "server.ops", {}).items()):
            depth = snap.get("queued_puts") if isinstance(snap, dict) \
                else None
            if not isinstance(depth, (int, float)):
                continue
            st = self._qgrowth.setdefault(server, [depth, 0])
            st[1] = st[1] + 1 if depth > st[0] else 0
            st[0] = depth
            if st[1] >= self.cfg.queue_growth_evals:
                out.append({"kind": "queue_growth", "verdict": "warn",
                            "server": server, "depth": depth,
                            "growing_evals": st[1]})
        return out

    # -------------------------------------------- critical-path attribution
    def _ingest(self, tracer):
        """Consume spans finished since the last evaluation and finalize
        the trace trees that have settled. A trace is attributed one
        evaluation after its last span lands: span trees complete across
        threads, so the cadence gap doubles as the straggler barrier."""
        total = tracer.events_total()
        fresh = total - self._events_seen
        self._events_seen = total
        if fresh > 0:
            events = tracer.events()
            for ev in events[-fresh:] if fresh < len(events) else events:
                trace_id, span_id, parent, name, _comp, _t0, dur, _args = ev
                ent = self._traces.get(trace_id)
                if ent is None:
                    while len(self._traces) >= self.cfg.max_pending_traces:
                        self._traces.pop(next(iter(self._traces)))
                    ent = self._traces[trace_id] = {
                        "spans": [], "root": None, "touched": 0}
                ent["spans"].append((span_id, parent, name, dur))
                if parent == 0:
                    ent["root"] = (name, dur)
                ent["touched"] = self._evals
        settled = [tid for tid, ent in self._traces.items()
                   if ent["root"] is not None
                   and ent["touched"] < self._evals]
        for tid in settled:
            self._finalize(self._traces.pop(tid))

    def _finalize(self, ent: dict):
        """Decompose one completed trace: per-span self time (duration
        minus direct children) lands in its name's segment — except the
        root's, which is by construction the time no handler span covers:
        the network/scheduling gap between hops. Shares are normalized
        over the segment total, so concurrent child threads (self time
        exceeding root wall) stay a partition."""
        kind, wall = ent["root"]
        child_dur: Dict[int, float] = {}
        for span_id, parent, _name, dur in ent["spans"]:
            child_dur[parent] = child_dur.get(parent, 0.0) + dur
        segs = {"queue": 0.0, "service": 0.0, "fsync": 0.0, "network": 0.0}
        total_self = 0.0
        for span_id, parent, name, dur in ent["spans"]:
            self_t = dur - child_dur.get(span_id, 0.0)
            if self_t > 0.0:
                segs["network" if parent == 0
                     else _segment(name)] += self_t
                total_self += self_t
        if wall > total_self:
            segs["network"] += wall - total_self
        agg = self._agg.get(kind)
        if agg is None:
            agg = self._agg[kind] = {
                "count": 0, "wall": 0.0,
                "durs": collections.deque(maxlen=self.cfg.trace_ring),
                "segs": {"queue": 0.0, "service": 0.0, "fsync": 0.0,
                         "network": 0.0}}
        agg["count"] += 1
        agg["wall"] += wall
        agg["durs"].append(wall)
        for seg, v in segs.items():
            agg["segs"][seg] += v

    def _bottlenecks(self) -> dict:
        ops = {}
        top = None
        for kind, agg in sorted(self._agg.items()):
            total = sum(agg["segs"].values())
            denom = total if total > 0.0 else 1.0
            durs = sorted(agg["durs"])
            p99 = durs[min(len(durs) - 1, int(0.99 * len(durs)))] \
                if durs else 0.0
            dominant = max(agg["segs"], key=lambda s: agg["segs"][s])
            share = agg["segs"][dominant] / denom
            ops[kind] = {
                "count": agg["count"], "wall_s": agg["wall"], "p99_s": p99,
                "segments": {s: {"s": v, "share": v / denom}
                             for s, v in sorted(agg["segs"].items())},
                "dominant": dominant,
                "summary": f"{dominant} is {share * 100.0:.0f}% "
                           f"of {kind}"}
            if top is None or agg["wall"] > ops[top]["wall_s"]:
                top = kind
        return {"ops": ops,
                "top": None if top is None else {
                    "op": top, "segment": ops[top]["dominant"],
                    "share": ops[top]["segments"][
                        ops[top]["dominant"]]["share"],
                    "summary": ops[top]["summary"]}}
